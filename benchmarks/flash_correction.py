"""llama3-405b x train_4k / prefill_32k with flash attention: corrected
roofline terms.

The dry-run executes Pallas kernels in interpret mode on CPU, so the HLO
of a flash cell contains the *emulation* (grid loop of dynamic slices),
whose cost_analysis bytes wildly overstate the real kernel (the whole
point of flash attention is that the S^2 intermediates live in VMEM and
never touch HBM).  This script builds the corrected cell:

    corrected = baseline_cell
                - measured naive-SDPA cost x n_layers (component probe)
                + analytic flash cost x n_layers (known by construction)

Flash analytic model per layer (per device, causal factor 1/2):
    flops_fwd  = 0.5 * 4 * B*H*S^2*hd          (qk + pv MXU work)
    flops_bwd  = 0.5 * 14 * B*H*S^2*hd         (dq: s,dp,dq; dkv: s,dp,dk,dv)
    hbm_fwd    = (3 reads + 1 write) * B*S*H*hd * 2B  (+ lse, negligible)
    hbm_bwd    = (2 kernels x ~5 reads + 3 writes) * B*S*H*hd * 2B
    remat: fwd recomputation inside the checkpointed scan body uses flash
    too -> one extra flops_fwd/hbm_fwd.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import json  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.models.sharding import MeshRules  # noqa: E402
from repro.models.attention import _sdpa  # noqa: E402


def measure_naive_sdpa(cfg, B, S, rules):
    """Per-layer per-device flops/bytes of the naive softmax-attention
    chain (fwd and fwd+bwd), q/k/v head-sharded over TP."""
    H, hd = cfg.n_heads, cfg.head_dim
    tp_ok = H % rules.axis_size(rules.tp) == 0
    spec = [rules.batch_axes, None, rules.tp if tp_ok else None, None]
    sds = jax.ShapeDtypeStruct(
        (B, S, H, hd), jnp.bfloat16,
        sharding=rules.named(rules.fit((B, S, H, hd), spec)))
    mask = jnp.tril(jnp.ones((S, S), bool))

    def fwd(q, k, v):
        return _sdpa(q, k, v, mask, jnp.bfloat16)

    def loss(q, k, v):
        return jnp.sum(fwd(q, k, v).astype(jnp.float32))

    from repro.compat import cost_analysis
    cf = cost_analysis(jax.jit(fwd).lower(sds, sds, sds).compile())
    cg = cost_analysis(jax.jit(jax.grad(loss, argnums=(0, 1, 2))).lower(
        sds, sds, sds).compile())
    return ({"flops": float(cf["flops"]),
             "bytes": float(cf.get("bytes accessed", 0.0))},
            {"flops": float(cg["flops"]),
             "bytes": float(cg.get("bytes accessed", 0.0))})


def flash_analytic(cfg, B, S, rules):
    """Per-layer per-device flash cost (causal)."""
    tp = rules.axis_size(rules.tp)
    dp = rules.axis_size(rules.batch_axes)
    H = cfg.n_heads / (tp if cfg.n_heads % tp == 0 else 1)
    Bl = B / dp
    hd = cfg.head_dim
    mm = 2.0 * Bl * H * S * S * hd          # one S^2 matmul's flops
    io = Bl * S * H * hd * 2.0              # one q-sized HBM pass (bytes)
    return {
        "flops_fwd": 0.5 * 2 * mm,
        "flops_bwd": 0.5 * 7 * mm,
        "bytes_fwd": 4 * io,
        "bytes_bwd": 13 * io,
    }


def correct_cell(baseline_path, shape_name, out_path):
    base = json.load(open(baseline_path))
    cell = [r for r in base if r["arch"] == "llama3_405b"
            and r["shape"] == shape_name][0]
    cfg = get_config("llama3_405b")
    mesh = make_production_mesh()
    rules = MeshRules(mesh)
    from repro.models.config import SHAPES
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    train = shp.kind == "train"

    naive_f, naive_g = measure_naive_sdpa(cfg, B, S, rules)
    fa = flash_analytic(cfg, B, S, rules)
    L = cfg.n_layers
    # baseline per-cell naive attention cost (remat adds one extra fwd in
    # training; prefill has no bwd and no remat)
    if train:
        naive_flops = (naive_g["flops"] + naive_f["flops"]) * L
        naive_bytes = (naive_g["bytes"] + naive_f["bytes"]) * L
        flash_flops = (fa["flops_fwd"] * 2 + fa["flops_bwd"]) * L
        flash_bytes = (fa["bytes_fwd"] * 2 + fa["bytes_bwd"]) * L
    else:
        naive_flops = naive_f["flops"] * L
        naive_bytes = naive_f["bytes"] * L
        flash_flops = fa["flops_fwd"] * L
        flash_bytes = fa["bytes_fwd"] * L

    out = dict(cell)
    out["variant"] = "flash-attention (analytic kernel costs; see header)"
    out["naive_attn_flops_measured"] = naive_flops
    out["naive_attn_bytes_measured"] = naive_bytes
    out["flash_attn_flops_analytic"] = flash_flops
    out["flash_attn_bytes_analytic"] = flash_bytes
    f2 = cell["hlo_flops_per_device"] - naive_flops + flash_flops
    b2 = cell["hlo_bytes_per_device"] - naive_bytes + flash_bytes
    out["hlo_flops_per_device"] = f2
    out["hlo_bytes_per_device"] = b2
    out["t_compute"] = f2 / HW["peak_flops_bf16"]
    out["t_memory"] = b2 / HW["hbm_bw"]
    terms = {k: out[k] for k in ("t_compute", "t_memory", "t_collective")}
    out["bottleneck"] = max(terms, key=terms.get)
    out["roofline_fraction"] = out["t_compute"] / sum(terms.values())
    out["useful_flop_ratio"] = out["model_flops_per_device"] / f2
    json.dump(out, open(out_path, "w"), indent=1, default=str)
    print(json.dumps({k: out[k] for k in (
        "arch", "shape", "t_compute", "t_memory", "t_collective",
        "bottleneck", "useful_flop_ratio", "roofline_fraction")}, indent=1))
    return out


if __name__ == "__main__":
    correct_cell("benchmarks/results/dryrun_single.json", "train_4k",
                 "benchmarks/results/hillclimb_llama3_flash_analytic.json")
    correct_cell("benchmarks/results/dryrun_single.json", "prefill_32k",
                 "benchmarks/results/hillclimb_llama3_flash_prefill_analytic.json")
