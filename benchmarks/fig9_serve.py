"""Figure 9 (beyond paper): serving SLO — the continuous-batching
engine vs the perf-model latency model (DESIGN.md §13).

Three mixed-model estimators (two K-SVMs at different C, one K-RR) fit
on ONE training set share ONE device-resident operator through the
registry; the engine serves their interleaved traffic in virtual time:

  * correctness gate: engine-served values match the legacy dense
    oracles (``objectives.ksvm_predict`` / ``krr_predict``) to <= 1e-5 —
    batching, bucketing and column-stacking change the schedule, never
    the algebra;
  * no-recompile gate: after ``warmup`` the jit cache does not grow
    across the whole steady phase (``serve_cache_size``);
  * latency gate: measured p50/p99 and throughput within 10% of
    ``perf_model.modeled_serve_latency`` with gamma/dispatch/ticket
    CALIBRATED from three interleaved probe step timings (the model's
    shape — bucketed drain recurrence, per-ticket vs per-bucket-row
    cost split, (T, 2T] latency — is what's under test, not the
    machine constants);
  * refit gate: a mid-stream ``registry.refit`` atomically swaps the
    K-RR weights; post-swap engine answers match a COLD fit on the
    combined data to <= 1e-5, and pre-swap traffic is unaffected.

Latency measurement runs in VIRTUAL time: tickets are stamped at their
(deterministic, uniform-rate) arrival times via the engine's injectable
clock, and each step advances the clock by its own measured wall time —
so the p50/p99 comparison sees the device's actual step cost but not
the host scheduler's submission jitter.

Measuring sub-millisecond steps on a shared host needs three defenses,
all documented inline: probe sets INTERLEAVED into the drive (the cost
level drifts over tens of milliseconds — probes must see the drive's
regime), symmetric SPIKE exclusion (a scheduler preemption inside one
step is not the queueing model's to predict; both the measured
quantiles and the probe pool drop steps > SPIKE_CUT x median, and raw
values are reported alongside), and a probed TAIL factor (the p99
inherits the step-time jitter distribution, not the deterministic
1.99 x mean).  A gate miss retries on a fresh window, bounded —
persistent model error still fails every attempt.
"""
from __future__ import annotations

import argparse
import gc
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelRidge, KernelSVM, SolverOptions
from repro.core import KernelConfig
from repro.core.objectives import krr_predict, ksvm_predict
from repro.core.perf_model import (Machine, modeled_predict_cost,
                                   modeled_serve_latency)
from repro.core.predict import serve_cache_size
from repro.data.synthetic import classification_dataset
from repro.serve import ModelRegistry, ServingEngine

from .common import emit, save_json

SLOTS = 32
GATE = 0.10                         # modeled-vs-measured tolerance
SPIKE_CUT = 1.5                     # step > cut x median = host artifact


def _fit_models(m, n, max_iters):
    kern = KernelConfig("rbf", sigma=1.0)
    A, yc = classification_dataset(jax.random.key(0), m, n)
    rng = np.random.default_rng(1)
    yr = jnp.asarray(np.asarray(A) @ rng.standard_normal(n)
                     + 0.1 * rng.standard_normal(m), A.dtype)
    opts = SolverOptions(method="sstep", s=8, max_iters=max_iters,
                         tol=1e-7, check_every=8, seed=2)
    kopts = SolverOptions(method="sstep", s=8, b=8, max_iters=max_iters,
                          tol=1e-7, check_every=8, seed=2)
    svm_a = KernelSVM(C=1.0, kernel=kern, options=opts)
    svm_a.fit(A, yc)
    svm_b = KernelSVM(C=0.25, kernel=kern, options=opts)
    svm_b.fit(A, yc)
    krr = KernelRidge(lam=1.0, kernel=kern, options=kopts)
    krr.fit(A, yr)
    return A, yc, yr, svm_a, svm_b, krr


# The probe batch sizes: a step with b single-row tickets costs
# T(b) = d + h*b + g*bucket(b) — d fixed dispatch, h per REAL ticket
# (host admission / buffer fill / scatter), g per padded-bucket row
# (device serve).  Two probes cannot separate h from g, so three
# points solve it: b = SLOTS and b = SLOTS//2 + 1 share the SAME
# bucket (isolating h), b = 8 sits in its own (recovering g).
PROBE_BS = (8, SLOTS // 2 + 1, SLOTS)


def _probe_set(probe, names, Qs, samples, reps=5):
    """One set of interleaved probe STEP timings into ``samples``
    (dict b -> [seconds]).  Probes cycle b values rep-by-rep so slow
    host drift cancels out of the T(b) differences; the first rep of
    each set is a warmup and is not recorded."""
    for r in range(reps + 1):
        for b in PROBE_BS:
            for k in range(b):
                probe.submit(names[k % len(names)], Qs[k][None, :])
            t0 = time.perf_counter()
            probe.step()
            dt = time.perf_counter() - t0
            assert probe.pending == 0
            if r >= 1:
                samples[b].append(dt)


def _solve_constants(samples, m, n, kernel):
    """(Machine, dispatch, ticket) from pooled probe samples: solve
    d/h/g from the per-b medians of the T(b) = d + h*b + g*bucket(b)
    line (see ``PROBE_BS``)."""
    t8, t_half, t_full = (float(np.median(samples[b])) for b in PROBE_BS)
    h = max((t_full - t_half) / (PROBE_BS[2] - PROBE_BS[1]), 0.0)
    g = max((t_half - t8 - (PROBE_BS[1] - PROBE_BS[0]) * h)
            / (SLOTS - 8), 1e-12)
    dispatch = max(t8 - 8 * (h + g), 1e-9)
    f_q = modeled_predict_cost(m, n, 1, kernel)["flops_per_query"]
    return Machine(gamma=g / f_q), dispatch, h


def _tail_factor(samples):
    """Host-jitter correction for the model's p99: a ticket's latency
    is ``r*T_a + T_b`` (r = arrival offset, uniform; T_a, T_b the two
    step times it spans), so its p99 is the q99 of that sum under the
    PROBED step-time distribution — not 1.99x the mean, which is only
    the deterministic-T limit.  Probe samples pool across sizes after
    per-size median normalization (only the jitter SHAPE pools, not
    the size-dependent level), steps past SPIKE_CUT x the median drop
    (the drive's quantiles exclude them too), and the q99 of the sum is
    taken over a deterministic r-grid x ratio x ratio product.
    Returns q99(r*J_a + J_b) / 1.99 — the factor that turns the
    deterministic ``1.99 * t_step`` tail into the jittered one (1.0
    when the probes show no jitter)."""
    ratios = []
    for b in PROBE_BS:
        med = float(np.median(samples[b]))
        ratios.extend(x / med for x in samples[b]
                      if x <= SPIKE_CUT * med)
    J = np.asarray(ratios)
    r = (np.arange(50, dtype=np.float64) + 1.0) / 50.0
    lat = (r[:, None, None] * J[None, :, None]
           + J[None, None, :]).ravel()
    return float(np.quantile(lat, 0.99)) / 1.99


def _calibrate(registry, names, Qs, m, n, kernel, reps=10):
    """One-shot engine calibration: a throwaway probe engine over the
    same registry (so admission, buffer fill, transfer and scatter are
    all priced in — the model and the measurement cover the same
    system), one probe set, constants solved from the medians.  fig9's
    steady phase instead POOLS probe sets interleaved with the drive
    (`_probe_set` between traffic chunks): on a noisy host the cost
    level drifts over tens of milliseconds, and probes that bracket
    the measurement see the same regime it does."""
    probe = ServingEngine(registry, slots=SLOTS, max_queue=16 * SLOTS,
                          clock=_make_clock())
    probe.warmup()
    samples = {b: [] for b in PROBE_BS}
    gc.collect()
    gc.disable()
    try:
        _probe_set(probe, names, Qs, samples, reps=reps)
    finally:
        gc.enable()
    return _solve_constants(samples, m, n, kernel)


def _drive(engine, plan, *, vt0=0.0, between=None, every=0):
    """Serve an arrival plan ``[(t_arr, name, X), ...]`` in virtual
    time; returns (latencies, vt_end).  Each ticket is stamped at its
    arrival time via the injected clock; every step advances virtual
    time by its own measured wall duration.

    ``between`` (with ``every`` > 0) is called after every ``every``-th
    step, OUTSIDE the timed region and with the virtual clock frozen —
    fig9's steady phase runs calibration probe sets there, bracketing
    the measurement in wall time without perturbing it (the queue stays
    warm; no re-ramp).

    Latencies come back TAGGED with the index of the step that served
    them, and ``steps`` is one (dt, rows_done) per step — the spike
    filter needs to trace a slow step to the tickets it tainted."""
    clockv = engine.clock.box          # [vt] holder (see _make_clock)
    vt = vt0
    i, live, lats, steps = 0, [], [], []
    while i < len(plan) or engine.pending:
        if not engine.pending and i < len(plan) and plan[i][0] > vt:
            vt = plan[i][0]            # idle: fast-forward to arrivals
        while i < len(plan) and plan[i][0] <= vt:
            t_arr, name, X = plan[i]
            clockv[0] = t_arr          # stamp at TRUE arrival time
            t = engine.submit(name, X)
            live.append((t_arr, t))
            i += 1
        clockv[0] = vt
        t0 = time.perf_counter()
        engine.step()
        dt = time.perf_counter() - t0
        vt += dt
        still, done = [], 0
        for t_arr, t in live:
            if t.status == "done":
                lats.append((len(steps), vt - t_arr))  # done at step END
                done += 1
            else:
                still.append((t_arr, t))
        live = still
        steps.append((dt, done))
        if between is not None and every and len(steps) % every == 0:
            between()
    return lats, vt, steps


def _make_clock():
    box = [0.0]
    clock = lambda: box[0]
    clock.box = box
    return clock


def run(fast: bool = False):
    m, n = (384, 16) if fast else (2048, 32)
    max_iters = 2048 if fast else 4096
    n_queries = 600 if fast else 2000
    kern = "rbf"
    rows = []

    A, yc, yr, svm_a, svm_b, krr = _fit_models(m, n, max_iters)
    reg = ModelRegistry(predict_batch=SLOTS)
    reg.register("svm-a", svm_a)
    reg.register("svm-b", svm_b)
    reg.register("krr", krr)
    assert reg.n_groups == 1, \
        f"three models on one dataset must share one operator " \
        f"(got {reg.n_groups} groups)"

    clock = _make_clock()
    engine = ServingEngine(reg, slots=SLOTS, max_queue=4 * SLOTS,
                           clock=clock)
    engine.warmup()

    # ---- correctness: engine == legacy dense oracle ---------------------
    # 24 rows: a ticket must fit the admission window (SLOTS rows)
    Q = classification_dataset(jax.random.key(9), 24, n)[0]
    tickets = {name: engine.submit(name, Q)
               for name in ("svm-a", "svm-b", "krr")}
    engine.run_until_idle()
    oracle = {
        "svm-a": ksvm_predict(A, yc, svm_a.alpha_, Q, svm_a.cfg),
        "svm-b": ksvm_predict(A, yc, svm_b.alpha_, Q, svm_b.cfg),
        "krr": krr_predict(A, krr.alpha_, Q, krr.cfg),
    }
    for name, t in tickets.items():
        np.testing.assert_allclose(np.asarray(t.result),
                                   np.asarray(oracle[name]),
                                   rtol=1e-5, atol=1e-5)
    print(f"fig9: engine-served values match the dense oracles "
          f"(<=1e-5) for all {len(tickets)} models")

    # ---- calibrate + steady mixed traffic vs the model ------------------
    names = ["svm-a", "svm-b", "krr"]
    # HOST query rows: serving traffic arrives as host data, and host
    # submits keep the device queue untouched between steps (device-
    # resident plan rows would pay a D2H copy inside every submit)
    Qs = np.asarray(
        classification_dataset(jax.random.key(10), n_queries, n)[0])
    f_q = modeled_predict_cost(m, n, 1, kern)["flops_per_query"]

    def steady_attempt():
        """One calibrate-drive-gate pass; returns (row, measured,
        model, gates) or raises AssertionError on a gate miss."""
        # the pilot calibration ONLY picks the offered rate: aim the
        # drain fixed point at the MIDDLE of the 16-bucket (steady
        # batch b* ~ 12) — far from both the bucket-8/16 and 16/32
        # edges, so a 20% host slowdown moves b* WITHIN the bucket
        # instead of flipping the orbit across a bucket boundary the
        # fluid model averages differently.  Any unsaturated rate
        # works for the gate itself: the GATED model is built from the
        # interleaved probes below, at this same rate, so it does not
        # inherit the pilot's error.
        mach0, dispatch0, ticket0 = _calibrate(reg, names, Qs, m, n,
                                               kern)
        t16 = dispatch0 + 12 * ticket0 + 16 * float(mach0.gamma * f_q)
        rate = 12.0 / t16
        plan = [(k / rate, names[k % 3], Qs[k][None, :])
                for k in range(n_queries)]
        eng = ServingEngine(reg, slots=SLOTS, max_queue=4 * SLOTS,
                            clock=_make_clock())
        # measure with probe sets INTERLEAVED into the drive (every
        # 5th step, virtual clock frozen, queue kept warm): on a noisy
        # host the cost level drifts over tens of milliseconds, and
        # probes that bracket the drive see the regime the drive
        # actually ran in
        probe = ServingEngine(reg, slots=SLOTS, max_queue=16 * SLOTS,
                              clock=_make_clock())
        samples = {b: [] for b in PROBE_BS}
        cache_before = serve_cache_size()
        gc.collect()
        gc.disable()                    # no GC pauses in timed steps
        try:
            lats, vt_end, steps = _drive(
                eng, plan, every=5,
                between=lambda: _probe_set(probe, names, Qs, samples,
                                           reps=2))
        finally:
            gc.enable()
        cache_growth = serve_cache_size() - cache_before
        mach, dispatch, ticket = _solve_constants(samples, m, n, kern)
        tail = _tail_factor(samples)
        model = modeled_serve_latency(rate, SLOTS, m, n, kern,
                                      mach=mach, dispatch_s=dispatch,
                                      ticket_s=ticket,
                                      tail_factor=tail)
        assert cache_growth == 0, \
            f"steady mixed traffic recompiled ({cache_growth} new " \
            f"jit cache entries after warmup)"
        assert eng.stats["shed"] == 0 and eng.stats["expired"] == 0, \
            f"unsaturated steady traffic shed/expired tickets " \
            f"(shed={eng.stats['shed']} expired={eng.stats['expired']}" \
            f") — host stalled long enough to overflow the queue"

        # host-preemption spikes: a scheduler pause inside one
        # sub-millisecond step taints every ticket it served AND the
        # tickets queued behind it — no latency model predicts the
        # host's scheduler, so steps > SPIKE_CUT x the median (and
        # their successors) are excluded from the gated quantiles and
        # REPORTED raw alongside.  At the pinned operating point the
        # legitimate step-cost spread is only a few percent (b* moves
        # +-2 tickets -> +-2h), so a 50%-over-median step IS an
        # artifact, not load
        dts = np.asarray([dt for dt, _ in steps])
        med = float(np.median(dts))
        spiked = {k for k, dt in enumerate(dts)
                  if dt > SPIKE_CUT * med}
        excluded = spiked | {k + 1 for k in spiked}
        # the model describes the STEADY state; the first steps also
        # ramp the batch up from an empty queue — drop that transient
        lats = lats[len(lats) // 3:]
        clean = np.asarray([l for k, l in lats if k not in excluded])
        raw = np.asarray([l for _, l in lats])
        # sustained service rate over clean steps: each unsaturated
        # step serves exactly what arrived during the previous one, so
        # rows/second over clean steps measures delivered throughput
        # without crediting or blaming preempted wall time
        clean_steps = [(dt, done) for k, (dt, done) in enumerate(steps)
                       if k not in excluded and done > 0]
        thr = (sum(d for _, d in clean_steps)
               / sum(dt for dt, _ in clean_steps))
        # the exclusion is SYMMETRIC (probe pool and measured quantiles
        # drop the same class of steps), so gating stays meaningful as
        # long as most of the window is clean — refuse only when the
        # host preempted a quarter of it
        assert len(spiked) <= max(2, len(steps) // 4), \
            f"host too noisy to gate: {len(spiked)}/{len(steps)} " \
            f"steps spiked > {SPIKE_CUT}x median"
        measured = {"p50_s": float(np.quantile(clean, 0.5)),
                    "p99_s": float(np.quantile(clean, 0.99)),
                    "throughput_qps": thr}
        gates = {}
        for key in ("p50_s", "p99_s", "throughput_qps"):
            rel = abs(measured[key] - model[key]) / model[key]
            gates[key] = rel
            assert rel <= GATE, \
                f"fig9 {key}: measured {measured[key]:.3e} vs " \
                f"modeled {model[key]:.3e} — off by {rel:.1%} " \
                f"(> {GATE:.0%})"
        row = {"phase": "steady", "m": m, "n": n, "slots": SLOTS,
               "rate_qps": rate, "queries": n_queries,
               "measured": measured,
               "raw": {"p50_s": float(np.quantile(raw, 0.5)),
                       "p99_s": float(np.quantile(raw, 0.99)),
                       "spiked_steps": len(spiked),
                       "total_steps": len(steps)},
               "modeled": {k: model[k] for k in
                           ("p50_s", "p99_s", "throughput_qps",
                            "t_step_s", "batch", "capacity_qps")},
               "tail_factor": tail,
               "rel_err": gates, "cache_growth": cache_growth,
               "stats": dict(eng.stats)}
        return row, measured, model, gates

    # the gate compares sub-millisecond wall timings on a shared host:
    # one scheduler preemption inside the ~25-step window shifts p99 by
    # more than the 10% gate, so a miss is retried on a fresh window
    # (bounded — persistent model error still fails all attempts)
    attempts = 4
    for attempt in range(attempts):
        try:
            row, measured, model, gates = steady_attempt()
            break
        except AssertionError as e:
            if attempt == attempts - 1:
                raise
            print(f"fig9: steady attempt {attempt + 1} missed a gate "
                  f"({e}); retrying on a fresh window")
            time.sleep(0.3 * (attempt + 1))  # decorrelate from a
            # transient host-contention burst before the next window
    rows.append(row)
    emit("fig9/steady", measured["p50_s"] * 1e6,
         f"p50={measured['p50_s']*1e3:.2f}ms("
         f"model={model['p50_s']*1e3:.2f});"
         f"p99={measured['p99_s']*1e3:.2f}ms;"
         f"qps={measured['throughput_qps']:.0f}")
    print(f"fig9: measured p50/p99/throughput within "
          f"{max(gates.values()):.1%} of the calibrated model "
          f"(gate {GATE:.0%})")

    # ---- overload: the bounded queue sheds, survivors keep latency ------
    over = ServingEngine(reg, slots=SLOTS, max_queue=SLOTS,
                         clock=_make_clock())
    over.warmup()
    burst = [(0.0, names[k % 3], Qs[k][None, :]) for k in range(200)]
    o_tagged, _, _ = _drive(over, burst)
    o_lats = [l for _, l in o_tagged]
    assert over.stats["shed"] > 0, "a 200-query burst into a one-window "\
        "queue must shed"
    rows.append({"phase": "overload", "burst": 200,
                 "shed": over.stats["shed"],
                 "served": over.stats["served"],
                 "p99_survivors_s": float(np.quantile(o_lats, 0.99))})
    emit("fig9/overload", float(np.quantile(o_lats, 0.99)) * 1e6,
         f"shed={over.stats['shed']}/200;"
         f"served={over.stats['served']}")

    # ---- mid-stream refit: atomic swap == cold fit ----------------------
    X_new = classification_dataset(jax.random.key(11), m // 8, n)[0]
    rng = np.random.default_rng(4)
    y_new = jnp.asarray(np.asarray(X_new) @ rng.standard_normal(n),
                        A.dtype)
    pre = engine.submit("krr", Q[:8])
    engine.run_until_idle()
    res = reg.refit("krr", X_new, y_new)
    reg.warmup()                       # the refit model's NEW group
    cache_mid = serve_cache_size()
    post = engine.submit("krr", Q[:8])
    engine.run_until_idle()
    assert serve_cache_size() == cache_mid, \
        "post-refit traffic recompiled after the new group's warmup"
    cold = KernelRidge(lam=1.0, kernel=KernelConfig("rbf", sigma=1.0),
                       options=krr.options)
    cold.fit(jnp.concatenate([A, X_new]), jnp.concatenate([yr, y_new]))
    np.testing.assert_allclose(np.asarray(post.result),
                               np.asarray(cold.predict(Q[:8])),
                               rtol=1e-5, atol=1e-5)
    drift = float(jnp.max(jnp.abs(post.result - pre.result)))
    rows.append({"phase": "refit", "new_rows": int(X_new.shape[0]),
                 "refit_converged": bool(res.converged),
                 "refit_iters": res.iters_run,
                 "pre_post_drift": drift})
    emit("fig9/refit", 0.0,
         f"cold-fit match<=1e-5;iters={res.iters_run};"
         f"swap_drift={drift:.2e}")
    print(f"fig9: mid-stream refit matches a cold fit on the combined "
          f"data (<=1e-5); the swap visibly moved the served model "
          f"(drift {drift:.2e})")

    save_json("fig9_serve.json", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(fast=ap.parse_args().fast)
