"""fig11: the price and product of telemetry (DESIGN.md §15).

Two overhead gates and three artifact checks:

  * GUARDED SOLVE — the same guarded s-step KRR fit with telemetry off
    vs on (fresh ``Telemetry`` per rep: host spans + traced marks at
    the sync points + guard counters).  Best-of-N wall clock; the
    enabled/disabled ratio must stay within ``GATE`` (3%).
  * SERVING DRIVE — a fig9-style ticket stream through ``ServingEngine``
    with and without the serving instruments (queue gauge, ticket
    counters, occupancy + latency histograms).  Same best-of-N gate.
  * the telemetry-ON artifacts must be USABLE: the modeled-vs-measured
    audit reconciles the instrumented fit, the merged solve+serve trace
    exports as schema-valid Chrome-trace JSON (committed to
    ``results/fig11_trace.json`` — CI uploads it as an artifact), and
    the engine metrics parse as Prometheus text exposition.

Sub-millisecond gates on a shared host are jittery, so a missed gate
retries on a fresh window (bounded attempts), mirroring fig9.
"""
from __future__ import annotations

import gc
import os
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelRidge, SolverOptions
from repro.core.kernels import KernelConfig
from repro.data.synthetic import classification_dataset
from repro.obs import Telemetry
from repro.serve import ModelRegistry, ServingEngine

from .common import RESULTS_DIR, emit, save_json

GATE = 0.03                      # enabled/disabled overhead ceiling
SLOTS = 32

# one Prometheus text-exposition sample line:  name{labels} value
_PROM_SAMPLE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$")


def _problem(m, n):
    A, _ = classification_dataset(jax.random.key(0), m, n)
    rng = np.random.default_rng(0)
    y = jnp.asarray(np.asarray(A) @ rng.standard_normal(n)
                    + 0.1 * rng.standard_normal(m), A.dtype)
    return A, y


def _opts(iters, telemetry):
    # guarded s-step KRR: tolerance path + drift correction + segment
    # seams — every mark site in the protocol is live.  Cadence 16:
    # each traced mark costs a fixed ~100us host-callback round trip,
    # so the gate prices telemetry at a practical check cadence on a
    # solve whose rounds do real work — not callbacks back to back.
    return SolverOptions(method="sstep", s=8, b=8, tol=1e-12,
                         check_every=16, max_iters=iters, guard=True,
                         recompute_every=16, seed=3, telemetry=telemetry)


def _best_of(fn, reps):
    # GC paused across the timed reps (both sides of every gate see the
    # same policy): in a long benchmark process a collection triggered
    # mid-window traverses ten suites' worth of live jit caches, a
    # multi-ms stall that would gate the collector, not telemetry
    gc.collect()
    gc.disable()
    ts = []
    try:
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    return min(ts)


def _solve_overhead(A, y, iters, reps):
    """(t_off, t_on, result_on): best-of-N walls for the telemetry-off
    and telemetry-on guarded fits, plus one instrumented FitResult for
    the audit/trace artifacts."""
    kern = KernelConfig("rbf", sigma=1.0)

    def fit(telemetry):
        kr = KernelRidge(lam=1.0, kernel=kern,
                         options=_opts(iters, telemetry))
        return kr.fit(A, y)

    # warm BOTH compile caches: marks=True/False are distinct static
    # args, so each side pays its own trace before any timed window
    fit(None)
    fit(Telemetry())
    t_off = _best_of(lambda: fit(None), reps)
    t_on = _best_of(lambda: fit(Telemetry()), reps)
    # the artifact fit runs LAST, fully warm: its "fit" span holds pure
    # run time, so the audit's measured shares aren't compile-skewed
    result_on = fit(Telemetry())
    return t_off, t_on, result_on


def _serve_drive(reg, names, Q, telemetry):
    eng = ServingEngine(reg, slots=SLOTS, telemetry=telemetry)
    for i in range(Q.shape[0]):
        eng.submit(names[i % len(names)], Q[i])
        if (i + 1) % 8 == 0:
            eng.step()
    eng.run_until_idle()
    return eng


def _serve_overhead(A, y, iters, tickets, reps):
    kern = KernelConfig("rbf", sigma=1.0)
    kr = KernelRidge(lam=1.0, kernel=kern,
                     options=SolverOptions(method="sstep", s=8, b=8,
                                           max_iters=iters, seed=4))
    kr.fit(A, y)
    reg = ModelRegistry(predict_batch=SLOTS)
    names = ("krr",)
    reg.register("krr", kr)
    reg.warmup()
    # each ticket carries a REAL query batch (ROWS rows), the practical
    # operating point: the per-ticket instrument cost (a couple of
    # counter incs + two histogram observes, ~5us) is fixed, so the
    # gate must price it against tickets that do device work — single-
    # row tickets would measure the metrics dict, not serving
    rows = 32
    Q = np.asarray(classification_dataset(
        jax.random.key(5), tickets * rows,
        A.shape[1])[0]).reshape(tickets, rows, A.shape[1])

    _serve_drive(reg, names, Q, None)            # warm the step path
    _serve_drive(reg, names, Q, Telemetry())
    # INTERLEAVED off/on reps: host-state drift over a ~20ms drive is
    # bigger than the 3% gate, so back-to-back blocks would gate the
    # drift, not the telemetry — alternating pairs see the same host
    ts_off, ts_on = [], []
    tel_last = {}
    gc.collect()
    gc.disable()                       # see _best_of
    try:
        for _ in range(reps):
            t0 = time.perf_counter()
            _serve_drive(reg, names, Q, None)
            ts_off.append(time.perf_counter() - t0)
            tel = Telemetry()
            t0 = time.perf_counter()
            _serve_drive(reg, names, Q, tel)
            ts_on.append(time.perf_counter() - t0)
            tel_last["tel"] = tel
    finally:
        gc.enable()
    return min(ts_off), min(ts_on), tel_last["tel"]


def _check_prometheus(text):
    """Every non-comment line must be a well-formed sample; at least
    the four serving instruments must be present."""
    lines = [ln for ln in text.splitlines() if ln.strip()]
    for ln in lines:
        if ln.startswith("#"):
            assert ln.startswith(("# HELP ", "# TYPE ")), ln
            continue
        assert _PROM_SAMPLE.match(ln), f"malformed sample line: {ln!r}"
    for name in ("repro_serve_queue_depth", "repro_serve_tickets_total",
                 "repro_serve_batch_occupancy",
                 "repro_serve_ticket_latency_seconds"):
        assert any(ln.startswith(name) or f" {name} " in ln
                   for ln in lines), f"{name} missing from exposition"
    return len(lines)


def run(fast=False):
    from repro.obs.audit import audit_fit
    from repro.obs.export import save_trace, to_chrome_trace

    m, n = (768, 32) if fast else (1024, 32)
    iters = 512 if fast else 1024
    tickets = 64 if fast else 128
    reps = 5 if fast else 7
    attempts = 5

    A, y = _problem(m, n)

    # ---- guarded solve: telemetry off vs on ----------------------------
    for attempt in range(attempts):
        t_off, t_on, result_on = _solve_overhead(A, y, iters, reps)
        ov_solve = t_on / t_off - 1.0
        if ov_solve <= GATE:
            break
        if attempt == attempts - 1:
            raise AssertionError(
                f"solve telemetry overhead {ov_solve:.1%} exceeds the "
                f"{GATE:.0%} gate (off {t_off*1e3:.2f}ms vs on "
                f"{t_on*1e3:.2f}ms)")
        print(f"fig11: solve overhead attempt {attempt + 1} measured "
              f"{ov_solve:.1%}; retrying on a fresh window")
        time.sleep(0.3 * (attempt + 1))
    emit("fig11/solve", t_on * 1e6,
         f"overhead={ov_solve:+.2%};gate={GATE:.0%};"
         f"off={t_off*1e3:.2f}ms")
    print(f"fig11: guarded solve telemetry overhead {ov_solve:+.2%} "
          f"(gate {GATE:.0%})")

    # ---- serving drive: instruments off vs on --------------------------
    for attempt in range(attempts):
        s_off, s_on, serve_tel = _serve_overhead(A, y, iters, tickets,
                                                 reps)
        ov_serve = s_on / s_off - 1.0
        if ov_serve <= GATE:
            break
        if attempt == attempts - 1:
            raise AssertionError(
                f"serving telemetry overhead {ov_serve:.1%} exceeds "
                f"the {GATE:.0%} gate (off {s_off*1e3:.2f}ms vs on "
                f"{s_on*1e3:.2f}ms)")
        print(f"fig11: serve overhead attempt {attempt + 1} measured "
              f"{ov_serve:.1%}; retrying on a fresh window")
        time.sleep(0.3 * (attempt + 1))
    emit("fig11/serve", s_on * 1e6,
         f"overhead={ov_serve:+.2%};gate={GATE:.0%};"
         f"tickets={tickets}")
    print(f"fig11: serving telemetry overhead {ov_serve:+.2%} "
          f"(gate {GATE:.0%})")

    # ---- the artifacts the instrumented run must yield -----------------
    report = audit_fit(result_on)
    print(report.render())

    tel = result_on.telemetry
    tel.spans.extend(serve_tel.spans)
    tel.marks.extend(serve_tel.marks)
    trace_path = os.path.join(RESULTS_DIR, "fig11_trace.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    save_trace(trace_path, tel)        # validates the schema internally
    n_events = len(to_chrome_trace(tel)["traceEvents"])
    emit("fig11/trace", 0.0, f"events={n_events};path=results/"
                             f"fig11_trace.json")

    prom = serve_tel.metrics.to_prometheus_text()
    n_lines = _check_prometheus(prom)
    emit("fig11/prometheus", 0.0, f"lines={n_lines}")
    print(f"fig11: audit ratio {report.ratio:.2f}, "
          f"{len(report.flagged)} flagged phase(s); trace "
          f"{n_events} events; prometheus {n_lines} lines parse")

    save_json("fig11_obs.json", {
        "solve": {"m": m, "n": n, "iters": iters, "reps": reps,
                  "t_off_s": t_off, "t_on_s": t_on,
                  "overhead": ov_solve, "gate": GATE,
                  "spans": len(result_on.telemetry.spans),
                  "marks": len(result_on.telemetry.marks)},
        "serve": {"tickets": tickets, "reps": reps, "t_off_s": s_off,
                  "t_on_s": s_on, "overhead": ov_serve, "gate": GATE},
        "audit": report.to_dict(),
        "trace": {"events": n_events,
                  "path": "benchmarks/results/fig11_trace.json"},
        "prometheus_lines": n_lines,
    })


if __name__ == "__main__":
    import sys
    run(fast="--fast" in sys.argv)
