"""Figure 5 (beyond paper): slab-free (GramOperator/KMV) vs materialized
s-step BDCD rounds — modeled HBM bytes and measured round time.

The paper removes the per-iteration NETWORK bottleneck with s-step slabs;
on a single accelerator the analogous bottleneck is HBM traffic: the
materialized path writes and re-reads the m x (s*b) slab every round
(2*m*s*b words) while only ever consuming U^T alpha, the (sb x sb) cross
block, and a scatter-add.  The slab-free path (EXPERIMENTS.md §Perf)
streams the slab through VMEM tiles and never materializes it, so round
HBM bytes drop by ~2*m*s*b words and m is no longer capped by slab
storage (``perf_model.slab_fits_hbm``).

Acceptance gate: modeled slab-free bytes must be STRICTLY below the
materialized model for every s >= 8 config swept here.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.api import KernelRidge, SolverOptions
from repro.core import KernelConfig
from repro.core.perf_model import (kmv_round_hbm_bytes, slab_fits_hbm,
                                   slab_round_hbm_bytes)
from repro.data.synthetic import regression_dataset

from .common import emit, save_json, timeit

S_VALUES = (8, 32, 256)
B = 4                                    # block size; sb = s*B per round


def modeled_traffic(fast: bool = False):
    """HBM-byte model across s and m, up to m where the slab stops
    fitting (16 GB budget) — the slab-free path keeps going."""
    n = 128
    ms = [4096, 65536, 1 << 20] if fast else [4096, 65536, 1 << 20, 1 << 24]
    rows = []
    for s in S_VALUES:
        sb = s * B
        for m in ms:
            mat = slab_round_hbm_bytes(m, n, sb)
            free = kmv_round_hbm_bytes(m, n, sb)
            fits = slab_fits_hbm(m, sb)
            rows.append({"s": s, "b": B, "m": m, "n": n,
                         "slab_bytes": mat, "slabfree_bytes": free,
                         "ratio": mat / free, "slab_fits_hbm": fits})
            emit(f"fig5/model/s={s}/m={m}", 0.0,
                 f"slab={mat:.3e}B;free={free:.3e}B;x{mat / free:.2f}"
                 + ("" if fits else ";slab-does-not-fit"))
    return rows


def measured_rounds(fast: bool = False):
    """Wall-time per outer round, materialized (slab_free=False — the
    gram_slab parity-oracle path) vs slab-free (GramOperator default),
    both through the ``repro.api`` facade, on host-sized problems."""
    m, n = (1024, 64) if fast else (8192, 128)
    A, y = regression_dataset(jax.random.key(0), m, n)
    kern = KernelConfig("rbf", sigma=0.5)
    rows = []
    for s in S_VALUES:
        rounds = 2
        H = s * rounds

        def fit_alpha(s=s, slab_free=True):
            opts = SolverOptions(method="sstep", s=s, b=B, max_iters=H,
                                 seed=1, slab_free=slab_free)
            return KernelRidge(lam=1.0, kernel=kern,
                               options=opts).fit(A, y).alpha

        t_mat = timeit(lambda s=s: fit_alpha(s, False), iters=1) / rounds
        t_free = timeit(lambda s=s: fit_alpha(s, True), iters=1) / rounds
        rows.append({"s": s, "b": B, "m": m, "n": n,
                     "t_round_slab_s": t_mat, "t_round_slabfree_s": t_free})
        emit(f"fig5/measured/s={s}", t_free * 1e6,
             f"slab={t_mat * 1e6:.0f}us;free={t_free * 1e6:.0f}us")
    return rows


def run(fast: bool = False):
    results = {"modeled": modeled_traffic(fast),
               "measured": measured_rounds(fast)}
    bad = [r for r in results["modeled"]
           if r["slabfree_bytes"] >= r["slab_bytes"]]
    if bad:
        raise AssertionError(
            f"slab-free modeled bytes not strictly lower: {bad}")
    print(f"fig5: slab-free strictly fewer modeled HBM bytes in "
          f"{len(results['modeled'])}/{len(results['modeled'])} configs "
          f"(min ratio x"
          f"{min(r['ratio'] for r in results['modeled']):.2f})")
    save_json("fig5_slabfree.json", results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(fast=ap.parse_args().fast)
