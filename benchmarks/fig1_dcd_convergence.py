"""Paper Figure 1: DCD vs s-step DCD convergence (duality gap) for
K-SVM-L1 and K-SVM-L2 on duke-like and diabetes-like datasets, all three
kernels.

Claim validated: the s-step iterates coincide with classical DCD at every
recorded point (machine-precision agreement) for s up to 256, and the
duality gap decreases toward the 1e-8 tolerance of the paper."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelSVM, SolverOptions
from repro.compat import enable_x64
from repro.core import (KernelConfig, SVMConfig, coordinate_schedule,
                        dcd_ksvm, ksvm_duality_gap, sstep_dcd_ksvm)
from repro.data.synthetic import classification_dataset

from .common import emit, fit_stats, save_json, timeit

DATASETS = {
    # paper Table 2 scales (m, n); synthetic generators (see DESIGN.md §7)
    "duke-like": (44, 7129),
    "diabetes-like": (768, 8),
}
KERNELS = [KernelConfig("linear"), KernelConfig("polynomial", 3, 0.0),
           KernelConfig("rbf", sigma=1.0)]
S_VALUES = (16, 256)


def run(fast: bool = False):
    results = []
    datasets = dict(list(DATASETS.items())[:1]) if fast else DATASETS
    with enable_x64(True):
        for dname, (m, n) in datasets.items():
            A, y = classification_dataset(jax.random.key(0), m, n,
                                          dtype=jnp.float64)
            H = 256 if fast else 2048
            H = min(H, 8 * m)
            sched = coordinate_schedule(jax.random.key(1), H, m)
            a0 = jnp.zeros(m, jnp.float64)
            for kern in KERNELS:
                for loss in ("l1", "l2"):
                    cfg = SVMConfig(C=1.0, loss=loss, kernel=kern)
                    t_ref = timeit(
                        lambda: dcd_ksvm(A, y, a0, sched, cfg)[0])
                    a_ref, _ = dcd_ksvm(A, y, a0, sched, cfg)
                    gap0 = float(ksvm_duality_gap(A, y, a0, cfg))
                    gapH = float(ksvm_duality_gap(A, y, a_ref, cfg))
                    row = {"dataset": dname, "kernel": kern.name,
                           "loss": loss, "H": H,
                           "gap_start": gap0, "gap_end": gapH,
                           "dcd_time_s": t_ref, "sstep": {}}
                    for s in S_VALUES:
                        if H % s:
                            continue
                        t_s = timeit(lambda s=s: sstep_dcd_ksvm(
                            A, y, a0, sched, cfg, s=s)[0])
                        a_s, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=s)
                        dev = float(jnp.max(jnp.abs(a_s - a_ref)))
                        fr = KernelSVM(
                            C=1.0, loss=loss, kernel=kern,
                            options=SolverOptions(method="sstep", s=s,
                                                  max_iters=H, seed=1),
                        ).fit(A, y)
                        row["sstep"][s] = {
                            "max_dev_from_dcd": dev, "time_s": t_s,
                            "speedup_1core": t_ref / t_s,
                            "fit": fit_stats(fr)}
                        emit(f"fig1/{dname}/{kern.name}/{loss}/s={s}",
                             t_s * 1e6,
                             f"dev={dev:.2e};gap={gapH:.2e};"
                             f"fit_wall={fr.wall_time_s*1e6:.0f}us")
                    results.append(row)
    save_json("fig1_dcd_convergence.json", results)
    return results


if __name__ == "__main__":
    run()
