"""Figure 10 (beyond paper): out-of-core streamed KMV vs the resident
slab-free contraction (DESIGN.md §14).

The tentpole claim: chunking X into row blocks and overlapping each
block's transfer with the previous block's contraction (double-buffered
DMA on TPU, ``lax.scan`` elsewhere) makes device memory a CHUNK-sized
budget instead of an m-sized one, at (near-)zero throughput cost in the
compute-bound regime — the streamed pipe pays ``max(t_dma, t_comp)``
per chunk, so when the contraction dominates the copies are free.

Three sections:

* ``modeled``  — ``stream_pipeline_cost`` across (m, chunk_rows):
  overlap speedup vs blocking copies, the streamed/resident slowdown,
  the regime flag, and the ``choose_chunk_rows`` pick under the on-chip
  working-set constraint, plus the ``streaming_required`` gate showing
  the resident representation EXCEEDS a device budget streaming fits.
* ``measured`` — wall time of the per-round contraction (``matvec``)
  and full-pass (``full_matvec``) through a resident
  ``ExactGramOperator`` vs a ``StreamingGramOperator`` at the
  autotuned chunk size, with ≤1e-5 parity asserted.
* ``fit``      — end-to-end facade solves, streamed vs resident, ≤1e-5
  alpha parity asserted.

Acceptance gates (CI smoke runs this suite): streamed results match
resident to 1e-5 ALWAYS; where the model says the measured shape is
compute-bound, measured streamed time must stay within
``GATE_RATIO``x of resident (the overlap-efficiency gate).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelRidge, SolverOptions
from repro.core.kernels import (ExactGramOperator, KernelConfig,
                                StreamingGramOperator)
from repro.core.perf_model import (choose_chunk_rows, stream_pipeline_cost,
                                   stream_working_set_bytes,
                                   streaming_required)
from repro.data.synthetic import regression_dataset

from .common import emit, save_json, timeit

GATE_RATIO = 1.3
PARITY_TOL = 1e-5


def modeled(fast: bool = False):
    n, sb = 256, 64
    ms = [1 << 16, 1 << 20] if fast else [1 << 16, 1 << 20, 1 << 24]
    rows = []
    for m in ms:
        cr, frontier = choose_chunk_rows(m, n, sb, "rbf",
                                         return_frontier=True)
        p = stream_pipeline_cost(m, n, sb, cr, "rbf")
        rows.append({
            "m": m, "n": n, "sb": sb, "chunk_rows": cr,
            "working_set_bytes": stream_working_set_bytes(cr, n, sb),
            "overlap_speedup": p["overlap_speedup"],
            "streamed_over_resident": p["streamed_over_resident"],
            "compute_bound": p["compute_bound"],
            "streaming_required_256MB": streaming_required(
                m, n, sb, device_bytes=256 * 2 ** 20),
            "frontier": frontier,
        })
        emit(f"fig10/model/m={m}", p["time"] * 1e6,
             f"chunk={cr};overlap=x{p['overlap_speedup']:.2f};"
             f"vs_resident=x{p['streamed_over_resident']:.3f};"
             + ("compute-bound" if p["compute_bound"] else "dma-bound"))
    # the out-of-core gate the acceptance test mirrors: the largest
    # swept problem cannot sit resident in a 256 MB device but its
    # streamed working set fits on-chip
    big = rows[-1]
    assert big["streaming_required_256MB"], big
    assert big["working_set_bytes"] < 256 * 2 ** 20, big
    return rows


def measured(fast: bool = False):
    # big enough that the gate's ratio is not timing noise: the matvec
    # is ~100 MFLOP even in fast mode
    m, n, sb = (4096, 128, 64) if fast else (16384, 128, 64)
    cfg = KernelConfig("rbf", sigma=0.5)
    A = jax.random.normal(jax.random.key(0), (m, n), jnp.float32)
    # autotuned pick over chunk sizes coarse enough for the host path:
    # the model's warm-up term prefers tiny chunks (free under real DMA
    # overlap), but the CPU scan emulation pays per-chunk dispatch, so
    # the measured gate runs at the >= 512-row end of the frontier
    cr = choose_chunk_rows(m, n, sb, cfg.name,
                           candidates=(512, 1024, 2048, 4096))
    exact = ExactGramOperator(A, cfg)
    stream = StreamingGramOperator.from_dense(A, cfg, chunk_rows=cr)
    idx = jnp.arange(sb)
    v = jax.random.normal(jax.random.key(1), (m,))
    model = stream_pipeline_cost(m, n, sb, cr, cfg.name)

    # parity first: the gate below is meaningless on wrong numbers
    err_mv = float(jnp.max(jnp.abs(stream.matvec(idx, v)
                                   - exact.matvec(idx, v))))
    err_full = float(jnp.max(jnp.abs(stream.full_matvec(v)
                                     - exact.full_matvec(v))))
    scale = float(jnp.max(jnp.abs(exact.full_matvec(v))))
    assert err_mv <= PARITY_TOL * max(1.0, scale), (err_mv, scale)
    assert err_full <= PARITY_TOL * max(1.0, scale), (err_full, scale)

    mv_res = jax.jit(lambda op, v: op.matvec(idx, v))
    full_res = jax.jit(lambda op, v: op.full_matvec(v))
    rows = []
    for name, fn in [("matvec", mv_res), ("full_matvec", full_res)]:
        # host-scheduler noise hardening (fig9's retry discipline): a
        # preempted measurement window inflates either side's median,
        # so the gate judges the BEST of up to 4 windows — a genuinely
        # broken overlap fails all of them
        attempts = []
        for _ in range(4):
            t_res = timeit(fn, exact, v, warmup=2, iters=5)
            t_str = timeit(fn, stream, v, warmup=2, iters=5)
            attempts.append((t_str / t_res, t_res, t_str))
            if attempts[-1][0] <= GATE_RATIO:
                break
        ratio, t_res, t_str = min(attempts)
        rows.append({"contraction": name, "m": m, "n": n, "sb": sb,
                     "chunk_rows": cr, "t_resident_s": t_res,
                     "t_streamed_s": t_str, "ratio": ratio,
                     "windows": len(attempts),
                     "model_compute_bound": model["compute_bound"],
                     "parity_err": err_mv if name == "matvec"
                     else err_full})
        emit(f"fig10/measured/{name}", t_str * 1e6,
             f"resident={t_res * 1e6:.0f}us;x{ratio:.2f};chunk={cr}")
        if model["compute_bound"]:
            assert ratio <= GATE_RATIO, (
                f"{name}: streamed {ratio:.2f}x resident exceeds the "
                f"{GATE_RATIO}x overlap-efficiency gate in the "
                f"compute-bound regime (best of {len(attempts)} "
                f"measurement windows)")
    return rows


def fit(fast: bool = False):
    m, n = (512, 32) if fast else (2048, 64)
    A, y = regression_dataset(jax.random.key(2), m=m, n=n)
    kw = dict(method="sstep", s=8, b=4, max_iters=32, record=False)
    cr = choose_chunk_rows(m, n, 32, "rbf")
    t_res = timeit(lambda: KernelRidge(
        lam=1.0, kernel="rbf",
        options=SolverOptions(**kw)).fit(A, y).alpha, iters=1)
    t_str = timeit(lambda: KernelRidge(
        lam=1.0, kernel="rbf",
        options=SolverOptions(stream=cr, **kw)).fit(A, y).alpha, iters=1)
    a_res = KernelRidge(lam=1.0, kernel="rbf",
                        options=SolverOptions(**kw)).fit(A, y).alpha
    a_str = KernelRidge(lam=1.0, kernel="rbf",
                        options=SolverOptions(stream=cr, **kw)).fit(
                            A, y).alpha
    err = float(jnp.max(jnp.abs(a_res - a_str)))
    assert err <= PARITY_TOL, err
    emit("fig10/fit", t_str * 1e6,
         f"resident={t_res * 1e6:.0f}us;x{t_str / t_res:.2f};"
         f"parity={err:.1e};chunk={cr}")
    return [{"m": m, "n": n, "chunk_rows": cr, "t_resident_s": t_res,
             "t_streamed_s": t_str, "alpha_parity": err}]


def run(fast: bool = False):
    results = {"modeled": modeled(fast), "measured": measured(fast),
               "fit": fit(fast)}
    worst = max(r["ratio"] for r in results["measured"])
    print(f"fig10: streamed/resident worst measured ratio x{worst:.2f} "
          f"(gate x{GATE_RATIO} where compute-bound), parity <= "
          f"{PARITY_TOL}")
    save_json("fig10_streaming.json", results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(fast=ap.parse_args().fast)
