"""Paper Figure 2: BDCD vs s-step BDCD convergence (relative solution
error vs the closed-form K-RR solution) on abalone-like (b=128) and
bodyfat-like (b=64) datasets, s in {16, 256}.

Claim validated: s-step BDCD attains the same solution as BDCD at every
round and is numerically stable even for b >> 1 and s = 256."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import KernelRidge, SolverOptions
from repro.compat import enable_x64
from repro.core import (KernelConfig, KRRConfig, bdcd_krr, block_schedule,
                        krr_closed_form, relative_solution_error,
                        sstep_bdcd_krr)
from repro.data.synthetic import regression_dataset

from .common import emit, fit_stats, save_json, timeit

KERNELS = [KernelConfig("linear"), KernelConfig("polynomial", 3, 0.0),
           KernelConfig("rbf", sigma=1.0)]


def run(fast: bool = False):
    # paper Table 2 scales; abalone shrunk in fast mode.  NOTE: the
    # paper's (b=128, s=256) MATLAB setting implies (s*b)^2 = 32768^2
    # correction tensors (~17 GB fp64) — beyond this container, so the
    # large-s run uses b=32 (s*b = 8192) and the large-b run uses s<=16;
    # both stability claims (s>>1, b>>1) are still exercised.
    datasets = {
        "abalone-like-b128": ((512, 8) if fast else (4177, 8), 128, (16,)),
        "abalone-like-b32": ((512, 8) if fast else (4177, 8), 32,
                             (16, 256)),
        "bodyfat-like": ((252, 14), 64, (16, 256)),
    }
    results = []
    with enable_x64(True):
        for dname, ((m, n), b, s_values) in datasets.items():
            A, y = regression_dataset(jax.random.key(2), m, n,
                                      dtype=jnp.float64)
            cfg0 = KRRConfig(lam=1.0)
            H = 256 if fast else 512
            sched = block_schedule(jax.random.key(3), H, m, b)
            a0 = jnp.zeros(m, jnp.float64)
            for kern in KERNELS:
                cfg = KRRConfig(lam=1.0, kernel=kern)
                astar = krr_closed_form(A, y, cfg)
                t_ref = timeit(lambda: bdcd_krr(A, y, a0, sched, cfg)[0],
                               iters=1)
                a_ref, _ = bdcd_krr(A, y, a0, sched, cfg)
                err_ref = float(relative_solution_error(a_ref, astar))
                row = {"dataset": dname, "kernel": kern.name, "b": b,
                       "H": H, "bdcd_relerr": err_ref,
                       "bdcd_time_s": t_ref, "sstep": {}}
                for s in s_values:
                    if H % s:
                        continue
                    t_s = timeit(lambda s=s: sstep_bdcd_krr(
                        A, y, a0, sched, cfg, s=s)[0], iters=1)
                    a_s, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=s)
                    err_s = float(relative_solution_error(a_s, astar))
                    dev = float(jnp.max(jnp.abs(a_s - a_ref)))
                    fr = KernelRidge(
                        lam=1.0, kernel=kern,
                        options=SolverOptions(method="sstep", s=s, b=b,
                                              max_iters=H, seed=3),
                    ).fit(A, y)
                    row["sstep"][s] = {"relerr": err_s,
                                       "max_dev_from_bdcd": dev,
                                       "time_s": t_s,
                                       "fit": fit_stats(fr)}
                    emit(f"fig2/{dname}/{kern.name}/b={b}/s={s}",
                         t_s * 1e6, f"relerr={err_s:.2e};dev={dev:.2e};"
                         f"fit_wall={fr.wall_time_s*1e6:.0f}us")
                results.append(row)
    save_json("fig2_bdcd_convergence.json", results)
    return results


if __name__ == "__main__":
    run()
