"""Figure 8 (beyond paper): the price of a guarded solve (DESIGN.md §12,
EXPERIMENTS.md §Resilience).

The resilience layer claims to be near-free: the residual recurrence
reuses the m x sb slab each round already evaluates, the health
predicate is O(m) elementwise, and the only real cost — the periodic
exact recompute ``f = K @ alpha`` — is amortized by the autotuned
``recompute_every`` cadence under ``perf_model.GUARD_OVERHEAD_BUDGET``.
This benchmark measures all three acceptance gates:

  * OVERHEAD — wall-clock of a guarded fit (autotuned cadence, sized so
    drift correction actually fires) vs the identical unguarded fit,
    both jit-warm; gate: measured overhead <= 10%.
  * RECOVERY — a NaN injected mid-solve; the guard discards the
    poisoned round and the ladder falls back; gate: final alpha within
    1e-5 of the clean UNGUARDED run.
  * RESUME — the fit killed at H/2 (after a durable checkpoint),
    resumed with ``resume_from=``; gate: final alpha within 1e-5 of the
    uninterrupted run.
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelRidge, SolverOptions
from repro.core import KernelConfig
from repro.core.perf_model import guard_overhead
from repro.data.synthetic import regression_dataset
from repro.resilience import FaultPlan, SimulatedKill, inject

from .common import emit, save_json

OVERHEAD_GATE = 0.10
RECOVERY_TOL = 1e-5


def _fit_wall(mk, A, y, iters=3, **fit_kw):
    """Min-of-N wall-clock of a full fit (jit-warm after the first
    call; min is the noise-robust statistic for same-work timing)."""
    mk().fit(A, y, **fit_kw)                    # warm every jit cache
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = mk().fit(A, y, **fit_kw)
        jax.block_until_ready(r.alpha)
        ts.append(time.perf_counter() - t0)
    return min(ts), r


def resilience(fast: bool = False):
    m, n = (768, 32) if fast else (2048, 64)
    H = 4096 if fast else 8192
    s, b = 8, 8
    kern = KernelConfig("linear")
    A, y = regression_dataset(jax.random.key(0), m, n)
    base = dict(method="sstep", s=s, b=b, max_iters=H, seed=3,
                slab_free=True)

    # ---- gate 1: guarded overhead at the autotuned cadence -----------
    plain_opts = SolverOptions(**base)
    guard_opts = SolverOptions(**base, guard=True)   # recompute="auto"
    t_plain, _ = _fit_wall(
        lambda: KernelRidge(lam=0.5, kernel=kern, options=plain_opts),
        A, y)
    t_guard, r_guard = _fit_wall(
        lambda: KernelRidge(lam=0.5, kernel=kern, options=guard_opts),
        A, y)
    rec = r_guard.options.recompute_every
    rounds = r_guard.rounds_run
    assert rounds > rec, \
        (f"sizing bug: {rounds} rounds at cadence {rec} — drift "
         f"correction never fired, the overhead measurement is vacuous")
    assert r_guard.health.corrections > 0
    overhead = t_guard / t_plain - 1.0
    modeled = guard_overhead(m, n, kern.name, b=b, s=s,
                             recompute_every=rec)
    emit("fig8/overhead", t_guard * 1e6,
         f"plain={t_plain * 1e6:.1f}us;recompute_every={rec};"
         f"measured={overhead:.3f};modeled={modeled:.3f}")

    # ---- gate 2: NaN recovery matches the clean unguarded run --------
    clean = KernelRidge(lam=0.5, kernel=kern, options=plain_opts)
    r_clean = clean.fit(A, y)
    with inject(FaultPlan(nan_at_iter=H // 3)) as fault:
        r_rec = KernelRidge(lam=0.5, kernel=kern,
                            options=guard_opts).fit(A, y)
    assert fault.carry_fired
    rec_err = float(jnp.max(jnp.abs(r_rec.alpha - r_clean.alpha)))
    emit("fig8/recovery", rec_err,
         f"fallbacks={[e.action for e in r_rec.health.fallbacks]}")

    # ---- gate 3: kill at H/2, resume from the durable checkpoint -----
    with tempfile.TemporaryDirectory() as ckpt:
        ck_opts = SolverOptions(**base, guard=True, checkpoint_every=64,
                                checkpoint_dir=ckpt)
        kr = KernelRidge(lam=0.5, kernel=kern, options=ck_opts)
        try:
            with inject(FaultPlan(kill_at_iter=H // 2)):
                kr.fit(A, y)
            raise AssertionError("simulated kill never fired")
        except SimulatedKill:
            pass
        r_res = kr.fit(A, y, resume_from=ckpt)
    full = KernelRidge(lam=0.5, kernel=kern,
                       options=SolverOptions(**base, guard=True)).fit(A, y)
    res_err = float(jnp.max(jnp.abs(r_res.alpha - full.alpha)))
    emit("fig8/resume", res_err,
         f"checkpoints={r_res.health.checkpoints};"
         f"resumed={r_res.health.resumed_from is not None}")

    save_json("fig8_resilience.json", {
        "m": m, "n": n, "H": H, "s": s, "b": b,
        "recompute_every": rec, "rounds": int(rounds),
        "corrections": int(r_guard.health.corrections),
        "max_drift": r_guard.health.max_drift,
        "t_plain_s": t_plain, "t_guarded_s": t_guard,
        "overhead_measured": overhead, "overhead_modeled": modeled,
        "recovery_max_abs_err": rec_err,
        "recovery_fallbacks": [e.action for e in r_rec.health.fallbacks],
        "resume_max_abs_err": res_err,
        "gates": {"overhead": OVERHEAD_GATE, "tol": RECOVERY_TOL}})

    assert overhead <= OVERHEAD_GATE, \
        (f"guarded overhead {overhead:.1%} exceeds the "
         f"{OVERHEAD_GATE:.0%} gate (modeled {modeled:.1%} at "
         f"recompute_every={rec})")
    assert rec_err <= RECOVERY_TOL, \
        f"NaN recovery error {rec_err} above {RECOVERY_TOL}"
    assert res_err <= RECOVERY_TOL, \
        f"resume-after-kill error {res_err} above {RECOVERY_TOL}"


def run(fast: bool = False):
    resilience(fast=fast)


if __name__ == "__main__":
    ap = __import__("argparse").ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(fast=ap.parse_args().fast)
