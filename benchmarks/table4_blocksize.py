"""Paper Table 4: speedup of s-step BDCD over BDCD for K-RR as the block
size b varies (1, 2, 4) — measured on-host (computation side) and modeled
at the paper's 512-core scale (communication side).

Expected (and observed in the paper): the s-step advantage SHRINKS as b
grows, because bandwidth (s*b*m words/round) starts to dominate latency."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (KernelConfig, KRRConfig, bdcd_krr, block_schedule,
                        sstep_bdcd_krr)
from repro.core.perf_model import Machine, Problem, best_s, bdcd_cost
from repro.data.synthetic import regression_dataset

from .common import emit, save_json, timeit

KERNELS = [KernelConfig("linear"), KernelConfig("polynomial", 3, 0.0),
           KernelConfig("rbf", sigma=1.0)]


def run(fast: bool = False):
    m, n = (256, 512) if fast else (512, 2000)   # colon-cancer-like scale
    A, y = regression_dataset(jax.random.key(4), m, n)
    a0 = jnp.zeros(m)
    mach = Machine()
    results = []
    for kern in KERNELS:
        cfg = KRRConfig(lam=1.0, kernel=kern)
        for b in (1, 2, 4):
            H = 128
            sched = block_schedule(jax.random.key(5), H, m, b)
            t_ref = timeit(lambda: bdcd_krr(A, y, a0, sched, cfg)[0],
                           iters=3)
            best_meas = 0.0
            for s in (8, 32):
                t_s = timeit(lambda s=s: sstep_bdcd_krr(
                    A, y, a0, sched, cfg, s=s)[0], iters=3)
                best_meas = max(best_meas, t_ref / t_s)
            prob = Problem(m=19996, n=1355191, f=0.0003, b=b, H=4096,
                           kernel=kern.name)
            t1 = bdcd_cost(prob, mach, 512)
            s_star, ts = best_s(prob, mach, 512)
            results.append({
                "kernel": kern.name, "b": b,
                "measured_1core_speedup": best_meas,
                "modeled_512core_speedup": t1["time"] / ts,
                "modeled_best_s": s_star,
            })
            emit(f"table4/{kern.name}/b={b}", 0.0,
                 f"measured={best_meas:.2f}x;"
                 f"modeled512={t1['time'] / ts:.2f}x;s*={s_star}")
    save_json("table4_blocksize.json", results)
    return results


if __name__ == "__main__":
    run()
