"""Paper Figure 3/5/6: strong scaling of DCD/BDCD vs the s-step variants.

Two parts:
 1. MEASURED single-node computation effect: the s-step schedule converts
    BLAS-1/2 per-iteration work into one BLAS-3 slab per round.  We
    measure wall-clock on this host (the paper's Fig. 4 'kernel
    computation decreases with s' effect).
 2. MODELED distributed scaling via the Hockney cost model of Theorems
    1-2, calibrated with the measured gamma — predicted strong-scaling
    speedup curves for P up to 4096, reproducing the paper's observation
    of ~3.5-9.8x speedups in the latency-bound regime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (KernelConfig, SVMConfig, coordinate_schedule,
                        dcd_ksvm, sstep_dcd_ksvm)
from repro.core.perf_model import Machine, Problem, best_s, bdcd_cost, \
    sstep_bdcd_cost
from repro.data.synthetic import classification_dataset

from .common import emit, save_json, timeit

DATASETS = {
    "colon-like": dict(m=62, n=2000, f=1.0),
    "duke-like": dict(m=44, n=7129, f=1.0),
    "news20-like": dict(m=19996, n=1355191, f=0.0003),
    "synthetic-sparse": dict(m=2000, n=800000, f=0.01),
}


def measured_compute_effect(fast=False):
    """Wall-clock DCD vs s-step DCD on one host (computation only)."""
    out = []
    m, n = (44, 1024) if fast else (44, 7129)
    A, y = classification_dataset(jax.random.key(0), m, n)
    cfg = SVMConfig(C=1.0, loss="l1", kernel=KernelConfig("rbf"))
    H = 512
    sched = coordinate_schedule(jax.random.key(1), H, m)
    a0 = jnp.zeros(m)
    t_dcd = timeit(lambda: dcd_ksvm(A, y, a0, sched, cfg)[0])
    row = {"dataset": "duke-like", "H": H, "dcd_s": t_dcd, "sstep": {}}
    for s in (4, 16, 64, 256):
        t_s = timeit(lambda s=s: sstep_dcd_ksvm(A, y, a0, sched, cfg,
                                                s=s)[0])
        row["sstep"][s] = {"time_s": t_s, "speedup": t_dcd / t_s}
        emit(f"fig3/measured/duke-like/s={s}", t_s * 1e6,
             f"speedup={t_dcd / t_s:.2f}x")
    out.append(row)
    return out


def modeled_strong_scaling():
    """Hockney-model speedup curves (Theorems 1-2)."""
    mach = Machine()
    out = []
    for dname, d in DATASETS.items():
        for b in (1, 4):
            prob = Problem(m=d["m"], n=d["n"], f=d["f"], b=b, H=4096,
                           kernel="rbf")
            rows = []
            # P capped at the paper's 512 cores for the small datasets;
            # news20 scales to 4096 in the paper (Fig. 5/6).
            plist = ((4, 16, 64, 128, 512) if d["m"] < 10000
                     else (128, 512, 2048, 4096))
            for P in plist:
                t1 = bdcd_cost(prob, mach, P)
                s, ts = best_s(prob, mach, P)
                rows.append({"P": P, "classical_s": t1["time"],
                             "t_lat_frac": t1["t_lat"] / t1["time"],
                             "best_s": s, "sstep_s": ts,
                             "speedup": t1["time"] / ts})
            peak = max(r["speedup"] for r in rows)
            out.append({"dataset": dname, "b": b, "scaling": rows,
                        "peak_speedup": peak,
                        "note": "Hockney model = leading-order upper bound"
                                " (idealized allreduce); paper measures"
                                " 2-9.8x in this regime"})
            emit(f"fig3/model/{dname}/b={b}", 0.0,
                 f"peak_speedup={peak:.1f}x@bestP")
    return out


def run(fast: bool = False):
    results = {"measured": measured_compute_effect(fast),
               "modeled": modeled_strong_scaling()}
    save_json("fig3_scaling.json", results)
    return results


if __name__ == "__main__":
    run()
