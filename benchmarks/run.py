"""Benchmark harness: one entry per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see common.emit).

  fig1   DCD vs s-step DCD convergence (duality gap)        [paper Fig 1]
         (each s-step record carries a ``fit`` block: repro.api FitResult
          wall-clock + Hockney-modeled comm words/msgs/time)
  fig2   BDCD vs s-step BDCD convergence (rel. error)       [paper Fig 2]
         (``fit`` blocks as in fig1)
  fig3   strong scaling, measured + Hockney-modeled         [paper Figs 3/5/6]
  fig4   running-time breakdown                             [paper Figs 4/7/8]
  table4 block-size ablation                                [paper Table 4]
  fig5   slab-free vs materialized round (HBM bytes/time)   [EXPERIMENTS §Perf]
  fig6   predict throughput: exact vs low-rank representation,
         batched slab-free vs legacy dense                  [DESIGN §9]
  fig7   sweep throughput: vmapped fleet vs sequential fits,
         warm-started path iteration counts                 [DESIGN §10]
  fig8   guarded-solve price: overhead at the autotuned
         recompute cadence, NaN recovery, resume-after-kill [DESIGN §12]
  fig9   serving SLO: continuous-batching p50/p99 + throughput
         vs the perf-model prediction, overload shedding,
         mid-stream refit correctness                       [DESIGN §13]
  fig10  out-of-core streamed KMV vs resident: modeled overlap
         pipeline + measured parity/ratio gates              [DESIGN §14]
  fig11  telemetry price + product: enabled-vs-disabled overhead
         gates (guarded solve, serving drive), audit report,
         Perfetto trace + Prometheus exposition checks       [DESIGN §15]
  roofline  assigned-arch roofline table from the dry-run   [EXPERIMENTS §Roofline]

``--fast`` shrinks datasets/iterations (used by CI / test_system).
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig1,table4")
    args = ap.parse_args()

    from benchmarks import (fig1_dcd_convergence, fig2_bdcd_convergence,
                            fig3_scaling, fig4_breakdown, fig5_slabfree,
                            fig6_predict, fig7_sweep, fig8_resilience,
                            fig9_serve, fig10_streaming, fig11_obs,
                            roofline, table4_blocksize)

    def paper_dist_subprocess(fast=False):
        # needs its own process: it forces a 16-device host platform
        import os
        import pathlib
        import subprocess
        root = pathlib.Path(__file__).resolve().parents[1]
        env = dict(os.environ)
        env["PYTHONPATH"] = f"{root / 'src'}:{root}"
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.paper_dist"]
            + (["--fast"] if fast else []),
            env=env, cwd=str(root), capture_output=True, text=True,
            timeout=1800)
        print(out.stdout, end="")
        if out.returncode != 0:
            raise RuntimeError(out.stderr[-2000:])

    suites = {
        "fig1": fig1_dcd_convergence.run,
        "fig2": fig2_bdcd_convergence.run,
        "fig3": fig3_scaling.run,
        "fig4": fig4_breakdown.run,
        "table4": table4_blocksize.run,
        "fig5": fig5_slabfree.run,
        "fig6": fig6_predict.run,
        "fig7": fig7_sweep.run,
        "fig8": fig8_resilience.run,
        "fig9": fig9_serve.run,
        "fig10": fig10_streaming.run,
        "fig11": fig11_obs.run,
        "paper_dist": paper_dist_subprocess,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    failed = []
    for name, fn in suites.items():
        if name not in only:
            continue
        print(f"==== {name} ====", flush=True)
        try:
            fn(fast=args.fast)
        except Exception as e:  # pragma: no cover
            failed.append(name)
            print(f"{name},FAILED,{type(e).__name__}: {e}", flush=True)
    if failed:
        sys.exit(f"benchmark suites failed: {failed}")


if __name__ == '__main__':
    main()
