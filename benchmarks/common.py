"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall-time of a jitted callable (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def env_fingerprint() -> dict:
    """Where a benchmark number came from: a perf trajectory point is
    only comparable to points from the same software/hardware coordinates,
    so every BENCH_*.json carries them.  Exception-safe: a missing git
    binary or detached worktree degrades to "unknown", never a crash."""
    import jaxlib

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip()
    except Exception:
        sha = "unknown"
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": dev.platform,
        "device_kind": dev.device_kind,
        "python": platform.python_version(),
        "git_sha": sha,
    }


def save_json(name: str, obj):
    """Write a benchmark payload, stamped with ``env_fingerprint()``:
    dict payloads gain a leading ``env`` key, list payloads wrap as
    ``{"env": ..., "records": [...]}`` (consumers that iterate rows read
    ``records``)."""
    fp = env_fingerprint()
    if isinstance(obj, dict):
        obj = {"env": fp, **obj}
    elif isinstance(obj, list):
        obj = {"env": fp, "records": obj}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    payload = json.dumps(obj, indent=1, default=str)
    with open(path, "w") as f:
        f.write(payload)
    # Committed perf-trajectory copy at the repo root (BENCH_<name>.json).
    # Baselines are always generated in --fast mode (CI's smoke gate is
    # the reference producer); the gate keeps incidental runs (pytest's
    # test_system, local experiments) from dirtying the committed files.
    # Refresh deliberately with REPRO_BENCH_BASELINE=1 and --fast.
    if os.environ.get("REPRO_BENCH_BASELINE"):
        with open(os.path.join(REPO_ROOT, f"BENCH_{name}"), "w") as f:
            f.write(payload)
    return path


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def fit_stats(result):
    """repro.api FitResult bookkeeping (wall-clock + Hockney comm model)
    surfaced into the fig1/fig2 JSON records, so the perf trajectory
    captures solver-loop overhead too — not just kernel bytes."""
    return {"wall_time_s": result.wall_time_s,
            "rounds_run": result.rounds_run,
            "iters_run": result.iters_run,
            "modeled_comm_words": result.comm["words"],
            "modeled_comm_msgs": result.comm["msgs"],
            "modeled_comm_time_s": result.comm["time"]}
