"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall-time of a jitted callable (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def save_json(name: str, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=str)
    return path


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
