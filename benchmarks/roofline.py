"""Roofline table assembly: reads the dry-run JSON artifacts and renders
the EXPERIMENTS.md §Roofline table (all three terms, bottleneck, useful
flop ratio, one-line remedy per cell)."""
from __future__ import annotations

import json
import os

from .common import RESULTS_DIR, emit

REMEDY = {
    "t_compute": "raise MXU utilization: larger per-device tiles / fewer "
                 "recompute passes (remat policy)",
    "t_memory": "cut HBM traffic: fused/blocked attention (avoid O(S^2) "
                "logit materialization), bf16 master-less optimizer reads",
    "t_collective": "defer/batch collectives (paper s-step schedule), "
                    "overlap psum with compute, shard logits reduction",
}


def load(name):
    path = os.path.join(RESULTS_DIR, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def render(results, title):
    lines = [f"### {title}", "",
             "| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | "
             "bottleneck | MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in results:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"skipped: {r['reason'][:40]}... | - | - |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED "
                         f"{r.get('error', '')[:60]} | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3g} | "
            f"{r['t_memory']:.3g} | {r['t_collective']:.3g} | "
            f"{r['bottleneck'][2:]} | {r['useful_flop_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def run(fast: bool = False):
    for name, title in (
            ("dryrun_single.json", "Single pod (16x16) — faithful baseline"),
            ("dryrun_multi.json", "Multi-pod (2x16x16) — faithful baseline"),
            ("dryrun_single_optimized.json",
             "Single pod (16x16) — optimized (SPerf defaults)"),
            ("dryrun_multi_optimized.json",
             "Multi-pod (2x16x16) — optimized (SPerf defaults)")):
        results = load(name)
        if results is None:
            emit(f"roofline/{name}", 0.0, "missing (dry-run not yet run)")
            continue
        print(render(results, title))
        ok = [r for r in results if r["status"] == "ok"]
        emit(f"roofline/{name}", 0.0,
             f"{len(ok)} ok cells; "
             f"worst_frac={min((r['roofline_fraction'] for r in ok), default=0):.3f}")
    return True


if __name__ == "__main__":
    run()
