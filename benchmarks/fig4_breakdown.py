"""Paper Figure 4/7: running-time breakdown (kernel computation, allreduce,
gradient correction, memory reset) of DCD/s-step DCD, from the calibrated
Hockney model at the paper's P values, plus the measured on-host split
between slab computation and inner-loop correction."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import KernelConfig, SVMConfig, coordinate_schedule, \
    sstep_dcd_ksvm
from repro.core.kernels import gram_slab
from repro.core.perf_model import Machine, Problem
from repro.data.synthetic import classification_dataset

from .common import emit, save_json, timeit


def modeled_breakdown(P=128, H=4096):
    mach = Machine()
    out = []
    for dname, (m, n, f) in {
        "colon-like": (62, 2000, 1.0),
        "duke-like": (44, 7129, 1.0),
        "news20-like": (19996, 1355191, 0.0003),
    }.items():
        for s in (1, 8, 32, 256):
            rounds = H / s
            kernel_flops = rounds * (s * f * m * n / P + mach.mu * s * m)
            correction_flops = rounds * math.comb(s, 2)
            t_kernel = mach.gamma * kernel_flops
            t_corr = mach.gamma * correction_flops
            t_band = mach.beta * H * m          # total words identical
            t_lat = mach.phi * rounds * math.log2(P)
            out.append({"dataset": dname, "P": P, "s": s,
                        "t_kernel": t_kernel, "t_correction": t_corr,
                        "t_allreduce_band": t_band, "t_allreduce_lat": t_lat,
                        "total": t_kernel + t_corr + t_band + t_lat})
            emit(f"fig4/model/{dname}/s={s}",
                 (t_kernel + t_corr + t_band + t_lat) * 1e6,
                 f"lat_frac={t_lat / (t_kernel + t_corr + t_band + t_lat):.2f}")
    return out


def measured_slab_vs_inner(fast=False):
    """On-host: time the slab (gram) vs the full s-step round — the
    difference is the inner correction loop (paper's 'gradient correction
    overhead grows with s')."""
    m, n = (44, 1024) if fast else (44, 7129)
    A, y = classification_dataset(jax.random.key(0), m, n)
    cfg = SVMConfig(C=1.0, loss="l2", kernel=KernelConfig("rbf"))
    out = []
    for s in (16, 64, 256):
        H = s * 4
        sched = coordinate_schedule(jax.random.key(1), H, m)
        a0 = jnp.zeros(m)
        Atil = y[:, None] * A
        idx = sched[:s]
        t_slab = timeit(lambda: gram_slab(Atil, Atil[idx], cfg.kernel))
        t_round = timeit(lambda s=s: sstep_dcd_ksvm(A, y, a0, sched, cfg,
                                                    s=s)[0]) / (H / s)
        out.append({"s": s, "t_slab_s": t_slab, "t_round_s": t_round,
                    "inner_frac": max(0.0, 1 - t_slab / t_round)})
        emit(f"fig4/measured/slab_vs_round/s={s}", t_round * 1e6,
             f"slab={t_slab * 1e6:.0f}us")
    return out


def run(fast: bool = False):
    results = {"modeled": modeled_breakdown(),
               "measured": measured_slab_vs_inner(fast)}
    save_json("fig4_breakdown.json", results)
    return results


if __name__ == "__main__":
    run()
