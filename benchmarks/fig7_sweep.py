"""Figure 7 (beyond paper): sweep throughput — vmapped solver fleets vs
sequential fits (DESIGN.md §10, EXPERIMENTS.md §Sweeps).

Hyperparameter search solves the SAME problem many times with different
regularizers; the fleet solver (``repro.tune.solve_fleet``) shares one
``GramOperator`` across the whole grid, so the per-round slab GEMM and
its nonlinear epilogue — the paper's dominant terms — are computed once
for F members instead of F times.  This sweep measures, for
F in {1, 4, 16}:

  * wall-clock of ONE fleet solve over an F-point lambda grid,
  * wall-clock of F sequential ``KernelRidge.fit`` calls (same options,
    same schedule — the jit cache is warm after the first member),
  * the modeled fleet cost (``perf_model.fleet_fit_cost``) and its
    modeled speedup, so the measured ratio can be checked against the
    Hockney-model split of shared vs per-member work,

plus a warm-started ``reg_path`` rung-iteration count vs cold solves at
the same tolerance (the path's win is fewer ITERATIONS, not faster
rounds).

Acceptance gates: the F=16 fleet must run >= 3x faster than 16
sequential fits AND every member must match its sequential solution to
<= 1e-5.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelRidge, SolverOptions
from repro.core import KernelConfig
from repro.core.perf_model import fleet_fit_cost
from repro.data.synthetic import regression_dataset
from repro.tune import reg_path, solve_fleet

from .common import emit, save_json

F_VALUES = (1, 4, 16)
SPEEDUP_GATE = 3.0                 # acceptance: F=16 fleet vs sequential
MATCH_TOL = 1e-5


def _wall(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0, out


def sweep(fast: bool = False):
    m, n = (768, 32) if fast else (4096, 64)
    H = 128 if fast else 512
    s, b = 8, 4
    kern = KernelConfig("rbf", sigma=1.0)
    opts = SolverOptions(method="sstep", s=s, b=b, max_iters=H, seed=3)
    A, y = regression_dataset(jax.random.key(0), m, n)
    grid_full = np.logspace(-1, 2, max(F_VALUES))

    rows = []
    for F in F_VALUES:
        lams = grid_full[:F]
        # warm EVERY jit cache first: the fleet trace, and each
        # sequential fit's per-lambda compile (cfg is a static jit arg,
        # so every grid point compiles its own executable — a real cost
        # the fleet's traced-lambda batching avoids, but the gate below
        # compares pure solve time, compile excluded on both sides)
        solve_fleet(A, y, lams=lams, kernel=kern, options=opts)
        for lam in lams:
            KernelRidge(lam=float(lam), kernel=kern, options=opts).fit(A, y)

        t_fleet, fr = _wall(
            lambda: solve_fleet(A, y, lams=lams, kernel=kern,
                                options=opts).alpha)

        seq = []
        t0 = time.perf_counter()
        for lam in lams:
            r = KernelRidge(lam=float(lam), kernel=kern,
                            options=opts).fit(A, y)
            seq.append(r.alpha)
        jax.block_until_ready(seq[-1])
        t_seq = time.perf_counter() - t0

        max_diff = float(jnp.max(jnp.abs(fr - jnp.stack(seq))))
        model = fleet_fit_cost(m, n, kern.name, F, b=b, s=s, iters=H)
        speedup = t_seq / t_fleet
        rows.append({"F": F, "m": m, "n": n, "s": s, "b": b, "H": H,
                     "t_fleet_s": t_fleet, "t_sequential_s": t_seq,
                     "speedup": speedup, "max_abs_diff": max_diff,
                     "modeled_time_s": model["time"],
                     "modeled_sequential_s": model["sequential_time"],
                     "modeled_speedup": model["modeled_speedup"]})
        emit(f"fig7/fleet/F{F}", t_fleet * 1e6,
             f"speedup={speedup:.1f}x;model={model['modeled_speedup']:.1f}x;"
             f"maxdiff={max_diff:.1e}")
        assert max_diff <= MATCH_TOL, \
            f"fleet diverged from sequential fits: {max_diff} (F={F})"

    gate = rows[-1]
    assert gate["F"] == max(F_VALUES)
    assert gate["speedup"] >= SPEEDUP_GATE, \
        (f"F={gate['F']} fleet speedup {gate['speedup']:.2f}x below the "
         f"{SPEEDUP_GATE}x acceptance gate")

    # warm-started path vs cold solves at the same tolerance (own
    # problem size: iterations-to-tol scales with m, and the point here
    # is ITERATION counts, not round throughput)
    m_p = 256 if fast else 1024
    A_p, y_p = regression_dataset(jax.random.key(4), m_p, n)
    tol_opts = SolverOptions(method="sstep", s=s, b=b, seed=3,
                             max_iters=16 * m_p, tol=2e-2, check_every=8)
    lams = grid_full[:4]
    path = reg_path(A_p, y_p, lams=lams, kernel=kern, options=tol_opts)
    cold = sum(KernelRidge(lam=float(v), kernel=kern,
                           options=tol_opts).fit(A_p, y_p).iters_run
               for v in path.values)
    rows.append({"path_values": list(map(float, path.values)),
                 "warm_total_iters": path.total_iters,
                 "cold_total_iters": int(cold),
                 "warm_iter_fraction": path.total_iters / max(cold, 1)})
    emit("fig7/path/warm_vs_cold", 0.0,
         f"warm={path.total_iters}it;cold={cold}it")
    return rows


def run(fast: bool = False):
    rows = sweep(fast)
    gate = [r for r in rows if r.get("F") == max(F_VALUES)][0]
    print(f"fig7: F={gate['F']} fleet {gate['speedup']:.1f}x faster than "
          f"sequential (gate >= {SPEEDUP_GATE}x), solutions match to "
          f"<= {MATCH_TOL}")
    save_json("fig7_sweep.json", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(fast=ap.parse_args().fast)
