"""Figure 6 (beyond paper): prediction throughput through the
representation hierarchy (DESIGN.md §9).

The legacy predict path (``objectives.ksvm_predict`` / ``krr_predict``)
materializes the dense (q x m) test-kernel slab against the full
training set — training got slab-free in fig5, serving did not.  This
sweep measures queries/second for:

  * legacy dense predict (the (q x m) ``gram_slab`` oracle),
  * batched slab-free predict (``core/predict.py``, fixed-block jit
    cache) over the EXACT representation,
  * batched predict over the LOW-RANK (Nystrom) representation —
    O(l) per query after the (l,)-word ``Phi^T w`` precompute,

for both estimators, plus the modeled per-query flops
(``perf_model.modeled_predict_cost``) so the measured ratios can be
checked against the model.

Acceptance gate: batched slab-free predictions must match the legacy
dense oracle to <= 1e-5 (exact representation, both estimators).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelRidge, KernelSVM, SolverOptions
from repro.core import KernelConfig, KRRConfig
from repro.core.objectives import krr_predict, ksvm_predict
from repro.core.perf_model import modeled_predict_cost
from repro.data.synthetic import classification_dataset, regression_dataset

from .common import emit, save_json, timeit

LANDMARKS = 128
BATCH = 512


def _throughput(fn, q, iters=3):
    t = timeit(fn, iters=iters)
    return {"t_s": t, "queries_per_s": q / t}


def sweep(fast: bool = False):
    m, n = (768, 32) if fast else (8192, 64)
    q = 512 if fast else 4096
    kern = KernelConfig("rbf", sigma=1.0)
    H = 64 if fast else 256
    rows = []

    # ---- K-RR -----------------------------------------------------------
    A, y = regression_dataset(jax.random.key(0), m, n)
    Q = regression_dataset(jax.random.key(1), q, n)[0]
    base = dict(method="sstep", s=8, b=4, max_iters=H, seed=1)
    reps = {
        "exact": SolverOptions(**base),
        "nystrom": SolverOptions(approx="nystrom", landmarks=LANDMARKS,
                                 **base),
    }
    for rep, opts in reps.items():
        reg = KernelRidge(lam=1.0, kernel=kern, options=opts,
                          predict_batch=BATCH)
        res = reg.fit(A, y)
        batched = _throughput(lambda: reg.predict(Q), q)
        if rep == "exact":
            legacy = _throughput(
                lambda: krr_predict(A, res.alpha, Q, reg.cfg), q)
            np.testing.assert_allclose(
                np.asarray(reg.predict(Q)),
                np.asarray(krr_predict(A, res.alpha, Q, reg.cfg)),
                rtol=1e-5, atol=1e-5)
        else:
            lin = KRRConfig(lam=1.0, kernel=KernelConfig("linear"))
            legacy = _throughput(
                lambda: krr_predict(reg.op_.Phi, res.alpha,
                                    reg.op_.fmap(Q), lin), q)
        model = modeled_predict_cost(
            m, n, q, kern.name,
            approx=opts.approx, landmarks=LANDMARKS)
        rows.append({"estimator": "krr", "representation": rep,
                     "m": m, "n": n, "q": q, "batch": BATCH,
                     "legacy_dense": legacy, "batched_slabfree": batched,
                     "modeled_flops_per_query": model["flops_per_query"]})
        emit(f"fig6/krr/{rep}", batched["t_s"] * 1e6,
             f"batched={batched['queries_per_s']:.0f}q/s;"
             f"legacy={legacy['queries_per_s']:.0f}q/s")

    # ---- K-SVM (decision values; SV-compacted serving) ------------------
    A, y = classification_dataset(jax.random.key(2), m, n)
    Q = classification_dataset(jax.random.key(3), q, n)[0]
    for rep, opts in reps.items():
        clf = KernelSVM(C=1.0, kernel=kern, options=opts,
                        predict_batch=BATCH)
        res = clf.fit(A, y)
        n_sv = int(jnp.sum(res.alpha != 0))
        batched = _throughput(lambda: clf.decision_function(Q), q)
        if rep == "exact":
            legacy = _throughput(
                lambda: ksvm_predict(A, y, res.alpha, Q, clf.cfg), q)
            np.testing.assert_allclose(
                np.asarray(clf.decision_function(Q)),
                np.asarray(ksvm_predict(A, y, res.alpha, Q, clf.cfg)),
                rtol=1e-5, atol=1e-5)
        else:
            legacy = _throughput(
                lambda: clf.op_.fmap(Q) @ (clf.op_.Phi.T
                                           @ (res.alpha * y)), q)
        model = modeled_predict_cost(
            m, n, q, kern.name, approx=opts.approx, landmarks=LANDMARKS,
            sv_fraction=n_sv / m)
        rows.append({"estimator": "ksvm", "representation": rep,
                     "m": m, "n": n, "q": q, "batch": BATCH,
                     "n_sv": n_sv,
                     "legacy_dense": legacy, "batched_slabfree": batched,
                     "modeled_flops_per_query": model["flops_per_query"]})
        emit(f"fig6/ksvm/{rep}", batched["t_s"] * 1e6,
             f"batched={batched['queries_per_s']:.0f}q/s;"
             f"legacy={legacy['queries_per_s']:.0f}q/s;sv={n_sv}/{m}")
    return rows


def run(fast: bool = False):
    rows = sweep(fast)
    print(f"fig6: batched slab-free predict matches the legacy dense "
          f"oracle (<=1e-5) on both estimators; "
          f"{len(rows)} (estimator x representation) configs")
    save_json("fig6_predict.json", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(fast=ap.parse_args().fast)
