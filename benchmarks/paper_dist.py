"""§Perf-paper: collective schedule of the paper's own solvers on the
production mesh, measured from the lowered programs.

Variants:
  classical-1D : BDCD, one psum of (m x b) words EVERY iteration (paper)
  sstep-1D     : s-step BDCD, one psum of (m x s*b) every s iterations
                 (the paper's contribution)
  sstep-2D     : beyond-paper samples x features partition — the slab
                 psum shrinks to (m/P_data x s*b) per device

Metrics: collective executions per solve (jaxpr, trip-count aware) and
collective bytes per outer round (HLO text of the round body).
Runs in-process on a (4 data x 4 model) host mesh = 16 devices.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=16 "
                           + os.environ.get("XLA_FLAGS", ""))

import json          # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import KernelConfig, KRRConfig, block_schedule  # noqa: E402
from repro.core.distributed import (dist_bdcd_krr, dist_sstep_bdcd_krr,
                                    dist_sstep_bdcd_krr_2d)  # noqa: E402
from repro.data.synthetic import regression_dataset  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes  # noqa: E402
from repro.launch.jaxpr_analysis import count_collective_executions  # noqa: E402


def run(fast: bool = False):
    import sys
    fast = fast or "--fast" in sys.argv
    # The 2D layout trades the (m x sb) slab psum for a (n/Pm x sb)
    # sampled-row gather + (m/Pd x sb) slab: it wins iff m(1-1/Pd) >
    # n/Pm + sb.  Measure BOTH regimes:
    datasets = {
        "tall (abalone-like m>>n)": (1024, 64) if fast else (4096, 64),
        "wide (duke-like n>>m)": (256, 2048) if fast else (2048, 8192),
    }
    b, s, H = 4, 16, 64
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    out = {}
    for dname, (m, n) in datasets.items():
        A, y = regression_dataset(jax.random.key(0), m, n)
        cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf"))
        sched = block_schedule(jax.random.key(1), H, m, b)
        a0 = jnp.zeros(m)
        variants = {
            "classical-1D": partial(dist_bdcd_krr, mesh, A, y, a0, sched,
                                    cfg),
            "sstep-1D": partial(dist_sstep_bdcd_krr, mesh, A, y, a0,
                                sched, cfg, s),
            "sstep-2D": partial(dist_sstep_bdcd_krr_2d, mesh, A, y, a0,
                                sched, cfg, s),
        }
        ref = None
        for name, fn in variants.items():
            jaxpr = jax.make_jaxpr(lambda: fn())()
            execs = count_collective_executions(jaxpr)
            hlo = jax.jit(lambda: fn()).lower().compile().as_text()
            per_kind = collective_bytes(hlo)  # body once = per round
            alpha = fn()
            if ref is None:
                ref = alpha
            dev = float(jnp.max(jnp.abs(alpha - ref)))
            out[f"{dname}/{name}"] = {
                "collective_executions_per_solve": execs,
                "collective_bytes_per_round": per_kind,
                "bytes_per_round_total": sum(per_kind.values()),
                "max_dev_from_classical": dev,
            }
            print(f"paper_dist/{dname}/{name},0.0,execs={execs};"
                  f"bytes/round={sum(per_kind.values())};dev={dev:.1e}")
    from .common import save_json
    save_json("paper_dist.json", out)
    return out


if __name__ == "__main__":
    run()
