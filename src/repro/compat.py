"""Version-compatibility shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` (0.4.x, with
``check_rep``/``axis_names``-less signature) to ``jax.shard_map`` (with
``check_vma``/``axis_names``); ``enable_x64``, ``CompilerParams``,
``cost_analysis`` and mesh ``axis_types`` similarly renamed or reshaped.
Call sites use these wrappers so the repo runs on both sides.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as _pltpu

# Pallas compiler params: pltpu.TPUCompilerParams (0.4.x) -> CompilerParams
CompilerParams = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")


def enable_x64(flag: bool = True):
    """``jax.experimental.enable_x64`` (0.4.x) / ``jax.enable_x64``."""
    try:
        from jax.experimental import enable_x64 as ctx
    except ImportError:
        ctx = jax.enable_x64
    return ctx(flag)


def make_mesh_auto(axis_shapes, axis_names):
    """``jax.make_mesh`` with every axis explicitly Auto on jax versions
    that have ``jax.sharding.AxisType``; 0.4.x has neither the kwarg nor
    any non-Auto behavior, so the plain call is equivalent."""
    AxisType = getattr(jax.sharding, "AxisType", None)
    if AxisType is None:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names))


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returned a one-element list of dicts
    on 0.4.x and a plain dict on newer jax; normalize to the dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False,
              axis_names=None):
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # Partial-auto (auto = axes not in axis_names) hits an XLA
    # IsManualSubgroup check-failure on 0.4.x CPU builds, so fall back to
    # fully-manual.  Safe for our call sites: their bodies only issue
    # collectives over the manual axes, so the auto axes merely lose the
    # GSPMD sharding hint and compute replicated — same values.
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
