"""Snowflake Arctic 480B [moe] — 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

Assigned numbers: 35L d_model=7168 56H (kv=8) d_ff=4864 (expert hidden)
vocab=32000.  The dense-residual branch runs a parallel MLP of the same
hidden dim alongside the MoE (arctic's dense+MoE hybrid residual)."""
import dataclasses

from repro.models.config import MOE, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    pattern=(MOE,),
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual_ff=4864,
    moe_impl="capacity",   # §Perf default (36x less expert compute);
    # pass moe_impl="dense" for the paper-baseline dispatch
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=128, moe_d_ff=128, dense_residual_ff=128, vocab_size=512,
    n_experts=8, top_k=2)
