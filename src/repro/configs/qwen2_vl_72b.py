"""Qwen2-VL 72B [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Backbone only per the assignment; the vision frontend is a stub —
``input_specs()`` supplies the 3-stream (temporal/height/width) M-RoPE
position ids that the frontend would produce."""
import dataclasses

from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    pattern=(DENSE,),
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1000000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, mrope_sections=(4, 6, 6))
