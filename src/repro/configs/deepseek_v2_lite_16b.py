"""DeepSeek-V2-Lite 16B [moe] — MLA (kv_lora=512), 2 shared + 64 routed
experts, top-6 [arXiv:2405.04434].

Assigned numbers used verbatim: 27L d_model=2048 16H d_ff=1408 (expert
hidden dim) vocab=102400, MoE 64e top-6, MLA kv_lora_rank=512."""
import dataclasses

from repro.models.config import MOE, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    pattern=(MOE,),
    attn_type="mla",
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    moe_impl="capacity",   # §Perf default; "dense" = baseline dispatch
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=64, moe_d_ff=64, vocab_size=512, kv_lora_rank=32,
    qk_rope_head_dim=16, n_experts=8, top_k=2, n_shared_experts=1)
