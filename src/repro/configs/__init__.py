"""Architecture registry: one module per assigned architecture, each
exporting CONFIG (the exact published numbers) and REDUCED (same family
traits at smoke-test scale)."""
from __future__ import annotations

import importlib

ARCHS = (
    "llama3_405b",
    "granite_20b",
    "yi_6b",
    "qwen3_1p7b",
    "zamba2_1p2b",
    "qwen2_vl_72b",
    "deepseek_v2_lite_16b",
    "arctic_480b",
    "falcon_mamba_7b",
    "whisper_tiny",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "llama3-405b": "llama3_405b",
    "qwen3-1.7b": "qwen3_1p7b",
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
})


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def get_config(arch: str, reduced: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False):
    return {a: get_config(a, reduced) for a in ARCHS}
