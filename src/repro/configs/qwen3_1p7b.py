"""Qwen3 1.7B [dense] — qk_norm, GQA (kv=8) [hf:Qwen/Qwen3-8B family]."""
import dataclasses

from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    pattern=(DENSE,),
    qk_norm=True,
    rope_theta=1000000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512)
