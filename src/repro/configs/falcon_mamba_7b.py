"""Falcon-Mamba 7B [ssm] — pure Mamba-1, attention-free
[arXiv:2410.05355]."""
import dataclasses

from repro.models.config import MAMBA1, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    pattern=(MAMBA1,),
    attn_type="none",
    ssm_state=16,
    expand=2,
    d_conv=4,
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, vocab_size=512, ssm_state=8)
