"""Yi 6B [dense] — llama-arch GQA (kv=4) [arXiv:2403.04652]."""
import dataclasses

from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    pattern=(DENSE,),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512)
