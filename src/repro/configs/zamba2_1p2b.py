"""Zamba2 1.2B [hybrid] — Mamba2 backbone + SHARED attention block applied
every other layer (weights reused) [arXiv:2411.15242].

Assigned numbers: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000
ssm_state=64.  We model the layout as 19 periods of (mamba2, mamba2) with
the shared attention+MLP block at each period boundary; head_dim=64 so
32 heads x 64 = d_model."""
import dataclasses

from repro.models.config import MAMBA2, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    pattern=(MAMBA2, MAMBA2),
    shared_attn_every=2,
    ssm_state=64,
    expand=2,
    mamba_headdim=64,
    ssm_impl="ssd",        # §Perf default: matmul-form SSD (-46% T_mem);
    # pass ssm_impl="scan" for the elementwise reference path
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, ssm_state=8, mamba_headdim=16)
