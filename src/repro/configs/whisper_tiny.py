"""Whisper tiny [audio] — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356].

``input_specs()`` supplies precomputed frame embeddings (B, 1500, 384) in
place of the log-mel + conv1d frontend, per the assignment."""
import dataclasses

from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    pattern=(DENSE,),
    encoder_layers=4,
    encoder_seq=1500,
    cross_attention=True,
    norm="layernorm",
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512, encoder_layers=2, encoder_seq=64)
