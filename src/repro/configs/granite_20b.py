"""Granite 20B [dense] — llama-arch code model, MQA (kv=1)
[arXiv:2405.04324]."""
import dataclasses

from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    pattern=(DENSE,),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512)
