"""Llama-3 405B [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
import dataclasses

from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    pattern=(DENSE,),
    rope_theta=500000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512)
