"""Unified model configuration covering the 10 assigned architectures.

One frozen dataclass; every architecture file in ``repro/configs`` fills in
the exact published numbers.  The model builder (``models/lm.py``) reads
only this config, so any (arch x shape x mesh) cell is reproducible from
the config alone.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

DENSE = "dense"        # attention + MLP block
MOE = "moe"            # attention + MoE block
MAMBA1 = "mamba1"      # Mamba-1 SSM block (attention-free)
MAMBA2 = "mamba2"      # Mamba-2 (SSD) block
ATTN = "attn"          # attention-only block (used by hybrid patterns)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128

    # Block layout: a repeating pattern of block kinds. The full stack is
    # pattern * (n_layers // len(pattern)). Homogeneous patterns scan over
    # stacked per-layer params; hybrid patterns scan over super-blocks.
    pattern: Tuple[str, ...] = (DENSE,)

    # Attention options
    attn_type: str = "gqa"            # "gqa" | "mla" | "none"
    qk_norm: bool = False             # qwen3
    rope_theta: float = 10000.0
    mrope: bool = False               # qwen2-vl M-RoPE (3 position streams)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    causal: bool = True               # False for encoder stacks

    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    v_head_dim: int = 0               # defaults to head_dim

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                 # expert hidden dim (d_ff if 0)
    dense_residual_ff: int = 0        # arctic: parallel dense MLP hidden dim
    router_noise: float = 0.0
    moe_impl: str = "dense"           # "dense" | "capacity" (§Perf)
    capacity_factor: float = 1.25

    # SSM (mamba)
    ssm_state: int = 16
    ssm_impl: str = "scan"            # "scan" | "ssd" (§Perf: matmul-form
                                      # SSD block decomposition, mamba2)
    d_conv: int = 4
    expand: int = 2                   # d_inner = expand * d_model
    mamba_headdim: int = 64           # mamba2 head dim

    # Hybrid (zamba2): a single SHARED attention block applied at the end
    # of each pattern period (weights reused across periods).
    shared_attn_every: int = 0        # 0 = no shared block

    # Encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper 30s @ 50Hz after conv stub
    cross_attention: bool = False

    # Frontend stubs ([audio]/[vlm]): inputs arrive as precomputed
    # embeddings of width d_model instead of token ids.
    embedding_inputs: bool = False    # whisper encoder side

    norm: str = "rmsnorm"             # "rmsnorm" | "layernorm"
    ce_impl: str = "gather"           # "gather" | "onehot" (§Perf: onehot
                                      # keeps the CE local under V-sharding)
    attn_impl: str = "naive"          # "naive" | "flash" (§Perf: Pallas
                                      # flash attention, VMEM softmax)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"               # "full" | "none"

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}")

    # ---- derived ----
    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def v_head(self) -> int:
        return self.v_head_dim or self.head_dim

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_attention_free(self) -> bool:
        return (all(p in (MAMBA1, MAMBA2) for p in self.pattern)
                and self.shared_attn_every == 0)

    @property
    def has_ssm(self) -> bool:
        return any(p in (MAMBA1, MAMBA2) for p in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (decode cost is O(1) in history
        for SSM blocks; hybrid shared-attn decode is O(S) linear)."""
        return self.has_ssm

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d                                    # embed
        if not self.tie_embeddings:
            total += v * d                               # lm head
        per_kind = {}
        qdim = self.n_heads * (self.head_dim + (self.qk_rope_head_dim
                               if self.attn_type == "mla" else 0))
        attn = 0
        if self.attn_type == "gqa":
            attn = (d * self.n_heads * self.head_dim          # q
                    + 2 * d * self.n_kv_heads * self.head_dim  # k, v
                    + self.n_heads * self.head_dim * d)        # o
        elif self.attn_type == "mla":
            attn = (d * qdim                                   # q proj
                    + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    + self.kv_lora_rank * self.n_heads * (
                        self.head_dim + self.v_head)
                    + self.n_heads * self.v_head * d)
        mlp = 3 * d * f                                        # gated mlp
        per_kind[DENSE] = attn + mlp
        per_kind[ATTN] = attn
        moe = (self.n_experts * 3 * d * self.moe_ff
               + self.n_shared_experts * 3 * d * self.moe_ff
               + d * self.n_experts)
        if self.dense_residual_ff:
            moe += 3 * d * self.dense_residual_ff
        per_kind[MOE] = attn + moe
        di = self.d_inner
        per_kind[MAMBA1] = (2 * d * di + di * self.d_conv
                            + di * (2 * self.ssm_state + 2)  # x_proj(B,C),dt
                            + di * self.ssm_state + di       # A, D
                            + di * d)
        nh = di // self.mamba_headdim
        per_kind[MAMBA2] = (d * (2 * di + 2 * self.ssm_state + nh)
                            + di * self.d_conv + 2 * nh + di * d)
        for p in self.pattern:
            total += self.n_periods * per_kind[p]
        if self.shared_attn_every:
            total += per_kind[ATTN]
        if self.encoder_layers:
            total += self.encoder_layers * per_kind[DENSE]
            if self.cross_attention:  # decoder cross-attn blocks
                total += self.n_layers * attn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        inactive = (self.n_experts - self.top_k) * 3 * self.d_model * self.moe_ff
        n_moe = sum(1 for p in self.pattern if p == MOE) * self.n_periods
        return full - n_moe * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
