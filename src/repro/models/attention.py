"""Attention blocks: GQA (covers MHA), MLA (DeepSeek compressed-KV), with
qk-norm (Qwen3), RoPE / M-RoPE, causal & bidirectional, cross-attention,
and single-token decode against a KV cache.

Softmax and logit math in fp32; matmuls in the config compute dtype.
Sharding is applied by the caller via with_sharding_constraint — these
functions are layout-agnostic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_mrope, apply_rope, dense_init, init_norm,
                     rmsnorm)

NEG_INF = -2.0e38


# =====================================================================
# GQA
# =====================================================================

def init_gqa(key, cfg: ModelConfig, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, (h, hd)),
        "wk": dense_init(ks[1], d, (kv, hd)),
        "wv": dense_init(ks[2], d, (kv, hd)),
        "wo": dense_init(ks[3], h * hd, d).reshape(h, hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd)
        p["k_norm"] = init_norm(hd)
    return p


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _sdpa(q, k, v, mask, dtype):
    """q: (B,S,H,hd) k/v: (B,T,H,hd); mask: (S,T) or (B,S,T) bool or None."""
    hd = q.shape[-1]
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    logits *= hd ** -0.5
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None]
        else:
            mask = mask[:, None]
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def gqa_forward(p, cfg: ModelConfig, x, positions,
                kv_x: Optional[jnp.ndarray] = None,
                causal: Optional[bool] = None, rules=None,
                rope_cache=None):
    """Full-sequence attention (training / prefill).  ``kv_x`` switches to
    cross-attention (no rope on k, no causal mask)."""
    dtype = x.dtype
    cross = kv_x is not None
    src = kv_x if cross else x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"].astype(dtype))
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"].astype(dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if not cross:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta, cache=rope_cache)
            k = apply_rope(k, positions, cfg.rope_theta, cache=rope_cache)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    use_causal = cfg.causal if causal is None else causal
    # SPerf: when heads don't divide TP the S^2 work would run REPLICATED
    # over the model axis; shard the q rows (sequence) over TP instead —
    # each shard attends its q rows against the full (small) K/V.
    seq_shard = (rules is not None and not cross
                 and cfg.n_heads % max(rules.axis_size(rules.tp), 1) != 0
                 and q.shape[1] % rules.axis_size(rules.tp) == 0)
    if seq_shard:
        q = rules.constrain(q, rules.batch_axes, rules.tp, None, None)
    if cfg.attn_impl == "flash" and use_causal and not cross:
        from repro.kernels.ops import sdpa_flash
        out = sdpa_flash(q, k, v, causal=True)
    else:
        mask = None
        if use_causal and not cross:
            S, T = q.shape[1], k.shape[1]
            mask = jnp.tril(jnp.ones((S, T), bool))
        out = _sdpa(q, k, v, mask, dtype)
    if seq_shard:   # back to batch-only sharding for the residual stream
        out = rules.constrain(out, rules.batch_axes, None, None, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def gqa_decode(p, cfg: ModelConfig, x, cache: Tuple, pos: jnp.ndarray,
               cross_kv: Optional[Tuple] = None):
    """One-token decode.  x: (B, 1, D); cache: (k, v) with shape
    (B, S_max, kv, hd); pos: (B,) current position (tokens written at pos).
    Returns (out, new_cache).  With ``cross_kv`` given, attends to the
    precomputed encoder KV instead (cache passes through untouched)."""
    dtype = x.dtype
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
    if cross_kv is not None:
        k, v = cross_kv
        k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
        v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
        out = _sdpa(q, k, v, None, dtype)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype)), cache

    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if cfg.qk_norm:
        k_new = rmsnorm(p["k_norm"], k_new)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(pos[None], (3, B))[..., None]   # (3,B,1)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k_new = apply_mrope(k_new, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    # scatter the new token at per-example position ``pos``
    ck, cv = cache
    oh = jax.nn.one_hot(pos, ck.shape[1], dtype=ck.dtype)       # (B, S)
    ck = ck * (1 - oh[..., None, None]) + oh[..., None, None] * k_new.astype(ck.dtype)
    cv = cv * (1 - oh[..., None, None]) + oh[..., None, None] * v_new.astype(cv.dtype)

    k = _repeat_kv(ck.astype(dtype), cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(cv.astype(dtype), cfg.n_heads // cfg.n_kv_heads)
    # mask out cache slots beyond the current position
    valid = (jnp.arange(ck.shape[1])[None] <= pos[:, None])     # (B, S)
    out = _sdpa(q, k, v, valid[:, None, :], dtype)              # (B,1,S) mask
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return o, (ck, cv)


def init_gqa_cache(cfg: ModelConfig, batch, seq, dtype):
    shape = (batch, seq, cfg.n_kv_heads, cfg.head_dim)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# =====================================================================
# MLA (DeepSeek-V2): compressed KV cache of width kv_lora_rank + rope dim
# =====================================================================

def init_mla(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    hd, rd, vd, r = cfg.head_dim, cfg.qk_rope_head_dim, cfg.v_head, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, (h, hd + rd)),        # q: nope + rope parts
        "w_dkv": dense_init(ks[1], d, r),                # down-proj (cached)
        "w_kr": dense_init(ks[2], d, rd),                # shared rope key
        "w_uk": dense_init(ks[3], r, (h, hd)),           # up-proj k_nope
        "w_uv": dense_init(ks[4], r, (h, vd)),           # up-proj v
        "wo": dense_init(ks[5], h * vd, d).reshape(h, vd, d),
        "kv_norm": init_norm(r),
    }


def _mla_qkv(p, cfg, x, c, k_rope, positions, dtype):
    hd, rd = cfg.head_dim, cfg.qk_rope_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_nope = jnp.einsum("btr,rhk->bthk", c, p["w_uk"].astype(dtype))
    v = jnp.einsum("btr,rhk->bthk", c, p["w_uv"].astype(dtype))
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (*k_nope.shape[:3], rd))
    k_full = jnp.concatenate([k_nope, k_rope_b], -1)
    return q_full, k_full, v


def mla_forward(p, cfg: ModelConfig, x, positions):
    dtype = x.dtype
    c = jnp.einsum("btd,dr->btr", x, p["w_dkv"].astype(dtype))
    c = rmsnorm(p["kv_norm"], c)
    k_rope = jnp.einsum("btd,dr->btr", x, p["w_kr"].astype(dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    q, k, v = _mla_qkv(p, cfg, x, c, k_rope, positions, dtype)
    S = x.shape[1]
    mask = jnp.tril(jnp.ones((S, S), bool))
    out = _sdpa(q, k, v, mask, dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def mla_decode(p, cfg: ModelConfig, x, cache, pos):
    """cache: (c, k_rope) with shapes (B, S, r) / (B, S, rd) — this is the
    whole point of MLA: the cache is rank-r, not n_heads * head_dim."""
    dtype = x.dtype
    cc, ckr = cache
    c_new = jnp.einsum("btd,dr->btr", x, p["w_dkv"].astype(dtype))
    c_new = rmsnorm(p["kv_norm"], c_new)
    kr_new = jnp.einsum("btd,dr->btr", x, p["w_kr"].astype(dtype))
    kr_new = apply_rope(kr_new[:, :, None, :], pos[:, None],
                        cfg.rope_theta)[:, :, 0, :]
    oh = jax.nn.one_hot(pos, cc.shape[1], dtype=cc.dtype)
    cc = cc * (1 - oh[..., None]) + oh[..., None] * c_new.astype(cc.dtype)
    ckr = ckr * (1 - oh[..., None]) + oh[..., None] * kr_new.astype(ckr.dtype)

    q, k, v = _mla_qkv(p, cfg, x, cc.astype(dtype), ckr.astype(dtype),
                       pos[:, None], dtype)
    valid = (jnp.arange(cc.shape[1])[None] <= pos[:, None])
    out = _sdpa(q, k, v, valid[:, None, :], dtype)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return o, (cc, ckr)


def init_mla_cache(cfg: ModelConfig, batch, seq, dtype):
    return (jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
            jnp.zeros((batch, seq, cfg.qk_rope_head_dim), dtype))


# dispatchers ---------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    if cfg.attn_type == "mla" and not cross:
        return init_mla(key, cfg)
    return init_gqa(key, cfg, cross=cross)


def attention_forward(p, cfg, x, positions, rules=None, rope_cache=None,
                      **kw):
    if cfg.attn_type == "mla":
        return mla_forward(p, cfg, x, positions)
    return gqa_forward(p, cfg, x, positions, rules=rules,
                       rope_cache=rope_cache, **kw)


def attention_decode(p, cfg, x, cache, pos, **kw):
    if cfg.attn_type == "mla":
        return mla_decode(p, cfg, x, cache, pos)
    return gqa_decode(p, cfg, x, cache, pos, **kw)


def init_cache(cfg: ModelConfig, batch, seq, dtype):
    if cfg.attn_type == "mla":
        return init_mla_cache(cfg, batch, seq, dtype)
    return init_gqa_cache(cfg, batch, seq, dtype)
