from .config import (ATTN, DENSE, MAMBA1, MAMBA2, MOE, SHAPES, ModelConfig,
                     ShapeConfig)
from .lm import (abstract_params, decode_step, forward, init_decode_state,
                 init_params, loss_fn, prefill_cross_kv)
from .sharding import MeshRules, param_spec, tree_pspecs, tree_shardings
