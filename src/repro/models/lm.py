"""Model assembly: init / train-forward / decode-step for every assigned
architecture, driven entirely by ModelConfig.

Layer stacking: parameters of each pattern position are stacked over the
``n_periods`` repeats and the stack is traversed with ``jax.lax.scan`` —
HLO size stays O(1) in depth (this is what makes a 126-layer 405B model
lowerable on a single CPU host) and remat wraps the scan body.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import mamba as mb
from .attention import (attention_decode, attention_forward, gqa_forward,
                        init_attention, init_cache)
from .config import ATTN, DENSE, MAMBA1, MAMBA2, MOE, ModelConfig
from .layers import (apply_norm, embed, init_embedding, init_mlp,
                     init_norm_for, mlp, unembed)
from .moe import init_moe, moe_apply
from .sharding import MeshRules

# ---------------------------------------------------------------- init ----


def _init_block(key, cfg: ModelConfig, kind: str, cross: bool):
    ks = jax.random.split(key, 8)
    p = {}
    if kind in (DENSE, MOE, ATTN):
        p["norm1"] = init_norm_for(cfg.norm, cfg.d_model)
        p["attn"] = init_attention(ks[0], cfg)
        if cross:
            p["norm_x"] = init_norm_for(cfg.norm, cfg.d_model)
            p["cross"] = init_attention(ks[1], cfg, cross=True)
        if kind == DENSE:
            p["norm2"] = init_norm_for(cfg.norm, cfg.d_model)
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff)
        elif kind == MOE:
            p["norm2"] = init_norm_for(cfg.norm, cfg.d_model)
            p["moe"] = init_moe(ks[3], cfg)
    elif kind == MAMBA1:
        p["norm1"] = init_norm_for(cfg.norm, cfg.d_model)
        p["mamba"] = mb.init_mamba1(ks[0], cfg)
    elif kind == MAMBA2:
        p["norm1"] = init_norm_for(cfg.norm, cfg.d_model)
        p["mamba"] = mb.init_mamba2(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    params = {"embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
              "final_norm": init_norm_for(cfg.norm, cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(ks[1], cfg.vocab_size,
                                           cfg.d_model)
    blocks = []
    for i, kind in enumerate(cfg.pattern):
        layer_keys = jax.random.split(jax.random.fold_in(ks[2], i),
                                      cfg.n_periods)
        blocks.append(jax.vmap(
            lambda k: _init_block(k, cfg, kind, cfg.cross_attention))(
                layer_keys))
    params["blocks"] = tuple(blocks)
    if cfg.shared_attn_every:
        params["shared_attn"] = {
            "norm": init_norm_for(cfg.norm, cfg.d_model),
            "attn": init_attention(ks[3], cfg),
        }
        if cfg.d_ff:
            params["shared_attn"]["norm2"] = init_norm_for(cfg.norm,
                                                           cfg.d_model)
            params["shared_attn"]["mlp"] = init_mlp(ks[5], cfg.d_model,
                                                    cfg.d_ff)
    if cfg.encoder_layers:
        enc_keys = jax.random.split(ks[4], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: _init_block(k, cfg, DENSE, cross=False))(enc_keys),
            "final_norm": init_norm_for(cfg.norm, cfg.d_model),
        }
    return params


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def _scan_blocks(body, carry, xs, unroll: bool):
    """lax.scan over stacked layer params, or a python unroll (used by the
    dry-run's two-point cost probes: XLA's cost_analysis counts a while
    body once, so probes lower unrolled shallow configs and extrapolate)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        sl = jax.tree.map(lambda x: x[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ------------------------------------------------------------- forward ----


def _block_forward(kind, p, cfg: ModelConfig, x, positions, enc_out,
                   rules: Optional[MeshRules], rope_cache=None):
    dtype = x.dtype
    if kind in (DENSE, MOE, ATTN):
        h = attention_forward(p["attn"], cfg,
                              apply_norm(cfg.norm, p["norm1"], x), positions,
                              rules=rules, rope_cache=rope_cache)
        if rules:
            h = rules.constrain_batch(h, None, None)
        x = x + h
        if cfg.cross_attention and enc_out is not None:
            hx = gqa_forward(p["cross"], cfg,
                             apply_norm(cfg.norm, p["norm_x"], x), None,
                             kv_x=enc_out)
            x = x + hx
        if kind == DENSE:
            x = x + mlp(p["mlp"], apply_norm(cfg.norm, p["norm2"], x), dtype)
        elif kind == MOE:
            x = x + moe_apply(p["moe"], cfg,
                              apply_norm(cfg.norm, p["norm2"], x),
                              rules=rules)
    else:
        fwd = mb.mamba1_forward if kind == MAMBA1 else mb.mamba2_forward
        x = x + fwd(p["mamba"], cfg, apply_norm(cfg.norm, p["norm1"], x))
    if rules:
        x = rules.constrain_batch(x, None, None)
    return x


def _shared_attn(params, cfg, x, positions, rules, rope_cache=None):
    """Zamba2-style shared transformer block (weights reused per period)."""
    p = params["shared_attn"]
    h = attention_forward(p["attn"], cfg,
                          apply_norm(cfg.norm, p["norm"], x), positions,
                          rules=rules, rope_cache=rope_cache)
    x = x + h
    if "mlp" in p:
        x = x + mlp(p["mlp"], apply_norm(cfg.norm, p["norm2"], x), x.dtype)
    return x


def _default_positions(cfg: ModelConfig, B, S):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope:
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def encoder_forward(params, cfg: ModelConfig, audio_embed,
                    rules: Optional[MeshRules] = None,
                    unroll: bool = False):
    """Whisper-style encoder over precomputed frontend embeddings
    (conv frontend is a stub per the assignment)."""
    x = audio_embed.astype(cfg.activation_dtype)
    B, S = x.shape[:2]
    positions = _default_positions(cfg, B, S)
    enc = params["encoder"]

    def body(h, p):
        h = _block_forward(DENSE, p, cfg, h, positions, None, rules)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = _scan_blocks(body_fn, x, enc["blocks"], unroll)
    return apply_norm(cfg.norm, enc["final_norm"], x)


def forward(params, cfg: ModelConfig, tokens,
            positions=None, audio_embed=None,
            rules: Optional[MeshRules] = None, unroll: bool = False):
    """Training / prefill forward -> fp32 logits (B, S, V).

    ``positions``: optional (B,S) or (3,B,S) for M-RoPE (vlm stub inputs).
    ``audio_embed``: encoder-side stub embeddings (enc-dec only).
    """
    dtype = cfg.activation_dtype
    B, S = tokens.shape
    x = embed(params["embed"], tokens, dtype)
    if rules:
        x = rules.constrain_batch(x, None, None)
    if positions is None:
        positions = _default_positions(cfg, B, S)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encoder_forward(params, cfg, audio_embed, rules, unroll)
    rope_cache = None
    if not cfg.mrope and cfg.attn_type == "gqa" and cfg.n_heads:
        from .layers import make_rope_cache
        rope_cache = make_rope_cache(positions, cfg.head_dim,
                                     cfg.rope_theta)

    def body(h, slices):
        for kind, p in zip(cfg.pattern, slices):
            h = _block_forward(kind, p, cfg, h, positions, enc_out, rules,
                               rope_cache)
        if cfg.shared_attn_every:
            h = _shared_attn(params, cfg, h, positions, rules, rope_cache)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = _scan_blocks(body_fn, x, params["blocks"], unroll)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x, dtype)
    if rules:
        logits = rules.constrain_batch(logits, None, "model")
    return logits


def loss_fn(params, cfg: ModelConfig, batch,
            rules: Optional[MeshRules] = None, unroll: bool = False):
    logits = forward(params, cfg, batch["tokens"],
                     positions=batch.get("positions"),
                     audio_embed=batch.get("audio_embed"), rules=rules,
                     unroll=unroll)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    if cfg.ce_impl == "onehot":
        # V-sharding-friendly: one-hot contraction partitions over the
        # vocab axis (local partial + tiny (B,S) psum) instead of
        # take_along_axis, which all-gathers the full logits tensor.
        onehot = jax.nn.one_hot(labels, logits.shape[-1],
                                dtype=logits.dtype)
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    else:
        gold = jnp.take_along_axis(
            logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ------------------------------------------------------------- decode -----


def _init_block_cache(cfg: ModelConfig, kind, batch, seq, dtype):
    if kind in (DENSE, MOE, ATTN):
        return init_cache(cfg, batch, seq, dtype)
    if kind == MAMBA1:
        return mb.init_mamba1_state(cfg, batch, dtype)
    return mb.init_mamba2_state(cfg, batch, dtype)


def init_decode_state(cfg: ModelConfig, batch, max_seq, with_encoder=False):
    """Decode state pytree: per-pattern-position caches stacked over
    periods (+ shared-attn caches, + whisper cross-KV slots)."""
    dtype = cfg.activation_dtype

    def stack(make):
        return jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[make() for _ in range(cfg.n_periods)])

    caches = tuple(
        stack(lambda kind=kind: _init_block_cache(cfg, kind, batch,
                                                  max_seq, dtype))
        for kind in cfg.pattern)
    state = {"caches": caches,
             "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.shared_attn_every:
        state["shared_cache"] = stack(
            lambda: init_cache(cfg, batch, max_seq, dtype))
    if cfg.encoder_layers and with_encoder:
        kvshape = (cfg.n_periods, batch, cfg.encoder_seq,
                   cfg.n_kv_heads, cfg.head_dim)
        state["cross_kv"] = (jnp.zeros(kvshape, dtype),
                             jnp.zeros(kvshape, dtype))
    return state


def prefill_cross_kv(params, cfg: ModelConfig, audio_embed, rules=None):
    """Whisper: run the encoder once and precompute each decoder layer's
    cross-attention K/V."""
    enc_out = encoder_forward(params, cfg, audio_embed, rules)
    dtype = enc_out.dtype

    def per_layer(p):
        k = jnp.einsum("btd,dhk->bthk", enc_out, p["cross"]["wk"].astype(dtype))
        v = jnp.einsum("btd,dhk->bthk", enc_out, p["cross"]["wv"].astype(dtype))
        return k, v

    # blocks[0] is the (only) decoder stack for enc-dec configs
    kv = jax.vmap(per_layer)(params["blocks"][0])
    return kv


def decode_step(params, cfg: ModelConfig, state, tokens,
                rules: Optional[MeshRules] = None, unroll: bool = False):
    """One new token per sequence.  tokens: (B, 1) -> logits (B, V).

    The layer scan carries the hidden state and threads each layer's cache
    slice through as scan xs/ys, so cache updates stay O(1) in depth.
    """
    dtype = cfg.activation_dtype
    pos = state["pos"]
    x = embed(params["embed"], tokens, dtype)
    has_shared = bool(cfg.shared_attn_every)
    cross_kv = state.get("cross_kv")

    xs = {"blocks": params["blocks"], "caches": state["caches"]}
    if has_shared:
        xs["shared_cache"] = state["shared_cache"]
    if cross_kv is not None:
        xs["cross_kv"] = cross_kv

    def body(h, scanned):
        new_caches = []
        for kind, p, c in zip(cfg.pattern, scanned["blocks"],
                              scanned["caches"]):
            if kind in (DENSE, MOE, ATTN):
                a_in = apply_norm(cfg.norm, p["norm1"], h)
                a, c = attention_decode(p["attn"], cfg, a_in, c, pos)
                h = h + a
                if cfg.cross_attention and "cross_kv" in scanned:
                    cx_in = apply_norm(cfg.norm, p["norm_x"], h)
                    a, _ = attention_decode(p["cross"], cfg, cx_in, c, pos,
                                            cross_kv=scanned["cross_kv"])
                    h = h + a
                if kind == DENSE:
                    h = h + mlp(p["mlp"],
                                apply_norm(cfg.norm, p["norm2"], h), dtype)
                elif kind == MOE:
                    h = h + moe_apply(p["moe"], cfg,
                                      apply_norm(cfg.norm, p["norm2"], h))
            else:
                dec = (mb.mamba1_decode if kind == MAMBA1
                       else mb.mamba2_decode)
                a, c = dec(p["mamba"], cfg,
                           apply_norm(cfg.norm, p["norm1"], h), c)
                h = h + a
            new_caches.append(c)
        out = {"caches": tuple(new_caches)}
        if has_shared:
            sp = params["shared_attn"]
            a_in = apply_norm(cfg.norm, sp["norm"], h)
            a, sc = attention_decode(sp["attn"], cfg, a_in,
                                     scanned["shared_cache"], pos)
            h = h + a
            if "mlp" in sp:
                h = h + mlp(sp["mlp"],
                            apply_norm(cfg.norm, sp["norm2"], h), dtype)
            out["shared_cache"] = sc
        return h, out

    x, scanned_out = _scan_blocks(body, x, xs, unroll)
    new_state = dict(state, caches=scanned_out["caches"], pos=pos + 1)
    if has_shared:
        new_state["shared_cache"] = scanned_out["shared_cache"]

    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x, dtype)[:, 0]
    return logits, new_state
