"""Shared neural-net layers: norms, embeddings, rotary embeddings, MLP.

Pure-pytree style: ``init_*`` returns a params dict, ``apply`` functions
take (params, inputs).  Compute dtype follows the config; params are stored
in fp32 (cast on use) so the optimizer sees full precision masters.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Init = jax.nn.initializers


def dense_init(key, in_dim, out_shape, scale: Optional[float] = None):
    """Truncated-normal fan-in init; out_shape may be a tuple (fused heads)."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    std = scale if scale is not None else in_dim ** -0.5
    return std * jax.random.truncated_normal(
        key, -2.0, 2.0, (in_dim, *out_shape), jnp.float32)


# ---------------------------------------------------------------- norms ---

def init_norm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def init_layernorm(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * p["scale"]).astype(dt)


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
            + p["bias"]).astype(dt)


def apply_norm(kind: str, p, x):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def init_norm_for(kind: str, d):
    return init_norm(d) if kind == "rmsnorm" else init_layernorm(d)


# ----------------------------------------------------------------- MLP ----

def init_mlp(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff),
        "wi_up": dense_init(k2, d_model, d_ff),
        "wo": dense_init(k3, d_ff, d_model),
    }


def mlp(p, x, dtype):
    gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(dtype))
    up = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(dtype))
    h = jax.nn.silu(gate) * up
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))


# ------------------------------------------------------------- rotary -----

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32)
                            / head_dim))


def make_rope_cache(positions: jnp.ndarray, head_dim: int, theta: float):
    """Precompute (cos, sin) ONCE per forward pass (§Perf C2: positions are
    identical for every layer; computing sin/cos inside the layer scan
    re-materializes two f32 (B,S,hd/2) tensors per layer)."""
    freqs = rope_freqs(head_dim, theta)                 # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs
    angles = angles[..., None, :]                       # broadcast over heads
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               cache=None) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) int32 (ignored when a
    precomputed ``cache`` = (cos, sin) is given)."""
    hd = x.shape[-1]
    if cache is None:
        cache = make_rope_cache(positions, hd, theta)
    cos, sin = cache
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Qwen2-VL M-RoPE.  positions3: (3, ..., S) — temporal/height/width
    position streams; the rotary half-dims are split into ``sections``
    (sum == hd/2), each rotated with its own stream."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # select the position stream per frequency-section:
    # positions3: (3, B, S) -> pos_sel: (B, S, hd/2)
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=hd // 2)
    p3 = positions3.astype(jnp.float32)
    pos_sel = p3[sec_id]                                # (hd/2, B, S)
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)              # (B, S, hd/2)
    angles = pos_sel * freqs                            # (B, S, hd/2)
    angles = angles[..., None, :]                       # (B, S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ embedding ---

def init_embedding(key, vocab, d_model):
    return {"table": 0.02 * jax.random.normal(key, (vocab, d_model),
                                              jnp.float32)}


def embed(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def unembed(p, x, dtype):
    """Logits via the (tied or separate) vocab projection, fp32 out."""
    return jnp.einsum("bsd,vd->bsv", x.astype(dtype),
                      p["table"].astype(dtype)).astype(jnp.float32)
