"""Sharding rules: map every parameter / activation to a PartitionSpec on
the (pod, data, model) production mesh.

Strategy (DESIGN.md §5): FSDP-style — weight matrices shard their d_model
dim over ``data`` and their heads/ff/expert dim over ``model`` (TP/EP);
``pod`` and ``data`` both carry batch for activations.  Every rule is
divisibility-checked against the mesh and falls back to replication for
that dim (e.g. whisper's 6 heads or vocab 51865 on a 16-way model axis),
so ANY config compiles on ANY mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    fsdp: str = "data"
    tp: str = "model"

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    def axis_size(self, name) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            s = 1
            for n in name:
                s *= self.axis_size(n)
            return s
        return self.mesh.shape[name] if name in self.mesh.axis_names else 0

    def fit(self, shape, axes) -> P:
        """Right-align ``axes`` onto ``shape``; drop any axis that does not
        divide its dim (or is absent from the mesh)."""
        full = [None] * (len(shape) - len(axes)) + list(axes)
        out = []
        for dim, ax in zip(shape, full):
            size = self.axis_size(ax)
            out.append(ax if (ax is not None and size > 0
                              and dim % size == 0) else None)
        return P(*out)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, *axes):
        """with_sharding_constraint for activations (divisibility-checked)."""
        return jax.lax.with_sharding_constraint(
            x, self.named(self.fit(x.shape, list(axes))))

    def constrain_batch(self, x, *rest):
        return self.constrain(x, self.batch_axes, *rest)


# ---- parameter rules: matched on (path substring, leaf name) -------------
# axes are right-aligned, so stacked leading dims (layers, experts handled
# explicitly) become None automatically.

def param_spec(rules: Optional[MeshRules], path: str, shape) -> P:
    if rules is None:
        return P()
    F, T = rules.fsdp, rules.tp
    leaf = path.split("/")[-1]
    in_moe = "/moe/" in path or path.endswith("moe")
    table = {
        "table": (T, F),
        # attention
        "wq": (F, T, None),
        "wk": (F, T, None),
        "wv": (F, T, None),
        "wo": (T, None, F),
        # MLA
        "w_dkv": (F, None),
        "w_kr": (F, None),
        "w_uk": (None, T, None),
        "w_uv": (None, T, None),
        # mlp
        "wi_gate": (F, T),
        "wi_up": (F, T),
        # mamba
        "in_proj": (F, T),
        "conv_w": (T, None),
        "x_proj": (T, None),
        "dt_proj": (None, T),
        "A_log": (T, None),
        "D": (T,),
        "out_proj": (T, F),
        "dt_bias": (None,),
        "router": (F, None),
    }
    if in_moe and leaf in ("wi_gate", "wi_up"):
        axes = (T, F, None)            # (E, d, f): EP over model
    elif in_moe and leaf == "wo":
        axes = (T, None, F)            # (E, f, d)
    elif leaf == "wo" and len(shape) == 2:
        axes = (T, F)                  # plain mlp wo (f, d)
    elif leaf == "D" and len(shape) == 1 and shape[0] < 1024:
        axes = (None,)                 # mamba2 per-head D
    elif leaf in table:
        axes = table[leaf]
    else:
        axes = ()                      # norms, biases -> replicate

    # §Perf (arctic iter 3): when heads don't divide the model axis
    # (arctic 56H, llama3/qwen kv=8, whisper 6H), head-sharding silently
    # degrades to REPLICATED compute over TP.  Fall back to sharding the
    # d_model contraction dim on TP instead (one small psum per projection
    # beats a 16x flop replication).
    # (right-aligned: block params carry a leading stacked-layer dim)
    tsz = rules.axis_size(T)
    tail = shape[-3:]
    if leaf in ("wq", "wk", "wv") and len(shape) >= 3 and tsz > 0 \
            and tail[1] % tsz != 0 and tail[0] % tsz == 0 \
            and tail[1] * tail[2] * 2 >= tail[0]:
        # heads don't divide TP and the projection is a significant flop
        # share -> shard the d_model contraction dim instead (one psum per
        # projection beats TP-replicated compute).  Small kv projections
        # (GQA kv=1..8) stay replicated: the psum would cost more than the
        # flops saved.
        axes = (T, None, F)            # (d->TP, heads, hd->FSDP)
    elif leaf == "wo" and len(shape) >= 3 and tsz > 0 \
            and tail[0] % tsz != 0 and tail[1] % tsz == 0:
        axes = (None, T, F)            # (h, hd->TP contraction, d->FSDP)
    return rules.fit(shape, axes)


def tree_pspecs(rules: Optional[MeshRules], params) -> object:
    """PartitionSpec tree matching a params pytree."""

    def walk(path_keys, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_keys)
        return param_spec(rules, path, leaf.shape)

    return jax.tree_util.tree_map_with_path(walk, params)


def tree_shardings(rules: MeshRules, params):
    return jax.tree.map(rules.named, tree_pspecs(rules, params),
                        is_leaf=lambda x: isinstance(x, P))
