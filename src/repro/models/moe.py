"""Mixture-of-Experts FFN: shared experts + routed top-k experts, with an
optional parallel dense-residual MLP (Snowflake Arctic).

Routing uses dense dispatch (einsum over one-hot combine weights) — the
TPU-friendly formulation: every expert computes on the full token set and
the combine tensor zero-masks non-routed pairs.  With experts sharded over
the ``model`` axis this lowers to an all-to-all-free schedule where the
routed compute is E-way parallel.  (A capacity-based dispatch variant is a
known further optimization; see EXPERIMENTS.md §Perf notes.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, init_mlp, mlp


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], d, e),
        # experts stacked on a leading E axis -> shardable over "model"
        "wi_gate": jax.vmap(lambda k: dense_init(k, d, f))(
            jax.random.split(ks[1], e)),
        "wi_up": jax.vmap(lambda k: dense_init(k, d, f))(
            jax.random.split(ks[2], e)),
        "wo": jax.vmap(lambda k: dense_init(k, f, d))(
            jax.random.split(ks[3], e)),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts)
    if cfg.dense_residual_ff:
        p["dense_residual"] = init_mlp(ks[5], d, cfg.dense_residual_ff)
    return p


def moe_forward(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D)."""
    dtype = x.dtype
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dtype))
    logits = logits.astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(gates, k)                 # (B,S,k)
    top_w = top_w / jnp.sum(top_w, -1, keepdims=True)        # renormalize
    # combine[b,s,e] = sum_j top_w[b,s,j] * [top_idx[b,s,j] == e]
    combine = jnp.sum(
        jax.nn.one_hot(top_idx, e, dtype=jnp.float32) * top_w[..., None],
        axis=-2).astype(dtype)                               # (B,S,E)

    # dense dispatch: every expert sees all tokens, combine masks the rest
    gate_h = jnp.einsum("bsd,edf->ebsf", x, p["wi_gate"].astype(dtype))
    up_h = jnp.einsum("bsd,edf->ebsf", x, p["wi_up"].astype(dtype))
    h = jax.nn.silu(gate_h) * up_h
    expert_out = jnp.einsum("ebsf,efd->ebsd", h, p["wo"].astype(dtype))
    out = jnp.einsum("ebsd,bse->bsd", expert_out, combine)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x, dtype)
    if cfg.dense_residual_ff:
        out = out + mlp(p["dense_residual"], x, dtype)
    return out


def moe_forward_capacity(p, cfg: ModelConfig, x, rules=None):
    """GROUPED capacity-based dispatch (§Perf optimization, GShard-style).

    Each expert processes at most C = S * top_k / E * capacity_factor
    tokens PER SEQUENCE (group = batch row).  vs dense dispatch this cuts
    expert FLOPs by E/(top_k*cf); vs a flat global top-C it keeps routing
    LOCAL to each row, so the gather never crosses the batch sharding —
    the data axis stays fully parallel (§Perf arctic iteration 4: a global
    gather made XLA replicate the expert GEMM over the data axis, 16x).
    Overflow tokens beyond capacity drop their lowest-priority expert.
    """
    dtype = x.dtype
    B, S, D = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(S * k / e * cfg.capacity_factor)
    cap = min(max(cap, 1), S)

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dtype))
    gates = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_w, top_idx = jax.lax.top_k(gates, k)                  # (B, S, k)
    top_w = top_w / jnp.sum(top_w, -1, keepdims=True)
    # priority[b, s, e] = gate weight if e routed for (b, s) else -inf
    routed = jnp.sum(jax.nn.one_hot(top_idx, e) * top_w[..., None], -2)
    priority = jnp.where(routed > 0, routed, -jnp.inf)        # (B, S, E)
    # each expert picks its top-C tokens within each row
    pri_w, tok_idx = jax.lax.top_k(
        priority.transpose(0, 2, 1), cap)                     # (B, E, C)
    w = jnp.where(jnp.isfinite(pri_w), pri_w, 0.0).astype(dtype)

    # within-row gather: batch sharding is preserved
    gidx = tok_idx.reshape(B, e * cap)
    gathered = jnp.take_along_axis(x, gidx[..., None], axis=1)
    gathered = gathered.reshape(B, e, cap, D)                 # (B, E, C, D)
    gate_h = jnp.einsum("becd,edf->becf", gathered,
                        p["wi_gate"].astype(dtype))
    up_h = jnp.einsum("becd,edf->becf", gathered,
                      p["wi_up"].astype(dtype))
    h = jax.nn.silu(gate_h) * up_h
    eo = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dtype))
    eo = eo * w[..., None]
    # within-row combine (scatter-add back to token positions)
    out = jnp.zeros((B, S, D), dtype)
    out = out.at[jnp.arange(B)[:, None], gidx].add(
        eo.reshape(B, e * cap, D))

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x, dtype)
    if cfg.dense_residual_ff:
        out = out + mlp(p["dense_residual"], x, dtype)
    return out


def moe_apply(p, cfg: ModelConfig, x, rules=None):
    if cfg.moe_impl == "capacity":
        return moe_forward_capacity(p, cfg, x, rules=rules)
    return moe_forward(p, cfg, x)


def aux_load_balance_loss(p, cfg: ModelConfig, x):
    """Switch-style load-balance auxiliary (fraction * prob per expert)."""
    dtype = jnp.float32
    logits = jnp.einsum("bsd,de->bse", x.astype(dtype),
                        p["router"].astype(dtype))
    gates = jax.nn.softmax(logits, -1)
    _, top_idx = jax.lax.top_k(gates, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(top_idx, cfg.n_experts), axis=(0, 1, 2))
    prob = jnp.mean(gates, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * prob)
