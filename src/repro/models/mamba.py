"""Mamba-1 (S6 selective scan) and Mamba-2 (SSD, scalar-decay heads)
blocks, with O(1)-state single-token decode — this is what makes the
``long_500k`` cell runnable for falcon-mamba / zamba2 when full attention
is quadratic-history.

Sequence mixing uses ``jax.lax.associative_scan`` over the time axis
(parallel prefix — TPU-friendly log-depth instead of a 4096-step serial
loop).  The recurrence h_t = a_t * h_{t-1} + b_t is associative in
(a, b):  (a2, b2) ∘ (a1, b1) = (a1*a2, a2*b1 + b2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def _ssm_scan(decay, inp):
    """Associative scan of h_t = decay_t * h_{t-1} + inp_t along axis 1.
    decay/inp: (B, L, ...) same shape."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (decay, inp), axis=1)
    return h


def _chunked_ssm(decay, drive, Cc, chunk: int):
    """Memory-bounded SSM: scan over chunks of the time axis; inside a
    chunk, a log-depth associative scan materializes h for only ``chunk``
    steps, contracts with C immediately, and carries the boundary state.

    decay/drive: (B, L, *state_shape) — state_shape e.g. (di, n) for
    mamba1, (nh, hd, n) for mamba2.  Cc: (B, L, n).
    Returns y: (B, L, *state_shape[:-1]) — h contracted over the last
    (state) axis.
    """
    B, L = drive.shape[:2]
    state_shape = drive.shape[2:]
    ck = min(chunk, L)
    pad = (-L) % ck
    if pad:
        zpad = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        decay, drive, Cc = zpad(decay), zpad(drive), zpad(Cc)
    nc = (L + pad) // ck

    def to_chunks(x):
        return jnp.moveaxis(
            x.reshape(B, nc, ck, *x.shape[2:]), 1, 0)      # (nc, B, ck, ...)

    dec_c, drv_c, C_c = to_chunks(decay), to_chunks(drive), to_chunks(Cc)
    h0 = jnp.zeros((B, *state_shape), drive.dtype)

    def body(h, xs):
        d, dr, cc = xs                                     # (B, ck, ...)
        h_rel = _ssm_scan(d, dr)
        cum = jnp.cumprod(d, axis=1)                       # prod of decays
        h_abs = h_rel + cum * h[:, None]
        y = jnp.einsum("bl...n,bln->bl...", h_abs, cc)
        return h_abs[:, -1], y

    _, ys = jax.lax.scan(body, h0, (dec_c, drv_c, C_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L + pad, *state_shape[:-1])
    return y[:, :L]


def _causal_conv(x, w, state=None):
    """Depthwise causal conv1d.  x: (B, L, C), w: (C, K).
    With ``state`` (B, K-1, C) given, performs the streaming update and
    returns (y, new_state)."""
    K = w.shape[1]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # y[b, t, c] = sum_k pad[b, t+k, c] * w[c, k]
    y = sum(pad[:, k:k + x.shape[1], :] * w[:, k].astype(x.dtype)
            for k in range(K))
    if state is None:
        return y
    return y, pad[:, -(K - 1):, :]


# ====================================================================
# Mamba-1
# ====================================================================

def init_mamba1(key, cfg: ModelConfig):
    d, di, n, kk = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    ks = jax.random.split(key, 6)
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": 0.1 * jax.random.normal(ks[1], (di, kk), jnp.float32),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * n),
        "dt_proj": dense_init(ks[3], dt_rank, di),
        "dt_bias": jnp.zeros((di,), jnp.float32) - 4.6,   # softplus ~ 0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)).copy()),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d),
    }


def _mamba1_ssm_inputs(p, xc, dtype):
    """Shared between train & decode: B, C, dt from the conv output."""
    di, n = p["A_log"].shape
    dt_rank = p["x_proj"].shape[1] - 2 * n
    proj = jnp.einsum("bld,de->ble", xc, p["x_proj"].astype(dtype))
    dt_in, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt_in, p["dt_proj"].astype(dtype))
        .astype(jnp.float32) + p["dt_bias"])               # (B, L, di)
    A = -jnp.exp(p["A_log"])                               # (di, n)
    decay = jnp.exp(dt[..., None] * A)                     # (B, L, di, n)
    drive = (dt[..., None] * Bc[:, :, None, :].astype(jnp.float32)
             * xc[..., None].astype(jnp.float32))          # (B, L, di, n)
    return decay, drive, Cc


def mamba1_forward(p, cfg: ModelConfig, x):
    """x: (B, L, D) -> (B, L, D)."""
    dtype = x.dtype
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dtype))
    xr, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xr, p["conv_w"]))
    decay, drive, Cc = _mamba1_ssm_inputs(p, xc, dtype)
    y = _chunked_ssm(decay, drive, Cc.astype(jnp.float32), chunk=64)
    y = (y + p["D"] * xc.astype(jnp.float32)).astype(dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(dtype))


def mamba1_decode(p, cfg: ModelConfig, x, state):
    """x: (B, 1, D); state: (conv_state (B, K-1, di), h (B, di, n))."""
    dtype = x.dtype
    conv_s, h = state
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dtype))
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, conv_s = _causal_conv(xr, p["conv_w"], conv_s)
    xc = jax.nn.silu(xc)
    decay, drive, Cc = _mamba1_ssm_inputs(p, xc, dtype)
    h = decay[:, 0] * h + drive[:, 0]                      # (B, di, n)
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0].astype(jnp.float32))
    y = (y + p["D"] * xc[:, 0].astype(jnp.float32)).astype(dtype)[:, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(dtype))
    return out, (conv_s, h)


def init_mamba1_state(cfg: ModelConfig, batch, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    return (jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
            jnp.zeros((batch, di, n), jnp.float32))


# ====================================================================
# Mamba-2 (SSD): per-head scalar decay, outer-product state (hd x n)
# ====================================================================

def init_mamba2(key, cfg: ModelConfig):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.mamba_headdim
    ks = jax.random.split(key, 4)
    return {
        # fused input proj -> [x(di), z(di), B(n), C(n), dt(nh)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + nh),
        "conv_w": 0.1 * jax.random.normal(ks[1], (di + 2 * n, cfg.d_conv),
                                          jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32) - 4.6,
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[2], di, d),
    }


def _mamba2_parts(p, cfg, zxbcdt, conv_state=None):
    di, n = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.mamba_headdim
    z, xbc, dt_in = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    if conv_state is None:
        xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"]))
        new_conv = None
    else:
        xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_state)
        xbc = jax.nn.silu(xbc)
    xr, Bc, Cc = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])  # (B,L,nh)
    a = -jnp.exp(p["A_log"])                                        # (nh,)
    decay = jnp.exp(dt * a)                                         # (B,L,nh)
    return z, xr, Bc, Cc, dt, decay, new_conv


def _ssd_chunked(xh, Bc, Cc, dt, decay, chunk: int):
    """Mamba-2 SSD block decomposition (matmul form — MXU-friendly).

    Per chunk of length c (per head, scalar decay a_t):
      g        = cumsum(log a)                      (c,)
      L[i, j]  = exp(g_i - g_j) for j <= i else 0   (c, c)
      Y_intra  = ((C B^T) o L) @ (dt * x)           2 GEMMs on the MXU
      Y_inter  = exp(g) * (C @ h_in^T)              1 GEMM
      h_out    = exp(g_c) h_in + X^T diag(exp(g_c - g) dt) B

    vs the elementwise associative scan this trades the (B, c, nh, hd, n)
    f32 state tensor for (c, c)-per-head logits — the dominant
    memory-term cut for the zamba2 cells (§Perf).
    xh: (B, L, nh, hd) f32; Bc/Cc: (B, L, n); dt/decay: (B, L, nh).
    Returns y: (B, L, nh, hd).
    """
    B_, L, nh, hd = xh.shape
    n = Bc.shape[-1]
    ck = min(chunk, L)
    pad = (-L) % ck
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)]
                                 + [(0, 0)] * (t.ndim - 2))
        xh, Bc, Cc, dt, decay = map(zpad, (xh, Bc, Cc, dt, decay))
        # padded decay=0 -> log blows up; clamp to 1 (state just carries)
        decay = decay.at[:, L:].set(1.0)
        nonpad = jnp.zeros_like(dt).at[:, :L].set(1.0)
        dt = dt * nonpad
    nc = (L + pad) // ck

    def chunks(t):
        return t.reshape(B_, nc, ck, *t.shape[2:])

    xh_c, B_c, C_c, dt_c, dec_c = map(chunks, (xh, Bc, Cc, dt, decay))
    g = jnp.cumsum(jnp.log(jnp.maximum(dec_c, 1e-37)), axis=2)  # (B,nc,c,nh)
    # intra-chunk: T[i,j] = exp(g_i - g_j) masked causal, per head
    rel = g[:, :, :, None, :] - g[:, :, None, :, :]             # (B,nc,c,c,nh)
    causal = jnp.tril(jnp.ones((ck, ck), bool))[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(rel), 0.0)
    CB = jnp.einsum("bkin,bkjn->bkij", C_c.astype(jnp.float32),
                    B_c.astype(jnp.float32))                    # (B,nc,c,c)
    M = CB[..., None] * Lmat                                    # (B,nc,c,c,nh)
    Xdt = xh_c * dt_c[..., None]                                # (B,nc,c,nh,hd)
    y_intra = jnp.einsum("bkijh,bkjhd->bkihd", M, Xdt)

    # inter-chunk: scan the (nh, hd, n) state across chunks
    glast = g[:, :, -1:, :]                                     # (B,nc,1,nh)
    wexp = jnp.exp(glast - g)                                   # (B,nc,c,nh)
    # h_chunk[k] = sum_i exp(g_last - g_i) dt_i x_i B_i^T   (B,nc,nh,hd,n)
    h_chunk = jnp.einsum("bkihd,bkin->bkhdn", Xdt * wexp[..., None],
                         B_c.astype(jnp.float32))

    dec_chunk = jnp.exp(glast[:, :, 0, :])                      # (B,nc,nh)

    def body(h, xs):
        dk, hk, gk, ck_ = xs          # per-chunk tensors (B, ...)
        y_inter = jnp.einsum("bin,bhdn,bih->bihd",
                             ck_.astype(jnp.float32), h, jnp.exp(gk))
        h = dk[..., None, None] * h + hk
        return h, y_inter

    h0 = jnp.zeros((B_, nh, hd, n), jnp.float32)
    xs = (jnp.moveaxis(dec_chunk, 1, 0), jnp.moveaxis(h_chunk, 1, 0),
          jnp.moveaxis(g, 1, 0), jnp.moveaxis(C_c, 1, 0))
    _, y_inter = jax.lax.scan(body, h0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    y = y.reshape(B_, L + pad, nh, hd)
    return y[:, :L]


def mamba2_forward(p, cfg: ModelConfig, x):
    dtype = x.dtype
    di, n = cfg.d_inner, cfg.ssm_state
    hd = cfg.mamba_headdim
    nh = di // hd
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dtype))
    z, xr, Bc, Cc, dt, decay, _ = _mamba2_parts(p, cfg, zxbcdt)
    B_, L = x.shape[:2]
    xh = xr.reshape(B_, L, nh, hd).astype(jnp.float32)
    if cfg.ssm_impl == "ssd":
        y = _ssd_chunked(xh, Bc.astype(jnp.float32),
                         Cc.astype(jnp.float32), dt, decay, chunk=64)
    else:
        # elementwise associative-scan reference path
        drive = (dt[..., None, None] * xh[..., None]
                 * Bc[:, :, None, None, :].astype(jnp.float32))
        decay_b = jnp.broadcast_to(decay[..., None, None], drive.shape)
        y = _chunked_ssm(decay_b, drive, Cc.astype(jnp.float32), chunk=64)
    y = y + p["D"][:, None] * xh
    y = y.reshape(B_, L, di).astype(dtype)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_scale"]).astype(dtype)
    return jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(dtype))


def mamba2_decode(p, cfg: ModelConfig, x, state):
    dtype = x.dtype
    di, n = cfg.d_inner, cfg.ssm_state
    hd = cfg.mamba_headdim
    nh = di // hd
    conv_s, h = state
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dtype))
    z, xr, Bc, Cc, dt, decay, conv_s = _mamba2_parts(p, cfg, zxbcdt, conv_s)
    B_ = x.shape[0]
    xh = xr[:, 0].reshape(B_, nh, hd).astype(jnp.float32)
    drive = (dt[:, 0, :, None, None] * xh[..., None]
             * Bc[:, 0, None, None, :].astype(jnp.float32))
    h = decay[:, 0, :, None, None] * h + drive                # (B,nh,hd,n)
    y = jnp.einsum("bhdn,bn->bhd", h, Cc[:, 0].astype(jnp.float32))
    y = y + p["D"][:, None] * xh
    y = y.reshape(B_, 1, di).astype(dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_scale"]).astype(dtype)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(dtype))
    return out, (conv_s, h)


def init_mamba2_state(cfg: ModelConfig, batch, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.mamba_headdim
    return (jnp.zeros((batch, cfg.d_conv - 1, di + 2 * n), dtype),
            jnp.zeros((batch, nh, cfg.mamba_headdim, n), jnp.float32))
