"""AdamW with bias correction, decoupled weight decay, global-norm clip,
and linear-warmup + cosine-decay schedule.  Pure pytree implementation —
moments inherit the parameter sharding (ZeRO-style: with params sharded
over (data, model), so are m and v; the update is fully local)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * pf * (p.ndim >= 2))
        return pf.astype(p.dtype), m, v

    p_flat, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(state["m"])
    v_flat = treedef.flatten_up_to(state["v"])
    trip = [upd(p, g, m, v) for p, g, m, v
            in zip(p_flat, g_flat, m_flat, v_flat)]
    new_params = treedef.unflatten([t[0] for t in trip])
    new_m = treedef.unflatten([t[1] for t in trip])
    new_v = treedef.unflatten([t[2] for t in trip])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm}
