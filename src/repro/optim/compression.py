"""Gradient compression for the data-parallel all-reduce: int8 block
quantization with error feedback.

Composable with the s-step deferred all-reduce (``train.defer_s``): the
deferred accumulator is quantized once per sync instead of per microbatch,
so the bandwidth saving multiplies the paper-style latency saving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _blockify(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def compress_int8(x):
    """-> (q: int8 blocks, scale: f32 per block, meta) with |err| <= scale/254."""
    blocks, pad = _blockify(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, (x.shape, pad)


def decompress_int8(q, scale, meta):
    shape, pad = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def error_feedback_compress(grads, residual):
    """Quantize (grads + residual); the quantization error becomes the new
    residual (error feedback keeps the compressed SGD unbiased over time).
    Returns (decompressed-after-roundtrip grads, new_residual).  In a real
    deployment the int8 payload is what crosses the network; here the
    roundtrip models it exactly."""

    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, s, meta = compress_int8(tot)
        deq = decompress_int8(q, s, meta)
        return deq.astype(g.dtype), tot - deq

    g_flat, treedef = jax.tree.flatten(grads)
    r_flat = treedef.flatten_up_to(residual)
    pairs = [one(g, r) for g, r in zip(g_flat, r_flat)]
    deq = treedef.unflatten([t[0] for t in pairs])
    new_r = treedef.unflatten([t[1] for t in pairs])
    return deq, new_r


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
