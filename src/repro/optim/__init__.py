from .adamw import AdamWConfig, adamw_init, adamw_update
from .compression import (compress_int8, decompress_int8,
                          error_feedback_compress)
