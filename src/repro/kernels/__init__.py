"""Pallas TPU kernels (validated in interpret mode on CPU):

  gram.py            fused kernel-slab GEMM + {linear,poly,rbf} epilogue
                     (the paper's hot spot: K(A, Omega^T A))
  kmv.py             fused gram·matvec K(A, B)^T X — the slab-free
                     GramOperator backend (DESIGN.md §2)
  flash_attention.py flash attention fwd + bwd (FlashAttention-2 style)
  rmsnorm.py         fused RMSNorm

ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles.
"""
from . import ops, ref
