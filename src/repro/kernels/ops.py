"""Public jit'd wrappers for the Pallas kernels.

``gram`` dispatches to the fused Pallas kernel on TPU and to interpret mode
(Python-evaluated kernel body — bit-identical control flow) elsewhere, so
the same call sites run everywhere.  Pass ``force_ref=True`` to get the
pure-jnp oracle (used by tests and as the XLA-fusion baseline in §Perf).

``REPRO_SANITIZE=1`` in the environment forces interpret mode EVERYWHERE
(TPU included): the kernel bodies run under the Python evaluator, where
out-of-bounds block reads and NaN/Inf propagation are observable — the
runtime half of the ``repro.analysis`` sanitizer (the pytest fixture in
``tests/conftest.py`` adds ``jax_debug_nans``/``jax_debug_infs`` on top
for the kernel test modules).
"""
from __future__ import annotations

import os

import jax

from repro.core.kernels import (ExactGramOperator, KernelConfig,
                                StreamingGramOperator)
from .gram import gram_pallas
from .kmv import kmv_pallas
from .kmv_stream import kmv_stream_pallas
from .ref import gram_ref, kmv_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sanitize_mode() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") == "1"


def _interpret(explicit=None) -> bool:
    if sanitize_mode():
        return True
    return (not on_tpu()) if explicit is None else explicit


def gram(A, B, cfg: KernelConfig, *, force_ref: bool = False, **tiles):
    if force_ref:
        return gram_ref(A, B, cfg)
    return gram_pallas(A, B, cfg, interpret=_interpret(), **tiles)


def kmv(A, B, X, cfg: KernelConfig, *, force_ref: bool = False, **tiles):
    """Fused ``K(A, B)^T X`` — the slab-free gram·matvec (DESIGN.md §2).
    Pallas on TPU, interpret mode elsewhere; ``force_ref`` materializes
    the slab (oracle / XLA-fusion baseline)."""
    if force_ref:
        return kmv_ref(A, B, X, cfg)
    return kmv_pallas(A, B, X, cfg, interpret=_interpret(), **tiles)


def kmv_stream(Xc, B, Xvc, cfg: KernelConfig, *, force_ref: bool = False,
               **kw):
    """Out-of-core ``K(A, B)^T X`` over CHUNKED data (DESIGN.md §14):
    the double-buffered DMA pipeline kernel on TPU, interpret mode
    elsewhere; ``force_ref`` flattens the chunks and materializes the
    slab (oracle)."""
    if force_ref:
        nc, cr, n = Xc.shape
        return kmv_ref(Xc.reshape(nc * cr, n), B,
                       Xvc.reshape(nc * cr, -1), cfg)
    return kmv_stream_pallas(Xc, B, Xvc, cfg, interpret=_interpret(), **kw)


def make_streaming_op_factory(chunk_rows: int, use_pallas: bool = True,
                              interpret=None):
    """op_factory for out-of-core solves: a ``StreamingGramOperator``
    whose streamed contraction runs the double-buffered DMA Pallas
    kernel (``kernels/kmv_stream.py``) — chunk i+1 copies in while
    chunk i contracts, so neither X nor any m-tall slab is ever
    VMEM/HBM-working-set resident.  ``use_pallas=False`` keeps the
    lax.scan fallback (the facade's default off-TPU)."""
    impl = None
    if use_pallas:
        interp = _interpret(interpret)

        def impl(Xc, B, Xvc, cfg):
            return kmv_stream_pallas(Xc, B, Xvc, cfg,
                                     interpret=interp).astype(Xvc.dtype)

    def factory(A, cfg):
        return StreamingGramOperator.from_dense(A, cfg,
                                                chunk_rows=chunk_rows,
                                                matvec_impl=impl)

    return factory


def sdpa_flash(q, k, v, causal=True, interpret=None, bq=256, bk=256):
    """Flash attention on (B, S, H, hd)-layout tensors (model convention).
    Returns (B, S, H, hdv).  K/V must already be head-repeated (GQA)."""
    from .flash_attention import flash_attention
    B, S, H, hd = q.shape
    T = k.shape[1]
    hdv = v.shape[-1]
    interp = _interpret(interpret)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, T, hdv)
    o = flash_attention(qt, kt, vt, causal, None, bq, bk, interp)
    return o.reshape(B, H, S, hdv).transpose(0, 2, 1, 3)


def make_solver_gram_fn(use_pallas: bool = True):
    """gram_fn for the core solvers' MATERIALIZED-slab path (matches
    core.kernels.gram_slab's signature).  On non-TPU backends interpret
    mode is slow, so solvers default to the jnp path there unless
    explicitly forced."""
    if not use_pallas:
        return None

    def fn(A, B, cfg):
        return gram(A, B, cfg).astype(A.dtype)

    return fn


def make_solver_op_factory(use_pallas: bool = True, interpret=None,
                           **tiles):
    """op_factory for the core solvers: a slab-free ``GramOperator`` whose
    matvec runs the fused Pallas KMV kernel — the m x sb slab never
    touches HBM.  Returns None (= jnp slab-free default) when
    ``use_pallas`` is False."""
    if not use_pallas:
        return None
    interp = _interpret(interpret)

    def matvec_impl(A, B, X, cfg):
        return kmv_pallas(A, B, X, cfg, interpret=interp,
                          **tiles).astype(X.dtype)

    def factory(A, cfg):
        return ExactGramOperator(A, cfg, matvec_impl=matvec_impl)

    return factory


def rmsnorm(x, scale, eps: float = 1e-6, interpret=None):
    """Fused RMSNorm (TPU Pallas; interpret-mode elsewhere)."""
    from .rmsnorm import rmsnorm_pallas
    return rmsnorm_pallas(x, scale, eps=eps, interpret=_interpret(interpret))
