"""Fused kernel-matrix·vector (KMV) Pallas TPU kernel.

Computes ``U^T X`` with ``U = K(A, B)`` — the slab-free contraction behind
``core.kernels.GramOperator.matvec`` — WITHOUT ever materializing the
``m x r`` kernel slab in HBM (DESIGN.md §2, EXPERIMENTS.md §Perf).

The s-step solvers only ever consume the slab through ``U^T alpha`` (plus
the tiny ``(sb x sb)`` cross block computed separately), yet the
materialized path writes and re-reads all ``m * s*b`` words every round.
This kernel streams ``(bm x bk)`` tiles of A, runs the GEMM on the MXU,
applies the Table-1 epilogue (linear/poly/RBF with folded row/col squared
norms) on the VPU while the f32 accumulator tile is VMEM-resident, then
immediately contracts the finished ``(bm x br)`` kernel tile against the
matching ``(c x bm)`` X^T tile (second MXU op) into a ``(c x br)`` VMEM
accumulator.  HBM traffic per round: read A once, read B, read X — zero
slab bytes (the ``2 * m * s*b`` word round-trip of the materialized path
disappears; see ``core.perf_model.kmv_round_hbm_bytes``).

Grid: (r/br, m/bm, n/bk) = (j, i, k); j parallel, i and k arbitrary so the
(c x br) output block stays resident across the whole (i, k) sweep.

The same contraction serves PREDICTION (DESIGN.md §9): with B = a query
block and X = the model weights, ``U^T X = K(Xq, A_train) @ w`` — the
batched predict subsystem (``core/predict.py``) tiles queries through
this kernel via ``ExactGramOperator.serve_block``, so the ``q x m``
test-kernel slab never exists either; the j-parallel grid axis then
ranges over queries, which is embarrassingly parallel across serving
batches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kernels import LINEAR, POLYNOMIAL, RBF, KernelConfig
from .gram import _CompilerParams, _pad_to, _round_up, _sublane


def _kmv_kernel(a_ref, b_ref, xt_ref, o_ref, acc_ref, oacc_ref, rs_ref,
                cs_ref, *, kernel_name: str, degree: int, coef0: float,
                sigma: float, m_steps: int, k_steps: int):
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(i == 0, k == 0))
    def _init_out():
        oacc_ref[...] = jnp.zeros_like(oacc_ref)

    @pl.when(k == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if kernel_name == RBF:
            rs_ref[...] = jnp.zeros_like(rs_ref)
            cs_ref[...] = jnp.zeros_like(cs_ref)

    a = a_ref[...]                                   # (bm, bk)
    b = b_ref[...]                                   # (br, bk)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # MXU
    if kernel_name == RBF:
        af = a.astype(jnp.float32)
        bf = b.astype(jnp.float32)
        rs_ref[...] += jnp.sum(af * af, axis=1, keepdims=True)
        cs_ref[...] += jnp.sum(bf * bf, axis=1, keepdims=True)

    @pl.when(k == k_steps - 1)
    def _epilogue_and_contract():                    # VPU then MXU, in VMEM
        dots = acc_ref[...]
        if kernel_name == LINEAR:
            ktile = dots
        elif kernel_name == POLYNOMIAL:
            ktile = (coef0 + dots) ** degree
        else:                                        # RBF
            sq = rs_ref[...] + cs_ref[...].T - 2.0 * dots
            ktile = jnp.exp(-sigma * jnp.maximum(sq, 0.0))
        xt = xt_ref[...].astype(jnp.float32)         # (c, bm)
        oacc_ref[...] += jax.lax.dot_general(
            xt, ktile, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (c, br)

    @pl.when(jnp.logical_and(i == m_steps - 1, k == k_steps - 1))
    def _emit():
        o_ref[...] = oacc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "bm", "br", "bk", "interpret", "out_dtype"))
def kmv_pallas(A: jnp.ndarray, B: jnp.ndarray, X: jnp.ndarray,
               cfg: KernelConfig, *, bm: int = 128, br: int = 128,
               bk: int = 512, interpret: bool = False,
               out_dtype=jnp.float32):
    """``U^T X`` for ``U = K(A, B)``; A: (m, n), B: (r, n), X: (m,)|(m, c).

    Returns (r,) / (r, c) in ``out_dtype``.  Shapes need not be
    block-aligned — inputs are zero-padded and the output sliced back.
    Padding is contraction-safe: padded X rows are zero, so the (nonzero
    for RBF/poly) kernel values of padded A rows contribute nothing, and
    padded B columns are sliced off before any consumer sees them.
    """
    vec = X.ndim == 1
    Xt = (X[None, :] if vec else X.T)                # (c, m)
    m, n = A.shape
    r, n2 = B.shape
    assert n == n2 and Xt.shape[1] == m, (A.shape, B.shape, X.shape)
    c = Xt.shape[0]

    sub = max(_sublane(A.dtype), _sublane(Xt.dtype))
    bm_ = _round_up(min(bm, _round_up(m, sub)), sub)
    br_ = _round_up(min(br, _round_up(r, sub)), sub)
    bk_ = min(bk, _round_up(n, 128))
    c_ = _round_up(c, sub)

    Ap = _pad_to(_pad_to(A, bm_, 0), bk_, 1)
    Bp = _pad_to(_pad_to(B, br_, 0), bk_, 1)
    Xp = _pad_to(_pad_to(Xt, c_, 0), bm_, 1)
    M, N = Ap.shape
    R = Bp.shape[0]
    m_steps, k_steps = M // bm_, N // bk_
    grid = (R // br_, m_steps, k_steps)

    kern = functools.partial(
        _kmv_kernel, kernel_name=cfg.name, degree=cfg.degree,
        coef0=cfg.coef0, sigma=cfg.sigma, m_steps=m_steps, k_steps=k_steps)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda j, i, k: (i, k)),
            pl.BlockSpec((br_, bk_), lambda j, i, k: (j, k)),
            pl.BlockSpec((c_, bm_), lambda j, i, k: (0, i)),
        ],
        out_specs=pl.BlockSpec((c_, br_), lambda j, i, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((c_, R), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm_, br_), jnp.float32),     # kernel-tile acc
            pltpu.VMEM((c_, br_), jnp.float32),      # output acc
            pltpu.VMEM((bm_, 1), jnp.float32),       # RBF row sqnorms
            pltpu.VMEM((br_, 1), jnp.float32),       # RBF col sqnorms
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(Ap, Bp, Xp)
    out = out[:c, :r]
    return out[0] if vec else out.T
