"""Streaming (out-of-core) KMV Pallas kernel: double-buffered DMA.

Computes ``U^T X`` with ``U = K(A, B)`` for an A that does NOT live in
fast device memory: A arrives pre-chunked as ``Xc: (nc, cr, n)`` row
blocks resident in HBM/host (``TPUMemorySpace.ANY`` — the pipelined
BlockSpec machinery never touches it), together with the equally chunked
right-hand side ``Xvc: (nc, cr, c)``.  The kernel owns TWO VMEM slots
per stream and overlaps the DMA of chunk ``i+1`` with the contraction of
chunk ``i`` — the flash-attention double-buffering idiom
(``kernels/flash_attention.py``), written out with manual
``make_async_copy``/semaphore pairs because the chunk axis is a data
axis, not a grid axis:

    warm-up: start DMA of chunk 0 into slot 0
    loop i:  start DMA of chunk i+1 into slot (i+1)%2   (prefetch)
             wait  DMA of chunk i   in   slot i%2       (consume)
             dots  = chunk_i @ B^T          (MXU)
             ktile = epilogue(dots)         (VPU, Table-1 kernel)
             acc  += ktile^T @ x_i          (MXU)

Steady state the pipe pays ``max(t_dma, t_compute)`` per chunk instead
of the sum — ``core.perf_model.stream_pipeline_cost`` prices exactly
this overlap, and ``repro.analysis``'s CHK-DMA check statically verifies
the wait-before-read and slot-alternation invariants of this loop.

Zero-padding is contraction-safe exactly as in ``kmv.kmv_pallas``: the
tail chunk's padded rows carry zero ``x`` rows, so their (nonzero for
RBF/poly) kernel values contribute nothing, and padded B columns are
sliced off by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kernels import LINEAR, POLYNOMIAL, RBF, KernelConfig
from .gram import _pad_to, _round_up, _sublane


def _kmv_stream_kernel(xc_hbm, xvc_hbm, b_ref, o_ref, *,
                       kernel_name: str, degree: int, coef0: float,
                       sigma: float, nc: int):
    """xc_hbm: (nc, cr, n) ANY, xvc_hbm: (nc, cr, c) ANY,
    b_ref: (r, n) VMEM, o_ref: (r, c) VMEM."""
    cr, n = xc_hbm.shape[1], xc_hbm.shape[2]
    c = xvc_hbm.shape[2]

    def body(a_buf, x_buf, a_sem, x_sem, acc):
        bt = b_ref[...].astype(jnp.float32)              # (r, n)
        if kernel_name == RBF:
            cs = jnp.sum(bt * bt, axis=1)                # (r,)
        # warm-up: fill slot 0 while the loop below sets up
        pltpu.make_async_copy(xc_hbm.at[0], a_buf.at[0],
                              a_sem.at[0]).start()
        pltpu.make_async_copy(xvc_hbm.at[0], x_buf.at[0],
                              x_sem.at[0]).start()
        acc[...] = jnp.zeros_like(acc)

        def loop(i, _):
            slot = jax.lax.rem(i, 2)
            nxt = jax.lax.rem(i + 1, 2)

            @pl.when(i + 1 < nc)
            def _prefetch():                 # DMA chunk i+1 into the
                pltpu.make_async_copy(       # OTHER slot while chunk i
                    xc_hbm.at[i + 1], a_buf.at[nxt],      # computes
                    a_sem.at[nxt]).start()
                pltpu.make_async_copy(
                    xvc_hbm.at[i + 1], x_buf.at[nxt],
                    x_sem.at[nxt]).start()

            pltpu.make_async_copy(xc_hbm.at[i], a_buf.at[slot],
                                  a_sem.at[slot]).wait()
            pltpu.make_async_copy(xvc_hbm.at[i], x_buf.at[slot],
                                  x_sem.at[slot]).wait()
            a = a_buf[slot].astype(jnp.float32)          # (cr, n)
            x = x_buf[slot].astype(jnp.float32)          # (cr, c)
            dots = jax.lax.dot_general(
                a, bt, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)      # (cr, r) MXU
            if kernel_name == LINEAR:
                ktile = dots
            elif kernel_name == POLYNOMIAL:
                ktile = (coef0 + dots) ** degree
            else:                                        # RBF
                rs = jnp.sum(a * a, axis=1)              # (cr,)
                sq = rs[:, None] + cs[None, :] - 2.0 * dots
                ktile = jnp.exp(-sigma * jnp.maximum(sq, 0.0))
            acc[...] += jax.lax.dot_general(
                ktile, x, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)      # (r, c) MXU

        jax.lax.fori_loop(0, nc, loop, None)
        o_ref[...] = acc[...].astype(o_ref.dtype)

    pl.run_scoped(
        body,
        a_buf=pltpu.VMEM((2, cr, n), xc_hbm.dtype),
        x_buf=pltpu.VMEM((2, cr, c), xvc_hbm.dtype),
        a_sem=pltpu.SemaphoreType.DMA((2,)),
        x_sem=pltpu.SemaphoreType.DMA((2,)),
        acc=pltpu.VMEM(o_ref.shape, jnp.float32))


@functools.partial(jax.jit, static_argnames=("cfg", "interpret",
                                             "out_dtype"))
def kmv_stream_pallas(Xc: jnp.ndarray, B: jnp.ndarray, Xvc: jnp.ndarray,
                      cfg: KernelConfig, *, interpret: bool = False,
                      out_dtype=jnp.float32):
    """``U^T X`` for ``U = K(A, B)`` with A CHUNKED out-of-core.

    Xc: (nc, cr, n) chunked rows of A (zero-padded tail), Xvc:
    (nc, cr, c) the identically chunked right-hand side, B: (r, n).
    Returns (r, c) in ``out_dtype``.  Shapes need not be aligned —
    chunk rows, features, r and c are zero-padded (contraction-safe,
    module docstring) and the output is sliced back.
    """
    nc, cr, n = Xc.shape
    r, n2 = B.shape
    nc2, cr2, c = Xvc.shape
    assert n == n2 and nc == nc2 and cr == cr2, (Xc.shape, B.shape,
                                                 Xvc.shape)
    sub = max(_sublane(Xc.dtype), _sublane(Xvc.dtype))
    cr_ = _round_up(cr, sub)
    n_ = _round_up(n, 128)
    r_ = _round_up(r, sub)
    c_ = _round_up(c, 128)

    Xp = _pad_to(_pad_to(Xc, cr_, 1), n_, 2)
    Bp = _pad_to(_pad_to(B, r_, 0), n_, 1)
    Vp = _pad_to(_pad_to(Xvc, cr_, 1), c_, 2)

    kern = functools.partial(
        _kmv_stream_kernel, kernel_name=cfg.name, degree=cfg.degree,
        coef0=cfg.coef0, sigma=cfg.sigma, nc=nc)

    out = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec((r_, n_), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((r_, c_), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r_, c_), out_dtype),
        interpret=interpret,
    )(Xp, Vp, Bp)
    return out[:r, :c]
