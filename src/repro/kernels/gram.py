"""Fused kernel-slab (gram) Pallas TPU kernel.

Computes ``K(A, B) = epilogue(A @ B^T)`` for the paper's three kernels
(Table 1) WITHOUT materializing the pre-epilogue dot-product slab in HBM.

Why this kernel exists (DESIGN.md §2): the paper pays ``mu * s*b*m`` for the
pointwise exp/pow AND streams the m x sb slab HBM->core->HBM twice (GEMM
write + epilogue read/write).  On TPU we tile the GEMM onto the MXU and run
the epilogue on the VPU while the f32 accumulator tile is still resident in
VMEM — one HBM write total, and the separate row/col squared-norm passes
for RBF are folded into the same k-loop.

Grid: (m/bm, r/br, n/bk), k innermost ("arbitrary"), so each (i, j) output
tile accumulates across k steps in VMEM scratch and applies the epilogue at
the final k step.

TPU alignment: block shapes are multiples of (8, 128) for f32 / (16, 128)
for bf16; the MXU sees (bm x bk) @ (bk x br) with bm=br=128, bk=512 by
default (A tile 256KB + B tile 256KB + acc 64KB << 16MB VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kernels import LINEAR, POLYNOMIAL, RBF, KernelConfig

from repro.compat import CompilerParams as _CompilerParams


def _gram_kernel(a_ref, b_ref, o_ref, acc_ref, rs_ref, cs_ref, *,
                 kernel_name: str, degree: int, coef0: float, sigma: float,
                 k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if kernel_name == RBF:
            rs_ref[...] = jnp.zeros_like(rs_ref)
            cs_ref[...] = jnp.zeros_like(cs_ref)

    a = a_ref[...]                                   # (bm, bk)
    b = b_ref[...]                                   # (br, bk)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # MXU
    if kernel_name == RBF:
        af = a.astype(jnp.float32)
        bf = b.astype(jnp.float32)
        rs_ref[...] += jnp.sum(af * af, axis=1, keepdims=True)
        cs_ref[...] += jnp.sum(bf * bf, axis=1, keepdims=True)

    @pl.when(k == k_steps - 1)
    def _epilogue():                                 # VPU, VMEM-resident
        dots = acc_ref[...]
        if kernel_name == LINEAR:
            out = dots
        elif kernel_name == POLYNOMIAL:
            out = (coef0 + dots) ** degree
        else:                                        # RBF
            sq = rs_ref[...] + cs_ref[...].T - 2.0 * dots
            out = jnp.exp(-sigma * jnp.maximum(sq, 0.0))
        o_ref[...] = out.astype(o_ref.dtype)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "bm", "br", "bk", "interpret", "out_dtype"))
def gram_pallas(A: jnp.ndarray, B: jnp.ndarray, cfg: KernelConfig,
                *, bm: int = 128, br: int = 128, bk: int = 512,
                interpret: bool = False, out_dtype=jnp.float32):
    """K(A, B) with A: (m, n), B: (r, n) -> (m, r) in ``out_dtype``.

    Shapes need not be block-aligned — inputs are zero-padded and the
    output sliced back (zero padding is epilogue-safe: padded rows/cols are
    discarded before any consumer sees them).
    """
    m, n = A.shape
    r, n2 = B.shape
    assert n == n2, (A.shape, B.shape)
    sub = _sublane(A.dtype)
    bm_ = _round_up(min(bm, _round_up(m, sub)), sub)
    br_ = _round_up(min(br, _round_up(r, sub)), sub)
    bk_ = min(bk, _round_up(n, 128))
    Ap = _pad_to(_pad_to(A, bm_, 0), bk_, 1)
    Bp = _pad_to(_pad_to(B, br_, 0), bk_, 1)
    M, N = Ap.shape
    R = Bp.shape[0]
    k_steps = N // bk_
    grid = (M // bm_, R // br_, k_steps)

    kern = functools.partial(
        _gram_kernel, kernel_name=cfg.name, degree=cfg.degree,
        coef0=cfg.coef0, sigma=cfg.sigma, k_steps=k_steps)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((br_, bk_), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm_, br_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, R), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm_, br_), jnp.float32),
            pltpu.VMEM((bm_, 1), jnp.float32),
            pltpu.VMEM((br_, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(Ap, Bp)
    return out[:m, :r]


def _sublane(dtype) -> int:
    """Minimum TPU sublane multiple for ``dtype`` ((8, 128) f32 tiles,
    (16, 128) bf16 — see pallas_guide Tiling Constraints)."""
    return 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8


def _round_up(x, mult: int = 8):
    return ((x + mult - 1) // mult) * mult
