"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.kernels import KernelConfig, gram_slab


def gram_ref(A: jnp.ndarray, B: jnp.ndarray, cfg: KernelConfig,
             out_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for kernels/gram.py: epilogue(A @ B^T) in f32 accumulation."""
    return gram_slab(A.astype(jnp.float32), B.astype(jnp.float32),
                     cfg).astype(out_dtype)


def kmv_ref(A: jnp.ndarray, B: jnp.ndarray, X: jnp.ndarray,
            cfg: KernelConfig, out_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for kernels/kmv.py: ``K(A, B)^T X`` with the slab
    materialized in f32 (the thing the fused kernel must never do)."""
    U = gram_slab(A.astype(jnp.float32), B.astype(jnp.float32), cfg)
    return (U.T @ X.astype(jnp.float32)).astype(out_dtype)


def flash_attention_ref(q, k, v, causal=True, scale=None):
    """Oracle for kernels/flash_attention.py.  q/k/v: (BH, S|T, hd)."""
    hd = q.shape[-1]
    scale = scale if scale is not None else hd ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((S, T), bool))
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
