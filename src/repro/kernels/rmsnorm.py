"""Fused RMSNorm Pallas TPU kernel.

The jnp rmsnorm upcasts to f32, computes the mean-square, rsqrt, scales,
and downcasts — on the pre-fusion HLO that is 4+ passes over the (.., D)
activation (a visible slice of every train cell's memory term).  The
kernel performs the whole chain on a VMEM-resident row tile: one HBM read
+ one write per element.

Grid: (rows / block_rows,); each step loads a (block_rows, D) tile, the
full scale vector, and normalizes in-register.  D is the model dim
(128-multiple for every assigned arch except whisper's 384 = 3 x 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                  # (rows, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_pallas(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False):
    """x: (..., D), scale: (D,) -> same shape/dtype as x."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    xr = x.reshape(rows, D)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((rows + pad), D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xr, scale)
    return out[:rows].reshape(orig_shape)
