"""Flash attention (forward + backward) as Pallas TPU kernels.

Why: the naive attention path materializes the (S x S) logit tensor in HBM
~10 times per layer (fwd chain + bwd + remat recompute) — the dominant
HBM-traffic term of every full-attention training/prefill cell in the
roofline table.  Flash attention keeps the softmax chain VMEM-resident:
HBM sees only Q, K, V, O (+ the (S,) logsumexp), cutting attention HBM
bytes from O(S^2) to O(S * hd) per row block.

Layout: inputs are (BH, S, hd) — batch and heads flattened by the ops.py
wrapper.  Grid (BH, S/bq, T/bk) with the KV index innermost ("arbitrary");
running max / sum / accumulator live in VMEM scratch across the KV loop
(the online-softmax recurrence).  Causal blocks strictly above the
diagonal are skipped with pl.when (no MXU work, no HBM reads counted).

Backward follows FlashAttention-2: a dq kernel (grid over q blocks) and a
dkv kernel (grid over kv blocks), each recomputing the block probabilities
from the saved logsumexp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams

NEG = -1e30


# ---------------------------------------------------------------- fwd -----

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, scale, causal, bq, bk, k_steps):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = True
    if causal:
        run = ik * bk <= iq * bq + bq - 1     # block intersects lower tri

    @pl.when(run if causal else True)
    def _block():
        q = q_ref[0]                           # (bq, hd)
        k = k_ref[0]                           # (bk, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG)
        m_prev = m_ref[...]                    # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, 1, keepdims=True))
        p = jnp.exp(s - m_new)                 # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)        # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, 1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l))[:, 0]


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "interpret"))
def flash_fwd(q, k, v, *, causal=True, scale=None, bq=256, bk=256,
              interpret=False):
    """q: (BH, S, hd), k/v: (BH, T, hd) -> (o (BH,S,hd), lse (BH,S))."""
    BH, S, hd = q.shape
    T = k.shape[1]
    hdv = v.shape[-1]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    scale = scale if scale is not None else hd ** -0.5
    k_steps = T // bk
    grid = (BH, S // bq, T // bk)
    kern = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                             bq=bq, bk=bk, k_steps=k_steps)
    o, lse = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hdv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hdv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hdv), q.dtype),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, hdv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------- bwd -----

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, bq, bk, k_steps):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        run = ik * bk <= iq * bq + bq - 1

    @pl.when(run if causal else True)
    def _block():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG)
        p = jnp.exp(s - lse_ref[0][:, None])              # (bq, bk)
        do = do_ref[0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale     # (bq, bk)
        acc_ref[...] += jax.lax.dot_general(
            ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == k_steps - 1)
    def _final():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, causal, bq, bk, q_steps):
    ik, iq = pl.program_id(1), pl.program_id(2)   # kv block outer, q inner

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    run = True
    if causal:
        run = ik * bk <= iq * bq + bq - 1         # q block reaches kv block

    @pl.when(run if causal else True)
    def _block():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG)
        p = jnp.exp(s - lse_ref[0][:, None])              # (bq, bk)
        do = do_ref[0].astype(jnp.float32)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bk, hd)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bk, hd)

    @pl.when(iq == q_steps - 1)
    def _final():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "interpret"))
def flash_bwd(q, k, v, o, lse, do, *, causal=True, scale=None,
              bq=256, bk=256, interpret=False):
    BH, S, hd = q.shape
    T = k.shape[1]
    hdv = v.shape[-1]
    bq = min(bq, S)
    bk = min(bk, T)
    scale = scale if scale is not None else hd ** -0.5
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                               # (BH, S)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, k_steps=T // bk),
        grid=(BH, S // bq, T // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hdv), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq, hdv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, q_steps=S // bq),
        grid=(BH, T // bk, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, hdv), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bq, hdv), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, hdv), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, hd), k.dtype),
            jax.ShapeDtypeStruct((BH, T, hdv), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hdv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ------------------------------------------------------- public entry -----

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, scale=None, bq=256, bk=256,
                    interpret=False):
    """Differentiable flash attention.  q/k/v: (BH, S|T, hd)."""
    o, _ = flash_fwd(q, k, v, causal=causal, scale=scale, bq=bq, bk=bk,
                     interpret=interpret)
    return o


def _fa_fwd(q, k, v, causal, scale, bq, bk, interpret):
    o, lse = flash_fwd(q, k, v, causal=causal, scale=scale, bq=bq, bk=bk,
                       interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, scale, bq, bk, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do, causal=causal, scale=scale,
                           bq=bq, bk=bk, interpret=interpret)
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)
