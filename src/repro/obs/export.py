"""Chrome-trace / Perfetto export of recorded telemetry (DESIGN.md §15).

``to_chrome_trace`` turns a ``Telemetry`` handle's span/mark log into
the Chrome Trace Event JSON format (the ``traceEvents`` array of
"X"/"B"/"E"/"i" events, microsecond timestamps) that chrome://tracing
and https://ui.perfetto.dev open directly.  Host spans land on the
"host" track, traced marks on the "traced" track; per-event args carry
the span's free-form payload.

``validate_chrome_trace`` is the schema check the tests and the fig11
benchmark gate on: required keys per event, non-negative ts/dur,
balanced per-track B/E nesting.
"""
from __future__ import annotations

import json
from typing import List, Optional

HOST_TID = 1
TRACED_TID = 2
PID = 1

_PHASES = {"X", "B", "E", "i", "M"}


def to_chrome_trace(telemetry, *, process_name: str = "repro") -> dict:
    """Serialize ``telemetry`` (obs/spans.Telemetry) to a Chrome-trace
    dict.  Timestamps rebase to the earliest recorded event so the
    trace starts near t=0."""
    window = telemetry.window()
    base = window[0] if window else 0.0

    def us(t: float) -> float:
        return (t - base) * 1e6

    events: List[dict] = [
        {"name": "process_name", "ph": "M", "pid": PID, "tid": 0,
         "args": {"name": process_name}},
        {"name": "thread_name", "ph": "M", "pid": PID, "tid": HOST_TID,
         "args": {"name": "host"}},
        {"name": "thread_name", "ph": "M", "pid": PID, "tid": TRACED_TID,
         "args": {"name": "traced"}},
    ]
    for s in telemetry.spans:
        events.append({"name": s.name, "cat": s.phase, "ph": "X",
                       "ts": us(s.t0), "dur": max(us(s.t1) - us(s.t0), 0.0),
                       "pid": PID, "tid": HOST_TID,
                       "args": {str(k): v for k, v in s.args.items()}})
    # traced begin/end marks export as paired complete ("X") events:
    # unordered-callback arrival can interleave B/E of different names,
    # which strict B/E stack nesting would reject — pairing first keeps
    # the trace valid while preserving the measured intervals
    for s in telemetry.paired_marks():
        events.append({"name": s.name, "cat": s.phase, "ph": "X",
                       "ts": us(s.t0), "dur": max(us(s.t1) - us(s.t0), 0.0),
                       "pid": PID, "tid": TRACED_TID,
                       "args": {str(k): v for k, v in s.args.items()}})
    for m in telemetry.marks:
        if m.kind != "i":
            continue
        args = {} if m.value is None else {"value": m.value}
        events.append({"name": m.name, "cat": m.phase, "ph": "i",
                       "ts": us(m.t), "s": "t", "pid": PID,
                       "tid": TRACED_TID, "args": args})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_chrome_trace(trace: dict) -> None:
    """Raise ``ValueError`` unless ``trace`` is structurally valid
    Chrome Trace Event JSON (the subset this exporter emits plus B/E
    pairs): a ``traceEvents`` list whose entries carry name/ph/pid/tid,
    timestamps where required, and balanced per-(pid, tid) B/E stacks."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    stacks = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} missing required key {key!r}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        if ph != "M":
            if "ts" not in ev:
                raise ValueError(f"event {i} ({ph}) missing 'ts'")
            if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
                raise ValueError(f"event {i} has invalid ts {ev['ts']!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i} (X) has invalid dur {dur!r}")
        if ph in ("B", "E"):
            stack = stacks.setdefault((ev["pid"], ev["tid"]), [])
            if ph == "B":
                stack.append(ev["name"])
            else:
                if not stack or stack.pop() != ev["name"]:
                    raise ValueError(
                        f"event {i}: unbalanced E for {ev['name']!r} "
                        f"on track {(ev['pid'], ev['tid'])}")
    for track, stack in stacks.items():
        if stack:
            raise ValueError(
                f"track {track} left {len(stack)} B events unclosed: "
                f"{stack}")
    # must round-trip as JSON (chrome://tracing reads a file)
    json.dumps(trace)


def save_trace(path: str, telemetry, *,
               process_name: str = "repro") -> str:
    """Export + schema-check + write; returns ``path``."""
    trace = to_chrome_trace(telemetry, process_name=process_name)
    validate_chrome_trace(trace)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
    return path


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
