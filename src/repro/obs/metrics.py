"""Process-local metrics registry (DESIGN.md §15).

Three instrument kinds — ``Counter`` (monotone), ``Gauge`` (last
value), ``Histogram`` (fixed buckets, derived quantiles) — behind one
``MetricsRegistry`` with Prometheus-text and JSON exports.  Adopted by
``serve/engine.py`` (queue depth, ticket outcomes, batch occupancy,
latency histogram), the guarded executors in ``repro.api`` (drift
corrections, fallback escalations) and ``tune/autotune`` (probe
outcomes).

Naming scheme: ``repro_<subsystem>_<what>[_<unit>]`` — e.g.
``repro_serve_ticket_latency_seconds`` — with Prometheus conventions
(``_total`` for counters, base units, labels for low-cardinality
dimensions like ticket status).  Everything is plain host Python: no
jax, no locks (the engine and executors are single-threaded hosts), no
global state unless you opt into ``default_registry()``.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Iterable, Optional, Tuple

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_labels(key: _LabelKey, extra: Tuple[Tuple[str, str], ...] = ()
                   ) -> str:
    items = key + extra
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


class _Bound:
    """A counter/gauge pre-resolved to one label set.  ``labels()``
    builds the key ONCE; hot paths (per-ticket engine counters) then
    pay a single dict add per ``inc`` instead of rebuilding the sorted
    label tuple on every call (~4x cheaper — the fig11 gate prices
    this)."""

    __slots__ = ("_inst", "_key", "_floor")

    def __init__(self, inst, key, floor):
        self._inst = inst
        self._key = key
        self._floor = floor

    def inc(self, value: float = 1.0) -> None:
        if self._floor and value < 0:
            raise ValueError(f"counter {self._inst.name} cannot "
                             f"decrease (inc by {value})")
        vals = self._inst._values
        vals[self._key] = vals.get(self._key, 0.0) + value

    def set(self, value: float) -> None:
        if self._floor:
            raise TypeError(f"counter {self._inst.name} has no set()")
        self._inst._values[self._key] = float(value)


class Counter:
    """Monotonically increasing value, optionally split by labels."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[_LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc by {value})")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + value

    def labels(self, **labels) -> _Bound:
        """Pre-resolve a label set for hot-path increments."""
        return _Bound(self, _label_key(labels), self.kind == "counter")

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def expose(self):
        for key, v in sorted(self._values.items()):
            yield f"{self.name}{_render_labels(key)} {v:g}"

    def to_json(self):
        return {_render_labels(k) or "": v
                for k, v in sorted(self._values.items())}


class Gauge(Counter):
    """Last-written value (``set``) with counter-style labels; ``inc``
    accepts negative deltas."""

    kind = "gauge"

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + value

    def set(self, value: float, **labels) -> None:
        self._values[_label_key(labels)] = float(value)


class Histogram:
    """Fixed-bucket histogram: cumulative-at-export bucket counts, sum,
    count, and bucket-interpolated derived quantiles (``quantile`` —
    exact within a bucket's resolution, which is all an SLO gate
    needs)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = (
                     1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0,
                     5.0, 10.0)):
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError(f"histogram {name} needs >= 1 bucket bound")
        self.name = name
        self.help = help
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # last slot = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        value = float(value)
        self.sum += value
        self.count += 1
        for i, b in enumerate(self.bounds):
            if value <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def quantile(self, q: float) -> float:
        """Derived quantile by linear interpolation inside the owning
        bucket; NaN when empty.  The overflow bucket clamps to its
        lower bound (no upper edge to interpolate toward)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return float("nan")
        target = q * self.count
        cum, lo = 0.0, 0.0
        for i, c in enumerate(self.counts):
            hi = self.bounds[i] if i < len(self.bounds) else math.inf
            if c and cum + c >= target:
                if math.isinf(hi):
                    return lo
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
            lo = hi if not math.isinf(hi) else lo
        return lo

    def expose(self):
        cum = 0
        for i, b in enumerate(self.bounds):
            cum += self.counts[i]
            yield f'{self.name}_bucket{{le="{b:g}"}} {cum}'
        cum += self.counts[-1]
        yield f'{self.name}_bucket{{le="+Inf"}} {cum}'
        yield f"{self.name}_sum {self.sum:g}"
        yield f"{self.name}_count {self.count}"

    def to_json(self):
        return {"buckets": {f"{b:g}": c
                            for b, c in zip(self.bounds, self.counts)},
                "overflow": self.counts[-1], "sum": self.sum,
                "count": self.count,
                "p50": self.quantile(0.5), "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Create-or-fetch instrument registry.  Re-requesting a name
    returns the existing instrument; a kind clash raises (one name, one
    meaning — the exposition format requires it)."""

    def __init__(self):
        self._instruments: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        inst = self._instruments.get(name)
        if inst is not None:
            if not isinstance(inst, cls) or type(inst) is not cls:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst
        inst = cls(name, help, **kw)
        self._instruments[name] = inst
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        kw = {} if buckets is None else {"buckets": buckets}
        return self._get(Histogram, name, help, **kw)

    def __iter__(self):
        return iter(sorted(self._instruments.items()))

    def __len__(self):
        return len(self._instruments)

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition (format 0.0.4): ``# HELP`` /
        ``# TYPE`` headers plus one sample line per series."""
        lines = []
        for name, inst in self:
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            lines.extend(inst.expose())
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> str:
        return json.dumps(
            {name: {"kind": inst.kind, "help": inst.help,
                    "values": inst.to_json()}
             for name, inst in self}, indent=1)


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry — for callers that want one shared
    scrape target instead of per-``Telemetry`` isolation."""
    return _DEFAULT
