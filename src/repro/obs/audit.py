"""Modeled-vs-measured reconciler (DESIGN.md §15).

``audit_fit`` takes a ``FitResult`` whose solve recorded telemetry and
reconciles where the time actually went against where
``perf_model.modeled_fit_cost`` said it would go — turning the fig4 /
fig10 ad-hoc "measured vs modeled" comparisons into a reusable
per-phase report.

Phase mapping (modeled bucket <- measured evidence):

  setup       ``comm["setup_time"]`` (Nystrom build; 0 for exact)
              <- host spans with phase "setup" (representation_build)
  compute     ``t_comp - setup_time`` (gram slab + epilogue flops)
              <- solve-phase span time minus the in-loop check/correct
              intervals paired from traced marks
  collective  ``t_band + t_lat`` <- not separable on a single host
              (collectives execute inside the fused solve region);
              reported modeled-only, measured merged into compute
  check       unpriced by the model (tolerance checks are a protocol
              choice, not an algorithm cost) <- paired "metric_check"
              begin/end marks
  correct     ``guard_overhead(...) * compute`` at the resolved
              cadence <- paired "drift_correction" marks

Each phase's MEASURED SHARE of the measured total is compared with its
MODELED SHARE of the modeled total; a phase whose measured evidence
exists and deviates more than ``tol`` (absolute share points) is
FLAGGED.  The report also carries the total measured/modeled ratio —
the PR 9 "measured ~0.4x vs modeled" style gap, now first-class.

Timestamps from traced marks are approximate (obs/spans.py module
docstring); shares over a whole solve smooth that out, which is why
the audit never reports mark-derived absolute latencies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.perf_model import guard_overhead

CHECK_SPAN = "metric_check"
CORRECT_SPAN = "drift_correction"


@dataclasses.dataclass
class PhaseRow:
    """One reconciled phase: seconds and shares on both sides, the
    share deviation (measured - modeled), and the flag.  ``measured_s``
    is None when the run produced no separable evidence for the phase
    (then the row is informational and never flagged)."""

    phase: str
    modeled_s: float
    modeled_share: float
    measured_s: Optional[float]
    measured_share: Optional[float]
    deviation: Optional[float]
    flagged: bool
    note: str = ""


@dataclasses.dataclass
class AuditReport:
    """The per-phase reconciliation ``audit_fit`` returns."""

    rows: List[PhaseRow]
    measured_total_s: float
    modeled_total_s: float
    tol: float

    @property
    def ratio(self) -> float:
        """measured / modeled total time (the fig4/fig10 headline)."""
        if self.modeled_total_s <= 0:
            return float("nan")
        return self.measured_total_s / self.modeled_total_s

    @property
    def flagged(self) -> List[PhaseRow]:
        return [r for r in self.rows if r.flagged]

    def to_dict(self) -> dict:
        return {"rows": [dataclasses.asdict(r) for r in self.rows],
                "measured_total_s": self.measured_total_s,
                "modeled_total_s": self.modeled_total_s,
                "ratio": self.ratio, "tol": self.tol,
                "flagged": [r.phase for r in self.flagged]}

    def render(self) -> str:
        hdr = (f"{'phase':<12} {'modeled_s':>10} {'share':>7} "
               f"{'measured_s':>11} {'share':>7} {'dev':>7}  flag")
        lines = [hdr, "-" * len(hdr)]
        for r in self.rows:
            ms = "-" if r.measured_s is None else f"{r.measured_s:.4g}"
            sh = "-" if r.measured_share is None \
                else f"{r.measured_share:.1%}"
            dv = "-" if r.deviation is None else f"{r.deviation:+.1%}"
            lines.append(
                f"{r.phase:<12} {r.modeled_s:>10.4g} "
                f"{r.modeled_share:>7.1%} {ms:>11} {sh:>7} {dv:>7}  "
                f"{'FLAG' if r.flagged else ''}")
        lines.append(f"total: measured {self.measured_total_s:.4g}s vs "
                     f"modeled {self.modeled_total_s:.4g}s "
                     f"(ratio {self.ratio:.2f}, tol {self.tol:.0%})")
        return "\n".join(lines)


def _fit_window(tel):
    """The last recorded top-level "fit" span — one handle can record
    several solves; the audit reads the most recent."""
    fits = [s for s in tel.spans if s.phase == "fit"]
    return fits[-1] if fits else None


def _within(spans, window):
    if window is None:
        return list(spans)
    return [s for s in spans if s.t0 >= window.t0 - 1e-9
            and s.t1 <= window.t1 + 1e-9]


def audit_fit(result, telemetry=None, *, tol: float = 0.25
              ) -> AuditReport:
    """Reconcile ``result`` (a ``FitResult``) against its recorded
    telemetry (``result.telemetry`` unless an explicit handle is
    passed).  Raises ``ValueError`` when the run recorded nothing —
    fit with ``SolverOptions(telemetry=True)`` first."""
    tel = telemetry if telemetry is not None else \
        getattr(result, "telemetry", None)
    if tel is None or (not tel.spans and not tel.marks):
        raise ValueError(
            "audit_fit needs a recorded solve: fit with "
            "SolverOptions(telemetry=True) (or telemetry=<Telemetry>) "
            "and pass the resulting FitResult")

    window = _fit_window(tel)
    spans = _within(tel.spans, window)
    paired = _within(tel.paired_marks(), window)

    measured_setup = sum(s.duration for s in spans
                         if s.phase == "setup")
    solve_s = sum(s.duration for s in spans if s.phase == "solve")
    check_s = sum(s.duration for s in paired if s.name == CHECK_SPAN)
    correct_s = sum(s.duration for s in paired if s.name == CORRECT_SPAN)
    # in-loop intervals are inside the solve spans; keep buckets disjoint
    compute_s = max(solve_s - check_s - correct_s, 0.0)
    measured_total = (window.duration if window is not None
                      else max(getattr(result, "wall_time_s", 0.0),
                               measured_setup + solve_s))

    comm = result.comm
    modeled_setup = float(comm.get("setup_time", 0.0))
    modeled_compute = max(float(comm["t_comp"]) - modeled_setup, 0.0)
    modeled_coll = float(comm.get("t_band", 0.0)) \
        + float(comm.get("t_lat", 0.0))
    opts = getattr(result, "options", None)
    modeled_correct = 0.0
    rec = getattr(opts, "recompute_every", 0) if opts is not None else 0
    if isinstance(rec, int) and rec >= 1 and "m" in comm:
        frac = guard_overhead(
            int(comm["m"]), int(comm["n"]), comm.get("kernel", "rbf"),
            b=int(comm.get("b", 1)), s=int(comm.get("s", 1)),
            P=int(comm.get("P", 1)), recompute_every=rec,
            approx=comm.get("approx"),
            landmarks=int(comm.get("landmarks", 0)))
        modeled_correct = frac * modeled_compute
    modeled_total = (modeled_setup + modeled_compute + modeled_coll
                     + modeled_correct)

    def share(x, total):
        return x / total if total > 0 else 0.0

    rows = []
    for phase, mod_s, meas_s, note in (
            ("setup", modeled_setup, measured_setup, ""),
            ("compute", modeled_compute, compute_s,
             "measured includes unseparable collectives"),
            ("collective", modeled_coll, None,
             "not separable on-host; merged into measured compute"),
            ("check", 0.0, check_s, "unpriced by the model"),
            ("correct", modeled_correct, correct_s, "")):
        mshare = share(mod_s, modeled_total)
        if meas_s is None:
            rows.append(PhaseRow(phase, mod_s, mshare, None, None, None,
                                 False, note))
            continue
        pshare = share(meas_s, measured_total)
        dev = pshare - mshare
        rows.append(PhaseRow(phase, mod_s, mshare, meas_s, pshare, dev,
                             abs(dev) > tol, note))
    return AuditReport(rows=rows, measured_total_s=measured_total,
                       modeled_total_s=modeled_total, tol=tol)
