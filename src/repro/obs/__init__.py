"""repro.obs — unified telemetry (DESIGN.md §15).

* ``spans``   — the ``Telemetry`` handle: host spans + traced marks at
  the round protocol's existing sync points.
* ``metrics`` — counters/gauges/histograms with Prometheus/JSON export.
* ``audit``   — modeled-vs-measured per-phase reconciliation of a fit
  against ``perf_model.modeled_fit_cost``.
* ``export``  — Chrome-trace/Perfetto JSON of any recorded window.

CLI: ``python -m repro.obs {report,trace,scrape}``.

Import note: ``core/loop.py`` imports ``obs.spans`` from inside the
round drivers, so this package __init__ stays dependency-light — the
audit (which imports ``repro.core.perf_model``) loads lazily via
module ``__getattr__`` to keep ``repro.core`` -> ``repro.obs`` acyclic.
"""
from .metrics import (Counter, Gauge, Histogram,  # noqa: F401
                      MetricsRegistry, default_registry)
from .spans import (Mark, Span, Telemetry, active_telemetry,  # noqa: F401
                    chunk_mark, span_begin, span_end)

_LAZY = {
    "audit_fit": "audit", "AuditReport": "audit", "PhaseRow": "audit",
    "to_chrome_trace": "export", "validate_chrome_trace": "export",
    "save_trace": "export", "load_trace": "export",
}

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry", "Mark", "Span", "Telemetry",
           "active_telemetry", "chunk_mark", "span_begin", "span_end",
           *_LAZY]


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.obs' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
