"""Span/trace recorder for solves and serving windows (DESIGN.md §15).

Two kinds of observation, one ``Telemetry`` handle:

  * HOST SPANS — ``with tel.span("representation_build", "setup"):`` —
    monotonic-clock intervals around host-side phases (representation
    build, guarded segments, engine steps).  Zero traced footprint.
  * TRACED MARKS — ``span_begin``/``span_end``/``chunk_mark`` — emitted
    INSIDE jitted code via ``jax.debug.callback``, but ONLY at existing
    sync points of the round protocol: the tolerance-check branch and
    the guarded drift-correction branch of ``core/loop.py``'s
    while-loop drivers, and the s-step chunk seams of the chunked
    executors.  The scan fast path has no sync points and carries no
    marks; when marks are off (the static ``marks=False`` flag) the
    traced code is BYTE-IDENTICAL to the uninstrumented driver — zero
    added ops, asserted jaxpr-identical in tests/test_obs.py.

Why a module-level active slot instead of closing over the handle: a
``Telemetry`` captured inside a jitted function would either be a
static arg (retrace per handle — the CHK-STATIC hazard) or baked into
the trace (first handle wins forever through the jit cache).  Instead
the callbacks are MODULE-LEVEL functions that look up the ACTIVE
telemetry at call time (``tel.activate()`` around the jitted call sets
it), so one compiled executable serves every handle — and runs
silently when none is active.  The slot is a plain module global, NOT
a contextvar: ``jax.debug.callback`` executes on runtime threads,
where a contextvar set on the solver thread would be invisible.

Timing caveat: ``jax.debug.callback`` is unordered (the ordered
``io_callback`` is not allowed inside ``lax.cond``/``while_loop``
branches), so mark timestamps are host arrival times near — not
exactly at — the device-side event.  Spans paired from begin/end marks
are therefore approximate; the audit (obs/audit.py) treats them as
shares of wall time, never as absolute truth.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional

import jax

# The process's active recording handle (None = record nothing).  A
# plain global on purpose: debug callbacks fire on runtime threads, so
# thread/context-local storage set by the solver thread would not be
# visible to them.  Solves are driven one at a time per process
# (facade + executors are host-serial), so a single slot suffices.
_ACTIVE: Optional["Telemetry"] = None


def active_telemetry() -> Optional["Telemetry"]:
    """The ``Telemetry`` the process currently records into, or None."""
    return _ACTIVE


@dataclasses.dataclass
class Span:
    """One closed host interval: ``[t0, t1]`` on ``time.perf_counter``'s
    clock, tagged with a phase (setup/solve/serve/fit/...) and free-form
    args."""

    name: str
    phase: str
    t0: float
    t1: float
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class Mark:
    """One instantaneous event.  ``kind`` follows the Chrome-trace
    phase letters: "B" (span begin), "E" (span end), "i" (instant)."""

    name: str
    phase: str
    t: float
    kind: str = "i"
    value: Optional[float] = None


class Telemetry:
    """The recording handle ``SolverOptions(telemetry=...)`` and
    ``ServingEngine(telemetry=...)`` accept (DESIGN.md §15).

    Holds the span/mark log plus a ``MetricsRegistry``
    (counters/gauges/histograms — obs/metrics.py).  ``enabled=False``
    makes every recording call a no-op AND keeps the traced fast paths
    uninstrumented (the facade maps a disabled handle to
    ``marks=False``, the same compiled code as no telemetry at all).
    """

    def __init__(self, *, enabled: bool = True, metrics=None):
        from .metrics import MetricsRegistry
        self.enabled = bool(enabled)
        self.spans: List[Span] = []
        self.marks: List[Mark] = []
        self.metrics = MetricsRegistry() if metrics is None else metrics

    # -- host-side recording -------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, phase: str = "host", **args):
        """Record a closed host span around the with-body (no-op when
        disabled).  The span is appended at EXIT, so the log stays
        ordered by end time."""
        if not self.enabled:
            yield None
            return
        t0 = time.perf_counter()
        try:
            yield None
        finally:
            self.spans.append(Span(name, phase, t0, time.perf_counter(),
                                   dict(args)))

    def mark(self, name: str, phase: str = "host", value=None,
             kind: str = "i") -> None:
        """Record one instant event (no-op when disabled)."""
        if not self.enabled:
            return
        self.marks.append(Mark(name, phase, time.perf_counter(), kind,
                               None if value is None else float(value)))

    @contextlib.contextmanager
    def activate(self):
        """Make this handle the process's active recorder — the target
        of the traced ``span_begin``/``span_end``/``chunk_mark``
        callbacks fired under the with-body.  Disabled handles activate
        as None (callbacks stay silent); the prior handle is restored
        on exit, so activations nest."""
        global _ACTIVE
        prev = _ACTIVE
        _ACTIVE = self if self.enabled else None
        try:
            yield self
        finally:
            _ACTIVE = prev

    # -- derived views --------------------------------------------------

    def window(self):
        """(t_min, t_max) over everything recorded, or None when empty."""
        ts = [s.t0 for s in self.spans] + [m.t for m in self.marks]
        te = [s.t1 for s in self.spans] + [m.t for m in self.marks]
        if not ts:
            return None
        return min(ts), max(te)

    def paired_marks(self) -> List[Span]:
        """Stitch "B"/"E" marks into approximate spans (see module
        docstring for the timing caveat).  Pairing is per-name LIFO in
        record order; unmatched begins are dropped — the CHK-SPAN static
        check (repro.analysis) keeps call sites paired at the source."""
        open_by_name: Dict[str, List[Mark]] = {}
        out: List[Span] = []
        for m in self.marks:
            if m.kind == "B":
                open_by_name.setdefault(m.name, []).append(m)
            elif m.kind == "E" and open_by_name.get(m.name):
                b = open_by_name[m.name].pop()
                args = {} if m.value is None else {"value": m.value}
                out.append(Span(m.name, m.phase, b.t, m.t, args))
        return out

    def clear(self) -> None:
        """Drop every recorded span/mark (metrics are kept — counters
        are cumulative by design)."""
        self.spans.clear()
        self.marks.clear()


# ---------------------------------------------------------------------------
# Traced-side marks.  These are called at TRACE time inside jitted code;
# the partials they stage are module-level functions, so the jit cache
# is stable across Telemetry handles (the handle is resolved at RUN time
# through the contextvar).  Callers gate every call site on a static
# ``marks`` bool — the disabled trace contains no callback at all.
# ---------------------------------------------------------------------------

def _record_mark(name: str, phase: str, kind: str, value=None) -> None:
    tel = _ACTIVE
    if tel is None:
        return
    tel.marks.append(Mark(name, phase, time.perf_counter(), kind,
                          None if value is None else float(value)))


def span_begin(name: str, phase: str = "round") -> None:
    """Open a traced span: emits a "B" mark through an unordered debug
    callback.  MUST be paired with a ``span_end`` of the same name
    inside the same function, at an existing sync point — enforced
    statically by repro.analysis CHK-SPAN."""
    jax.debug.callback(partial(_record_mark, name, phase, "B"))


def span_end(name: str, value=None, phase: str = "round") -> None:
    """Close the traced span opened by ``span_begin(name)``; ``value``
    (a traced scalar) rides along into the mark."""
    if value is None:
        jax.debug.callback(partial(_record_mark, name, phase, "E"))
    else:
        jax.debug.callback(partial(_record_mark, name, phase, "E"), value)


def chunk_mark(name: str, value=None, phase: str = "round") -> None:
    """One traced instant ("i") mark — chunk boundaries, round seams."""
    if value is None:
        jax.debug.callback(partial(_record_mark, name, phase, "i"))
    else:
        jax.debug.callback(partial(_record_mark, name, phase, "i"), value)
