"""``python -m repro.obs`` — record, audit, and export telemetry from
self-contained demo workloads (mirrors the ``repro.analysis`` CLI).

    python -m repro.obs report            # instrumented solve -> audit table
    python -m repro.obs trace --out t.json  # solve + serving window -> trace
    python -m repro.obs scrape            # serving drive -> Prometheus text

Every subcommand fits/serves a small synthetic problem with telemetry
enabled, so the tooling is demonstrable with zero setup; pass --m/--iters
to scale the demo.
"""
from __future__ import annotations

import argparse
import os
import sys


def _demo_fit(m: int, iters: int):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.api import KernelRidge, SolverOptions
    from repro.obs import Telemetry

    key = jax.random.key(0)
    A = jax.random.normal(key, (m, 16), jnp.float32)
    rng = np.random.default_rng(0)
    y = jnp.asarray(np.asarray(A) @ rng.standard_normal(16), A.dtype)
    tel = Telemetry()
    opts = SolverOptions(method="sstep", s=8, b=8, tol=1e-8,
                         check_every=4, max_iters=iters, guard=True,
                         recompute_every=8, telemetry=tel)
    kr = KernelRidge(lam=1.0, kernel="rbf", options=opts)
    result = kr.fit(A, y)
    return result, tel


def _demo_serve(m: int, iters: int, tickets: int):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.api import KernelRidge, SolverOptions
    from repro.obs import Telemetry
    from repro.serve import ModelRegistry, ServingEngine

    key = jax.random.key(1)
    A = jax.random.normal(key, (m, 16), jnp.float32)
    rng = np.random.default_rng(1)
    y = jnp.asarray(np.asarray(A) @ rng.standard_normal(16), A.dtype)
    kr = KernelRidge(lam=1.0, kernel="rbf",
                     options=SolverOptions(method="sstep", s=8, b=8,
                                           max_iters=iters))
    kr.fit(A, y)
    reg = ModelRegistry(predict_batch=32)
    reg.register("krr", kr)
    tel = Telemetry()
    engine = ServingEngine(reg, slots=32, telemetry=tel)
    engine.warmup()
    Q = np.asarray(jax.random.normal(jax.random.key(2), (tickets, 16),
                                     jnp.float32))
    for i in range(tickets):
        engine.submit("krr", Q[i])
        if (i + 1) % 8 == 0:
            engine.step()
    engine.run_until_idle()
    return engine, tel


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="telemetry demos: audit report, Perfetto trace, "
                    "Prometheus scrape")
    # shared demo knobs live on a parent so they parse AFTER the
    # subcommand too (python -m repro.obs report --m 256)
    shared = argparse.ArgumentParser(add_help=False)
    shared.add_argument("--m", type=int, default=192,
                        help="demo problem rows")
    shared.add_argument("--iters", type=int, default=256,
                        help="demo solve iteration budget")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("report", parents=[shared],
                   help="instrumented demo solve -> "
                        "modeled-vs-measured audit table")
    p_trace = sub.add_parser("trace", parents=[shared],
                             help="record a solve + serving window, "
                                  "export Chrome trace")
    p_trace.add_argument("--out", default="repro_trace.json",
                         help="output trace path")
    p_scrape = sub.add_parser("scrape", parents=[shared],
                              help="serving drive -> Prometheus text "
                                   "exposition")
    p_scrape.add_argument("--tickets", type=int, default=64)
    args = ap.parse_args(argv)

    if args.cmd == "report":
        from repro.obs.audit import audit_fit
        result, _tel = _demo_fit(args.m, args.iters)
        report = audit_fit(result)
        print(report.render())
        return 0

    if args.cmd == "trace":
        from repro.obs.export import save_trace
        result, tel = _demo_fit(args.m, args.iters)
        engine, stel = _demo_serve(args.m, args.iters, tickets=32)
        # both windows ride one trace: merge the serving log into the
        # solve handle (timestamps share the perf_counter clock)
        tel.spans.extend(stel.spans)
        tel.marks.extend(stel.marks)
        path = save_trace(os.path.abspath(args.out), tel)
        print(f"wrote {path} ({len(tel.spans)} spans, "
              f"{len(tel.marks)} marks) — open in ui.perfetto.dev")
        return 0

    # scrape
    engine, tel = _demo_serve(args.m, args.iters, tickets=args.tickets)
    sys.stdout.write(tel.metrics.to_prometheus_text())
    return 0


if __name__ == "__main__":
    sys.exit(main())
