"""repro.tune — solver fleets, warm-started regularization paths, and
the perf-model-driven autotuner (DESIGN.md §10).

The paper's experiments are SWEEPS — over s, b, lambda/C, and process
grids — and hyperparameter search is the dominant real workload for
kernel methods at scale.  This subsystem turns the single-solve facade
(repro.api) into a search system:

  * ``solve_fleet``     — F problems, one vmapped computation, one
                          shared operator (tune/fleet.py);
  * ``reg_path``        — warm-started regularization ladder,
                          ``cross_validate`` — k-fold grid search
                          composing fleet + path (tune/path.py);
  * ``resolve_options`` — ``SolverOptions(s="auto", b="auto",
                          layout="auto", approx="auto")`` resolved
                          through the Hockney perf model, optionally
                          refined by measured probe rounds, returning a
                          ``TunedPlan`` (tune/autotune.py).
"""
from .autotune import TunedPlan, resolve_options
from .fleet import FleetResult, solve_fleet
from .path import CVResult, PathResult, cross_validate, reg_path

__all__ = ["TunedPlan", "resolve_options", "FleetResult", "solve_fleet",
           "CVResult", "PathResult", "cross_validate", "reg_path"]
