"""Warm-started regularization paths and k-fold cross-validation
(DESIGN.md §10).

``reg_path`` solves a regularization ladder SEQUENTIALLY, seeding each
solve from its neighbour's solution: dual solutions vary continuously in
the regularizer, so the warm start enters each solve already close to
optimal and the tolerance stopper exits in a fraction of the cold-start
rounds.  The ladder runs from strongest to weakest regularization
(lambda descending; C ascending — 1/C plays lambda's role), the
direction in which the solution path is best-conditioned.  The
representation (DESIGN.md §9) is built ONCE and reused by every rung —
for Nystrom that amortizes the landmark draw, the l x l
eigendecomposition, and the feature-map GEMM across the whole ladder.

``cross_validate`` composes the two sweep subsystems: per fold it solves
the full grid as one vmapped fleet (``tune.fleet``) — or, with
``via="path"``, as one warm-started ladder — then serves every member's
validation predictions through the SHARED operator in one slab-free
sweep (``serve_weights``/``serve_block`` accept (m, F)-stacked fleet
weights), and reports per-fold, per-value scores.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import KRRConfig, SVMConfig

VIAS = ("fleet", "path")


# repro: noqa[CHK-PYTREE] host-side result record — holds per-rung
#   FitResults after solving; never crosses a jit boundary.
@dataclasses.dataclass
class PathResult:
    """A solved regularization ladder: ``results[i]`` is the
    ``FitResult`` at ``values[i]`` (solved order: strongest -> weakest
    regularization), warm-started from ``results[i-1]``; ``op`` is the
    shared representation operator (serve any rung through it)."""

    results: List[object]          # FitResult per rung
    values: np.ndarray             # (F,) ladder, solved order
    param: str                     # "lam" | "C"
    problem: str
    alphas: jnp.ndarray            # (F, m) stacked solutions
    op: object                     # shared representation operator

    def metric_history(self, i: int):
        """Rung i's evaluated convergence trajectory."""
        return self.results[i].metric_history()

    @property
    def total_iters(self) -> int:
        """Inner iterations summed over the ladder — the quantity warm
        starting shrinks vs F independent cold solves."""
        return int(sum(r.iters_run for r in self.results))


def _problem_of(lams, Cs):
    if (lams is None) == (Cs is None):
        raise ValueError("pass exactly one of lams= (K-RR) or Cs= (K-SVM)")
    return ("krr" if Cs is None else "ksvm",
            np.asarray(lams if Cs is None else Cs, dtype=np.float64))


def _ladder(problem, values):
    """Strongest-to-weakest regularization order (module docstring)."""
    if np.any(values <= 0.0):
        raise ValueError("regularization values must be positive")
    return np.sort(values)[::-1] if problem == "krr" else np.sort(values)


def reg_path(A, y, *, lams=None, Cs=None, cfg=None, kernel=None,
             loss: str = "l1", options=None) -> PathResult:
    """Warm-started ladder over a lambda grid (K-RR) or C grid (K-SVM);
    see module docstring.  ``cfg`` (a ``KRRConfig``/``SVMConfig``) fixes
    the kernel and loss — the facade's ``fit_path`` passes its own;
    otherwise one is built from ``kernel``/``loss``.  Set
    ``options.tol`` for the warm starts to pay off: with pure budget
    stopping every rung runs the full ``max_iters`` regardless."""
    from repro.api import (SolverOptions, _as_kernel,
                           _build_representation, _fit)

    problem, values = _problem_of(lams, Cs)
    ladder = _ladder(problem, values)
    opts = options or SolverOptions()
    if cfg is None:
        cfg = (KRRConfig(lam=1.0, kernel=_as_kernel(kernel))
               if problem == "krr"
               else SVMConfig(C=1.0, loss=loss, kernel=_as_kernel(kernel)))

    if opts.needs_autotune:
        from .autotune import resolve_options
        plan = resolve_options(A.shape[0], A.shape[1], cfg, opts,
                               problem=problem, A=A, y=y)
        opts = plan.options

    rep = _build_representation(A, cfg, opts)
    results, alpha = [], None
    for v in ladder:
        cfg_i = (dataclasses.replace(cfg, lam=float(v))
                 if problem == "krr"
                 else dataclasses.replace(cfg, C=float(v)))
        res, _ = _fit(problem, A, y, cfg_i, opts, a0=alpha, rep=rep)
        results.append(res)
        alpha = res.alpha
    return PathResult(results=results, values=ladder,
                      param="lam" if problem == "krr" else "C",
                      problem=problem,
                      alphas=jnp.stack([r.alpha for r in results]),
                      op=rep[0])


# repro: noqa[CHK-PYTREE] host-side result record — scores are gathered
#   on the host across folds; never crosses a jit boundary.
@dataclasses.dataclass
class CVResult:
    """k-fold grid search scores.  ``scores[k, f]`` is fold k's
    validation score at ``values[f]`` (input grid order): MSE for K-RR
    (lower is better), accuracy for K-SVM (higher is better) — see
    ``score_name``.  ``best_value``/``best_index`` pick the grid point
    with the best mean score; ``folds[k]`` keeps fold k's full
    ``FleetResult``/``PathResult`` (solutions, histories, comm model).
    """

    scores: np.ndarray             # (k, F)
    mean_scores: np.ndarray        # (F,)
    values: np.ndarray             # (F,) grid, input order
    param: str
    problem: str
    score_name: str                # "mse" | "accuracy"
    best_index: int
    best_value: float
    folds: List[object]


def _fold_indices(m: int, n_folds: int, seed: int):
    perm = np.random.RandomState(seed).permutation(m)
    return np.array_split(perm, n_folds)


def _score_members(problem, op, alpha_F, values, A_tr, y_tr, A_val,
                   y_val):
    """All F members' validation scores in ONE slab-free serving sweep:
    the shared operator takes the (m, F)-stacked weights through
    ``serve_weights``/``serve_block`` (one KMV for the whole grid)."""
    W = alpha_F.T                                     # (m_tr, F)
    if problem == "ksvm":
        W = W * y_tr[:, None]
    sw = op.serve_weights(W)
    preds = op.serve_block(jnp.asarray(A_val), sw)    # (q, F)
    if problem == "krr":
        preds = preds / jnp.asarray(values, preds.dtype)[None, :]
        err = preds - jnp.asarray(y_val)[:, None]
        return np.asarray(jnp.mean(err * err, axis=0))
    hit = jnp.sign(preds) == jnp.asarray(y_val)[:, None]
    return np.asarray(jnp.mean(hit.astype(jnp.float32), axis=0))


def cross_validate(A, y, *, lams=None, Cs=None, kernel=None,
                   loss: str = "l1", options=None, folds: int = 5,
                   via: str = "fleet", seed: int = 0) -> CVResult:
    """k-fold grid search over a regularization grid; see module
    docstring.  ``via="fleet"`` solves each fold's grid as one vmapped
    fleet; ``via="path"`` as one warm-started ladder."""
    from .fleet import solve_fleet

    if via not in VIAS:
        raise ValueError(f"via must be one of {VIAS}, got {via!r}")
    if not isinstance(folds, int) or folds < 2:
        raise ValueError(f"folds must be an int >= 2, got {folds!r}")
    problem, values = _problem_of(lams, Cs)
    m = A.shape[0]
    if folds > m:
        raise ValueError(f"folds={folds} exceeds m={m}")

    A_h, y_h = np.asarray(A), np.asarray(y)
    scores, fold_results = [], []
    for val_idx in _fold_indices(m, folds, seed):
        tr_mask = np.ones(m, bool)
        tr_mask[val_idx] = False
        A_tr = jnp.asarray(A_h[tr_mask])
        y_tr = jnp.asarray(y_h[tr_mask])
        A_val, y_val = A_h[val_idx], y_h[val_idx]
        kw = ({"lams": values} if problem == "krr" else {"Cs": values})
        if via == "fleet":
            fr = solve_fleet(A_tr, y_tr, kernel=kernel, loss=loss,
                             options=options, **kw)
            alpha_F, op, order = fr.alpha, fr.op, values
        else:
            fr = reg_path(A_tr, y_tr, kernel=kernel, loss=loss,
                          options=options, **kw)
            # ladder order -> input grid order
            pos = {float(v): i for i, v in enumerate(fr.values)}
            sel = jnp.asarray([pos[float(v)] for v in values])
            alpha_F, op, order = fr.alphas[sel], fr.op, values
        fold_results.append(fr)
        scores.append(_score_members(problem, op, alpha_F, order,
                                     A_tr, y_tr, A_val, y_val))
    scores = np.stack(scores)                        # (k, F)
    mean = scores.mean(axis=0)
    best = int(np.argmin(mean) if problem == "krr" else np.argmax(mean))
    return CVResult(scores=scores, mean_scores=mean, values=values,
                    param="lam" if problem == "krr" else "C",
                    problem=problem,
                    score_name="mse" if problem == "krr" else "accuracy",
                    best_index=best, best_value=float(values[best]),
                    folds=fold_results)
