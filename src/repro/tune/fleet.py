"""Vmapped multi-problem solver fleets (DESIGN.md §10).

Hyperparameter search is the dominant real workload for kernel methods:
every point of a lambda/C grid is a FULL solve against the SAME data, so
solving them one at a time re-reads and re-epilogues the same kernel
slabs once per grid point.  A *fleet* instead solves F related problems
in ONE jitted computation: the shared round-protocol loop runs over a
batched state pytree (alpha: (F, m)) with the regularization scalar as
a batched cfg leaf (``make_*_round_fn(..., lam=/C=)``), vmapped per
member.

Why this amortizes the dominant cost: the fleet shares ONE
``GramOperator`` (exact or low-rank — operators are registered pytrees,
DESIGN.md §9).  Under ``jax.vmap`` only values that depend on the batch
axis are batched; the operator's leaves and the round's sampled rows do
not, so the slab GEMM and its nonlinear epilogue — the paper's dominant
per-round terms — are computed ONCE per round for the whole fleet, and
only the O(m)-per-member contraction ``U^T alpha_f``, the O((sb)^2)
correction solves, and the state updates scale with F
(``perf_model.fleet_fit_cost`` prices exactly this split; the measured
counterpart is ``benchmarks/fig7_sweep.py``).

Tolerance stopping is per member (``loop.run_rounds_fleet``): each
member checks its own convergence metric, converged members are frozen
in place (their lockstep updates are masked off), and the loop exits
when the whole fleet is done.

Layouts: ``serial`` vmaps the serial round fns; ``1d`` vmaps INSIDE the
``shard_map`` body, so the per-round psum payload batches only where the
member states do — for nonlinear exact kernels the pre-epilogue
``m x sb`` all-reduce stays SHARED across the fleet (same words as a
single solve).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (KRRConfig, NO_TOL, SVMConfig, kmv_slab_free,
                        block_schedule, coordinate_schedule,
                        make_sstep_bdcd_round_fn, make_sstep_dcd_round_fn,
                        pad_rounds, run_rounds_fleet)
from repro.core.objectives import ksvm_gap_from_Qa, krr_rel_residual_value
from repro.core.perf_model import fleet_fit_cost

FLEET_LAYOUTS = ("serial", "1d")


# repro: noqa[CHK-PYTREE] host-side result record assembled AFTER the
#   jitted fleet chunks return; never re-enters a traced function.
@dataclasses.dataclass
class FleetResult:
    """Everything ``solve_fleet`` observed, fleet-wide.

    ``alpha[f]`` is member f's solution for ``values[f]``;
    ``history[:, f]`` its convergence trajectory (``metric_history``);
    ``comm`` the modeled fleet cost (``perf_model.fleet_fit_cost`` —
    includes the modeled ``sequential_time`` of F independent fits and
    the implied ``modeled_speedup``).
    """

    alpha: jnp.ndarray             # (F, m)
    values: np.ndarray             # (F,) the lambda/C grid, input order
    param: str                     # "lam" | "C"
    problem: str                   # "krr" | "ksvm"
    history: Optional[np.ndarray]  # (checks_run, F) or None
    metric: str                    # "rel_residual" | "duality_gap"
    converged: np.ndarray          # (F,) bool
    rounds_run: int
    iters_run: int
    wall_time_s: float
    comm: dict
    options: object                # the (resolved) SolverOptions
    representation: str
    op: object = None              # shared representation operator
                                   # (raw-data; serve fleet predictions
                                   # through it — see cross_validate)

    def metric_history(self, member: Optional[int] = None):
        """Evaluated trajectory: (checks, F), or member f's (checks,)."""
        if self.history is None:
            return None
        return self.history if member is None else self.history[:, member]


def _member_metric(problem, A_s, y, cfg_s):
    """Per-member convergence metric with the regularizer TRACED —
    ``(alpha, value) -> scalar``, vmapped over the fleet.  The formulas
    are the facade's own stopper cores (``objectives.
    krr_rel_residual_value`` / ``ksvm_gap_from_Qa``) — one definition,
    two drivers.  The kernel matvec runs slab-free through
    ``kmv_slab_free`` over the SOLVE representation (A for exact, Phi +
    linear for low-rank — the linear branch IS the factored
    ``ksvm_duality_gap_lowrank`` contraction), so under vmap the kernel
    tiles are built once for all F metrics."""
    kern = cfg_s.kernel

    if problem == "krr":
        return lambda alpha, lam: krr_rel_residual_value(A_s, y, alpha,
                                                         lam, kern)
    loss = cfg_s.loss

    def metric(alpha, C):
        Qa = y * kmv_slab_free(A_s, A_s, y * alpha, kern)
        return ksvm_gap_from_Qa(Qa, alpha, C, loss)
    return metric


def _make_fleet_round_fn(problem, A_s, y, cfg_s, s, op, params):
    """The vmapped lockstep round: per-member round fns built from the
    SAME factories the facade drives, with the regularizer as the
    batched cfg leaf.  ``op`` (shared, unbatched) is closed over — vmap
    keeps every reduction that ignores the batch axis un-replicated."""
    if problem == "ksvm":
        def member(alpha, p, xs):
            rf = make_sstep_dcd_round_fn(A_s, y, cfg_s, s, op=op, C=p)
            return rf(alpha, xs)
    else:
        def member(alpha, p, xs):
            rf = make_sstep_bdcd_round_fn(A_s, y, cfg_s, s, op=op, lam=p)
            return rf(alpha, xs)
    vround = jax.vmap(member, in_axes=(0, 0, None))
    return lambda state, x: vround(state, params, x)


@partial(jax.jit, static_argnames=("problem", "cfg", "s", "check_every",
                                   "want_metric"))
def _fleet_serial(A_s, y, a0F, params, schedule, tol, op, *, problem,
                  cfg, s, check_every, want_metric):
    round_fn = _make_fleet_round_fn(problem, A_s, y, cfg, s, op, params)
    xs = pad_rounds(schedule, s)
    metric_fn = None
    if want_metric:
        mm = _member_metric(problem, A_s, y, cfg)
        metric_fn = lambda st: jax.vmap(mm)(st, params)
    return run_rounds_fleet(round_fn, a0F, xs, tol=tol,
                            check_every=check_every, metric_fn=metric_fn)


@partial(jax.jit, static_argnames=("problem", "cfg", "s", "mesh",
                                   "axis_name"))
def _fleet_1d_chunk(A_s, y, a0F, params, schedule, *, problem, cfg, s,
                    mesh, axis_name="model"):
    """One jitted chunk of 1d-layout fleet rounds: the vmap sits INSIDE
    the shard_map body, so per-rank operators are built once per chunk
    and shared psums stay unbatched across the fleet."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.distributed import (AllreduceGramOperator,
                                        _psummed_row_sqnorms)

    @partial(shard_map, mesh=mesh,
             in_specs=(P(None, axis_name), P(), P(), P(), P()),
             out_specs=P(), check_vma=False)
    def run(A_loc, y_r, a0F_r, params_r, sched_r):
        data_loc = (y_r[:, None] * A_loc if problem == "ksvm" else A_loc)
        rs = _psummed_row_sqnorms(data_loc, cfg.kernel, axis_name)
        op = AllreduceGramOperator(axis_name, data_loc, cfg.kernel, rs)
        round_fn = _make_fleet_round_fn(problem, A_loc, y_r, cfg, s, op,
                                        params_r)
        xs = pad_rounds(sched_r, s)
        return run_rounds_fleet(round_fn, a0F_r, xs).state

    return run(A_s, y, a0F, params, schedule)


def solve_fleet(A, y, *, lams=None, Cs=None, kernel=None, loss: str = "l1",
                options=None, warm_start=None) -> FleetResult:
    """Solve F independent problems — a lambda grid (K-RR, ``lams``) or a
    C grid (K-SVM, ``Cs``) on shared data — in ONE vmapped computation
    over one shared representation operator (module docstring).

    ``options`` is the facade's ``SolverOptions`` (auto knobs resolve
    through the autotuner first); fleets are slab-free by construction
    and support the ``serial`` and ``1d`` layouts.  ``warm_start`` seeds
    the whole fleet — (F, m) per-member, or (m,) broadcast (e.g. the
    solution at a neighbouring grid point).
    """
    from repro.api import (SolverOptions, _as_kernel,
                           _build_representation, _resolve_mesh,
                           _solve_cfg)

    if (lams is None) == (Cs is None):
        raise ValueError("pass exactly one of lams= (K-RR fleet) or "
                         "Cs= (K-SVM fleet)")
    problem = "krr" if Cs is None else "ksvm"
    values = np.asarray(lams if Cs is None else Cs, dtype=np.float64)
    if values.ndim != 1 or values.size < 1:
        raise ValueError(f"the {'lams' if Cs is None else 'Cs'} grid must "
                         f"be a non-empty 1-D sequence, got shape "
                         f"{values.shape}")
    if np.any(values <= 0.0):
        raise ValueError("regularization values must be positive")
    opts = options or SolverOptions()
    if not opts.slab_free:
        raise ValueError("fleets are slab-free by construction "
                         "(one shared operator); slab_free=False is the "
                         "single-solve parity oracle")

    m, n = A.shape
    F = values.size
    if problem == "krr":
        cfg = KRRConfig(lam=1.0, kernel=_as_kernel(kernel))
    else:
        cfg = SVMConfig(C=1.0, loss=loss, kernel=_as_kernel(kernel))

    if opts.needs_autotune:
        from .autotune import resolve_options
        plan = resolve_options(m, n, cfg, opts, problem=problem, A=A, y=y,
                               layouts=FLEET_LAYOUTS)
        opts = plan.options
    if opts.layout not in FLEET_LAYOUTS:
        raise ValueError(f"fleet layout must be one of {FLEET_LAYOUTS}, "
                         f"got {opts.layout!r} (2d fleets: shard the "
                         f"members, not the samples — open item)")

    H = opts.max_iters
    s = opts.s_eff
    b = opts.b if problem == "krr" else 1
    key = jax.random.key(opts.seed)
    if problem == "ksvm":
        schedule = coordinate_schedule(key, H, m)
        metric_name = "duality_gap"
    else:
        schedule = block_schedule(key, H, m, b)
        metric_name = "rel_residual"

    t0 = time.perf_counter()
    rep_op, A_s = _build_representation(A, cfg, opts)
    cfg_s = _solve_cfg(cfg, opts)
    train_op = rep_op.scale_rows(y) if problem == "ksvm" else rep_op
    params = jnp.asarray(values, A.dtype)
    if warm_start is None:
        a0F = jnp.zeros((F, m), A.dtype)
    else:
        a0F = jnp.broadcast_to(jnp.asarray(warm_start, A.dtype),
                               (F, m)).copy()

    want_metric = opts.tol > 0.0 or opts.record
    tol = opts.tol if opts.tol > 0.0 else NO_TOL
    history = None
    converged = np.zeros(F, bool)

    if opts.layout == "serial":
        P_count = 1
        res = _fleet_serial(A_s, y, a0F, params, schedule, tol, train_op,
                            problem=problem, cfg=cfg_s, s=s,
                            check_every=opts.check_every,
                            want_metric=want_metric)
        alpha = res.state
        rounds_run = int(res.rounds_run)
        if want_metric:
            converged = np.asarray(res.converged)
            history = np.asarray(res.metric_history())
    else:
        mesh = _resolve_mesh(opts)
        P_count = mesh.shape["model"]
        dist_kw = dict(problem=problem, cfg=cfg_s, s=s, mesh=mesh)
        if not want_metric:
            alpha = _fleet_1d_chunk(A_s, y, a0F, params, schedule,
                                    **dist_kw)
            rounds_run = -(-H // s)
        else:
            # chunked per-member stopping, mirroring the facade's 1d
            # tolerance path: whole multiples of s per chunk keep the
            # round decomposition identical; converged members are
            # frozen on the host between chunks
            mm = jax.jit(jax.vmap(_member_metric(problem, A_s, y, cfg_s)))
            chunk = opts.check_every * s
            done = np.zeros(F, bool)
            pos, rounds_run, hist = 0, 0, []
            alpha = a0F
            while pos < H:
                sched_c = schedule[pos:pos + chunk]
                new = _fleet_1d_chunk(A_s, y, alpha, params, sched_c,
                                      **dist_kw)
                alpha = jnp.where(jnp.asarray(done)[:, None], alpha, new)
                pos += sched_c.shape[0]
                rounds_run += -(-sched_c.shape[0] // s)
                vals = np.asarray(mm(alpha, params))
                hist.append(vals)
                if opts.tol > 0.0:
                    done |= vals <= opts.tol
                    if done.all():
                        break
            converged = done
            history = np.asarray(hist)
    jax.block_until_ready(alpha)
    wall = time.perf_counter() - t0

    iters_run = min(rounds_run * s, H)
    l = A_s.shape[1] if opts.approx else 0
    comm = fleet_fit_cost(m, n, cfg.kernel.name, F, b=b, s=s,
                          iters=iters_run, P=P_count, approx=opts.approx,
                          landmarks=l)
    rep_name = f"nystrom(l={l})" if opts.approx else "exact"
    return FleetResult(alpha=alpha, values=values,
                       param="lam" if problem == "krr" else "C",
                       problem=problem, history=history,
                       metric=metric_name, converged=converged,
                       rounds_run=rounds_run, iters_run=iters_run,
                       wall_time_s=wall, comm=comm, options=opts,
                       representation=rep_name, op=rep_op)
