"""Perf-model-driven autotuner for (s, b, layout, approx)
(DESIGN.md §10).

The paper's experiments show the optimal s-step depth is machine- and
problem-dependent (its Section 5.2.1 tunes s offline from the Hockney
model); block size b, partition layout, and the kernel representation
interact with it — a deep s is free when rounds are latency-bound and
ruinous when the O((sb)^2) correction term or the m x sb KMV working
set dominates.  ``resolve_options`` turns ``SolverOptions`` knobs left
at ``"auto"`` into concrete choices:

  1. enumerate the candidate grid over exactly the auto knobs (pinned
     knobs are respected verbatim);
  2. drop infeasible points — s*b whose slab working-set bound
     (``perf_model.slab_fits_hbm``, same constraint ``best_s`` enforces)
     exceeds the HBM budget, b > m, s > max_iters;
  3. price every survivor with ``perf_model.modeled_fit_cost`` (exact
     rounds at data width, low-rank rounds at landmark width plus the
     one-time ``lowrank_setup_cost``) at the layout's processor count;
  4. optionally REFINE by measurement (``options.probe > 0``): the top
     modeled candidates each run ``probe`` outer rounds through the
     real solver and the fastest measured one wins — the model ranks,
     the machine decides.

The chosen plan is returned as a ``TunedPlan`` (resolved options +
modeled cost breakdown + the full searched frontier) and lands on
``FitResult.plan``, so a tuned fit documents why its configuration was
picked.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import jax

from repro.core.perf_model import (Machine, choose_chunk_rows,
                                   modeled_fit_cost, slab_fits_hbm)

S_CANDIDATES = (1, 2, 4, 8, 16, 32, 64, 128, 256)
B_CANDIDATES = (1, 2, 4, 8, 16, 32, 64)
PROBE_TOP_K = 3


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """What the autotuner decided and why: ``options`` has every knob
    concrete; ``modeled`` is the winner's ``modeled_fit_cost`` breakdown;
    ``frontier`` records every candidate searched (config, modeled time,
    feasibility) — infeasible points keep their modeled time so the
    frontier shows what the memory ceiling cost; ``probed`` the measured
    refinement rows when ``probe > 0`` ran."""

    options: object                # resolved SolverOptions
    modeled: dict
    frontier: Tuple[dict, ...]
    probed: Optional[Tuple[dict, ...]] = None

    @property
    def choice(self) -> dict:
        o = self.options
        return {"s": o.s, "b": o.b, "layout": o.layout, "approx": o.approx}


def _layout_P(layout: str, ndev: int) -> int:
    return 1 if layout == "serial" else max(ndev, 1)


def resolve_options(m: int, n: int, cfg, opts, *, problem: str = "krr",
                    A=None, y=None, mach: Machine = None,
                    hbm_bytes: int = 16 * 2 ** 30,
                    layouts=None) -> TunedPlan:
    """Resolve every ``"auto"`` knob of ``opts`` for an (m, n) problem
    (module docstring).  ``A``/``y`` enable the measured-probe
    refinement when ``opts.probe > 0``; without data the Hockney model
    decides alone.  ``layouts`` restricts the layout search space (the
    fleet solver passes its supported pair)."""
    from repro.api import AUTO, LAYOUTS

    if not opts.needs_autotune:
        return TunedPlan(options=opts,
                         modeled=_price(m, n, cfg, opts, problem,
                                        opts.layout, mach),
                         frontier=())
    ndev = len(jax.devices())

    if opts.method != "sstep":
        s_cands = (1,)
    elif opts.s == AUTO:
        s_cands = tuple(s for s in S_CANDIDATES if s <= opts.max_iters)
    else:
        s_cands = (opts.s,)
    if problem != "krr":
        b_cands = (1,)
    elif opts.b == AUTO:
        b_cands = tuple(b for b in B_CANDIDATES if b <= m)
    else:
        b_cands = (opts.b,)
    if opts.layout == AUTO:
        lay_cands = ("serial",) if ndev == 1 else ("serial", "1d", "2d")
        if layouts is not None:
            lay_cands = tuple(l for l in lay_cands if l in layouts)
        # the 2d layout shards samples: it needs m divisible by the
        # data-axis extent (the facade's auto mesh uses every device)
        lay_cands = tuple(l for l in lay_cands
                          if l != "2d" or m % max(ndev, 1) == 0)
    else:
        lay_cands = (opts.layout,)
    if opts.stream is not None:
        # the streamed representation is serial + exact by construction
        # (SolverOptions validates the pinned combinations; here the
        # remaining AUTO dimensions are restricted to the compatible
        # subspace)
        lay_cands = ("serial",)
    assert all(l in LAYOUTS for l in lay_cands)
    if opts.approx == AUTO:
        # a rank >= m "approximation" is strictly more work than exact
        ap_cands = ((None, "nystrom") if opts.landmarks < m else (None,))
    else:
        ap_cands = (opts.approx,)
    if opts.stream is not None:
        ap_cands = (None,)

    frontier = []
    for lay in lay_cands:
        P = _layout_P(lay, ndev)
        for ap in ap_cands:
            l = min(opts.landmarks, m)
            for b in b_cands:
                for s in s_cands:
                    # KMV working-set bound: identical constraint to
                    # perf_model.best_s (s=1 is the classical floor).
                    # Streamed runs have no m-tall working set at all —
                    # that ceiling is exactly what streaming removes.
                    feasible = (opts.stream is not None or s == 1
                                or slab_fits_hbm(m, s * b, hbm_bytes))
                    cost = modeled_fit_cost(
                        m, n, cfg.kernel.name, b=b, s=s,
                        iters=opts.max_iters, P=P, mach=mach,
                        approx=ap, landmarks=l)
                    frontier.append({"s": s, "b": b, "layout": lay,
                                     "approx": ap, "time": cost["time"],
                                     "feasible": feasible})
    feas = [f for f in frontier if f["feasible"]]
    if not feas:
        # only reachable when s (and/or b) is PINNED above the HBM
        # working-set budget — s="auto" always carries the s=1 floor.
        # The tuner must not silently override a pinned knob, so the
        # remaining auto dimensions are resolved best-effort toward the
        # smallest working set instead of crashing.
        feas = sorted(frontier,
                      key=lambda f: (f["s"] * f["b"], f["time"]))
    else:
        feas.sort(key=lambda f: (f["time"], f["s"], f["b"]))

    probed = None
    if opts.probe > 0 and A is not None and y is not None:
        probed = _probe(A, y, cfg, opts, problem, feas[:PROBE_TOP_K])
        winner = min(probed, key=lambda p: p["measured_s"])
    else:
        winner = feas[0]

    resolved = dataclasses.replace(
        opts, s=winner["s"], b=winner["b"], layout=winner["layout"],
        approx=winner["approx"])
    if resolved.stream == AUTO:
        # chunk_rows="auto": best modeled streaming-pipeline time whose
        # double-buffered working set fits the on-chip budget, at the
        # winner's (s, b) slab width (DESIGN.md §14)
        resolved = dataclasses.replace(resolved, stream=choose_chunk_rows(
            m, n, winner["s"] * winner["b"], cfg.kernel.name, mach=mach))
    if resolved.guard and resolved.recompute_every == AUTO:
        # price drift correction for the WINNER (s, b, layout): the
        # cadence that keeps guarded overhead under the budget.  The
        # distributed layouts recompute from alpha every round — no
        # drifting residual, correction off (see repro.resilience).
        if winner["layout"] == "serial":
            from repro.core.perf_model import choose_recompute_every
            rec = choose_recompute_every(
                m, n, cfg.kernel.name,
                b=winner["b"] if problem == "krr" else 1,
                s=winner["s"], mach=mach,
                approx=bool(winner["approx"]),
                landmarks=min(opts.landmarks, m) if winner["approx"]
                else 0)
        else:
            rec = 0
        resolved = dataclasses.replace(resolved, recompute_every=rec)
    return TunedPlan(options=resolved,
                     modeled=_price(m, n, cfg, resolved, problem,
                                    winner["layout"], mach),
                     frontier=tuple(frontier),
                     probed=None if probed is None else tuple(probed))


def _price(m, n, cfg, opts, problem, layout, mach):
    ndev = len(jax.devices())
    s = opts.s_eff if opts.s != "auto" or opts.method != "sstep" else 1
    b = opts.b if (problem == "krr" and isinstance(opts.b, int)) else 1
    l = min(opts.landmarks, m) if opts.approx else 0
    return modeled_fit_cost(m, n, cfg.kernel.name, b=b, s=s,
                            iters=opts.max_iters,
                            P=_layout_P(layout, ndev), mach=mach,
                            approx=opts.approx, landmarks=l)


def _probe(A, y, cfg, opts, problem, candidates):
    """Measured refinement: run ``opts.probe`` outer rounds of each top
    candidate through the real facade solver (budget stopping, no
    metric) twice — the first call pays compile, the second is the
    measurement — and report wall seconds.

    Probe fits run with ``telemetry=None`` — their spans/marks belong
    to the tuner, not the fit being tuned; the PARENT handle (when the
    tuned fit carries one) records each probe as a counter bump and a
    wall-seconds histogram sample instead."""
    from repro.api import _fit, _active_tel

    from repro.api import AUTO

    tel = _active_tel(opts)
    rows = []
    for cand in candidates:
        s_eff = cand["s"] if opts.method == "sstep" else 1
        stream = opts.stream
        if stream == AUTO:               # concretize per candidate so the
            m, n = A.shape               # probe fit needs no re-tuning
            stream = choose_chunk_rows(m, n, cand["s"] * cand["b"],
                                       cfg.kernel.name)
        probe_opts = dataclasses.replace(
            opts, s=cand["s"], b=cand["b"], layout=cand["layout"],
            approx=cand["approx"], tol=0.0, record=False, probe=0,
            stream=stream, max_iters=max(opts.probe * s_eff, 1),
            telemetry=None)
        _fit(problem, A, y, cfg, probe_opts)         # compile + warm
        t0 = time.perf_counter()
        _fit(problem, A, y, cfg, probe_opts)
        dt = time.perf_counter() - t0
        rows.append(dict(cand, measured_s=dt))
        if tel is not None:
            tel.metrics.counter(
                "repro_autotune_probes_total",
                "measured autotune probes run").inc(
                    layout=cand["layout"])
            tel.metrics.histogram(
                "repro_autotune_probe_seconds",
                "measured wall seconds per probe fit").observe(dt)
    return rows
