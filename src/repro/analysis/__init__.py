"""Static analysis for the repro tree (DESIGN.md §11).

Three analyzers behind one CLI (``python -m repro.analysis``):

* ``pallas_check`` — Pallas kernel sanitizer (races, coverage holes,
  tile alignment, VMEM budget, unexercised sites)
* ``lint`` — jit hygiene (tracer branching in round fns, unregistered
  pytree dataclasses, callable-typed static args)
* ``comm_check`` — s-step collective auditor (census of traced
  collectives vs ``perf_model``'s modeled message schedule)
* ``guard_check`` — guarded-carry coverage auditor (every floating
  carry leaf must be seen by the divergence-guard health predicate)
* ``obs_check`` — traced-span pairing auditor (every ``span_begin`` in
  a function has a same-name ``span_end`` — unmatched begins vanish
  silently from traces)

Findings carry stable check IDs and honor justified
``# repro: noqa[CHK-...]`` suppressions (``findings`` module).
"""
from .findings import (ERROR, INFO, WARNING, Finding,  # noqa: F401
                       apply_suppressions, render_report)

ANALYZERS = ("pallas", "lint", "comm", "guard", "obs")

CHECKS = {
    "CHK-RACE": ("pallas", "error",
                 "output block written from >1 parallel grid point"),
    "CHK-HOLE": ("pallas", "error", "output block never written"),
    "CHK-ALIGN": ("pallas", "warning",
                  "block shape off the dtype's (sublane, lane) tile"),
    "CHK-VMEM": ("pallas", "warning",
                 "double-buffered working set exceeds VMEM"),
    "CHK-SITE": ("pallas", "warning",
                 "pallas_call site not exercised by the registry"),
    "CHK-TRACER": ("lint", "error",
                   "host branching/coercion on traced value in round fn"),
    "CHK-PYTREE": ("lint", "error",
                   "array-carrying dataclass not a registered pytree"),
    "CHK-STATIC": ("lint", "info",
                   "Callable-typed static argname (retrace hazard)"),
    "CHK-COMM": ("comm", "error",
                 "collective executions != modeled message schedule"),
    "CHK-AXIS": ("comm", "error", "collective over unknown mesh axis"),
    "CHK-SSTEP": ("comm", "error",
                  "s-step per-round collectives != classical/s"),
    "CHK-CARRY": ("guard", "error",
                  "guarded-carry leaf missed by the health predicate"),
    "CHK-SPAN": ("obs", "error",
                 "traced span_begin without a same-function span_end"),
    "CHK-NOQA": ("-", "error", "suppression without justification"),
}


def run_all(only=None):
    """Run the selected analyzers (all by default) and resolve
    suppressions; returns the full finding list, suppressed included."""
    from . import comm_check, guard_check, lint, obs_check, pallas_check
    runners = {"pallas": pallas_check.run, "lint": lint.run,
               "comm": comm_check.run, "guard": guard_check.run,
               "obs": obs_check.run}
    selected = ANALYZERS if not only else tuple(only)
    found = []
    for name in selected:
        found.extend(runners[name]())
    return apply_suppressions(found)
