"""Traced-span pairing auditor for repro.obs (DESIGN.md §§11, 15).

``obs.spans.span_begin``/``span_end`` fire through UNORDERED debug
callbacks, so the recorder cannot detect a missing end at runtime — an
unmatched begin is silently dropped by ``paired_marks()`` and the span
simply vanishes from every trace and audit.  The invariant must
therefore hold at the SOURCE: every ``span_begin(name)`` in traced code
is paired with a ``span_end(name)`` in the SAME enclosing function (the
round protocol's sync points are always intra-function), and span names
are string literals (a computed name cannot be audited — and would
re-stage the callback partial per value).

* CHK-SPAN (error) — a ``span_begin`` without a same-function
  ``span_end`` of the same literal name (or vice versa), or a
  begin/end call whose name argument is not a string literal.
  Anchors to the offending call.

Purely syntactic (AST over ``src/repro``): the begin/end calls are
module-level functions gated on a static flag, so call-site counting is
exact — there is no dynamic dispatch to miss.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Tuple

from .findings import ERROR, Finding

SRC_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BEGIN = "span_begin"
_END = "span_end"


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _span_calls(fn: ast.AST) -> List[Tuple[str, ast.Call]]:
    """Every span_begin/span_end call lexically inside ``fn`` but NOT
    inside a nested function (the nested def is its own pairing
    scope)."""
    out: List[Tuple[str, ast.Call]] = []

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if isinstance(child, ast.Call):
                kind = _call_name(child)
                if kind in (_BEGIN, _END):
                    out.append((kind, child))
            walk(child)

    walk(fn)
    return out


def _check_function(path: str, fn) -> List[Finding]:
    calls = _span_calls(fn)
    if not calls:
        return []
    findings: List[Finding] = []
    opens: Dict[str, int] = {}
    closes: Dict[str, int] = {}
    anchor: Dict[str, int] = {}
    for kind, call in calls:
        name_arg = call.args[0] if call.args else None
        if not (isinstance(name_arg, ast.Constant)
                and isinstance(name_arg.value, str)):
            findings.append(Finding(
                check="CHK-SPAN", severity=ERROR, path=path,
                line=call.lineno,
                message=f"{kind} name must be a string literal "
                        f"(computed names defeat the static pairing "
                        f"audit and re-stage the callback per value)"))
            continue
        name = name_arg.value
        anchor.setdefault(name, call.lineno)
        tally = opens if kind == _BEGIN else closes
        tally[name] = tally.get(name, 0) + 1
    for name in sorted(set(opens) | set(closes)):
        nb, ne = opens.get(name, 0), closes.get(name, 0)
        if nb != ne:
            findings.append(Finding(
                check="CHK-SPAN", severity=ERROR, path=path,
                line=anchor[name],
                message=f"traced span {name!r} has {nb} span_begin vs "
                        f"{ne} span_end call sites in "
                        f"{getattr(fn, 'name', '<module>')!r} — an "
                        f"unmatched begin is silently dropped by "
                        f"paired_marks()"))
    return findings


def run(root: str = SRC_ROOT) -> List[Finding]:
    findings: List[Finding] = []
    for dirpath, _dirs, files in os.walk(root):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.abspath(os.path.join(dirpath, fname))
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
            for node in ast.walk(tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    findings.extend(_check_function(path, node))
    return findings
