"""Static s-step collective auditor (DESIGN.md §11).

The paper's headline invariant is STRUCTURAL: the s-step variants run
the identical update in exact arithmetic while communicating every s
steps instead of every step — H iterations cost ceil(H/s) rounds of
messages.  ``perf_model`` prices that schedule; this module asserts the
code actually implements it, by tracing every distributed solver x
layout x (classical, s-step) x kernel combination to a jaxpr and
running ``launch.jaxpr_analysis.collective_census`` over it:

* CHK-COMM (error) — total collective EXECUTIONS (scan trip counts
  multiplied through) != rounds x ``perf_model.round_collectives``
  + ``perf_model.setup_collectives``, where rounds comes from the same
  Hockney model term (``modeled_fit_cost``'s message count at P=1)
  the autotuner prices with.  An extra psum in a round-fn closure or a
  collective that silently left the scan body fails this count.
* CHK-AXIS (error) — a collective communicating over an axis name the
  shard_map mesh does not define (it would crash at run time on a real
  mesh; at trace time over a 1x1 mesh it silently no-ops).
* CHK-SSTEP (error) — for each solver/layout/kernel, per-round
  collective executions of the s-step trace != classical / s: the
  paper's communication-avoidance claim itself.

Tracing happens on a 1x1 ("data", "model") mesh — the census counts
collective SITES x trip counts, which are mesh-size-invariant, so one
device audits the schedule of any P.  Findings anchor to the traced
solver's ``def`` line in ``core/distributed.py``.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
import os
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from repro.compat import make_mesh_auto
from repro.core import distributed as dist
from repro.core.bdcd import KRRConfig
from repro.core.dcd import SVMConfig
from repro.core.kernels import KernelConfig
from repro.core.perf_model import (modeled_fit_cost, round_collectives,
                                   setup_collectives)
from repro.launch.jaxpr_analysis import CollectiveUse, collective_census

from .findings import ERROR, Finding

M, N, H, B, S = 32, 16, 16, 2, 4          # trace-problem concretization

SOLVERS = {
    ("ksvm", "1d"): dist.dist_sstep_dcd_ksvm,
    ("ksvm", "2d"): dist.dist_sstep_dcd_ksvm_2d,
    ("krr", "1d"): dist.dist_sstep_bdcd_krr,
    ("krr", "2d"): dist.dist_sstep_bdcd_krr_2d,
}
KERNEL_NAMES = ("linear", "rbf")


@dataclasses.dataclass(frozen=True)
class CommCase:
    """One audited trace point."""

    problem: str          # "ksvm" | "krr"
    layout: str           # "1d" | "2d"
    mode: str             # "classical" | "sstep"
    kernel: str           # "linear" | "rbf"

    @property
    def s(self) -> int:
        return 1 if self.mode == "classical" else S

    @property
    def rounds(self) -> int:
        return math.ceil(H / self.s)


CASES: Tuple[CommCase, ...] = tuple(
    CommCase(p, l, m, k)
    for (p, l) in SOLVERS
    for m in ("classical", "sstep")
    for k in KERNEL_NAMES)


def _cfg(case: CommCase):
    kern = KernelConfig(case.kernel)
    if case.problem == "ksvm":
        return SVMConfig(C=1.0, loss="l1", kernel=kern)
    return KRRConfig(lam=1.0, kernel=kern)


def trace_case(case: CommCase) -> Tuple[CollectiveUse, ...]:
    """Trace the case's solver on a 1x1 mesh and return its census."""
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    fn = SOLVERS[(case.problem, case.layout)]
    cfg = _cfg(case)
    A = jnp.zeros((M, N), jnp.float32)
    y = jnp.ones((M,), jnp.float32)
    a0 = jnp.zeros((M,), jnp.float32)
    sched = jnp.zeros((H,) if case.problem == "ksvm" else (H, B),
                      jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda A, y, a0, sc: fn(mesh, A, y, a0, sc, cfg, s=case.s))(
            A, y, a0, sched)
    return collective_census(jaxpr)


def expected_executions(case: CommCase) -> int:
    """The model's count: per-round collectives x the Hockney message
    rounds (``modeled_fit_cost`` msgs at P=1 — one message per round)
    plus the loop-invariant setup collectives (RBF row sqnorms)."""
    b = B if case.problem == "krr" else 1
    rounds = int(modeled_fit_cost(M, N, case.kernel, b=b, s=case.s,
                                  iters=H, P=1)["msgs"])
    assert rounds == case.rounds, (rounds, case)
    return (rounds * round_collectives(case.layout, case.kernel)
            + setup_collectives(case.layout, case.kernel))


def _anchor(case: CommCase) -> Tuple[str, int]:
    fn = SOLVERS[(case.problem, case.layout)]
    return (os.path.abspath(inspect.getsourcefile(fn)),
            inspect.getsourcelines(fn)[1])


def audit_case(case: CommCase, census=None) -> List[Finding]:
    """CHK-COMM + CHK-AXIS for one trace point (``census`` injectable
    for fixture tests)."""
    census = trace_case(case) if census is None else census
    path, line = _anchor(case)
    label = f"{case.problem}/{case.layout}/{case.mode}/{case.kernel}"
    out: List[Finding] = []

    total = sum(u.executions for u in census)
    want = expected_executions(case)
    if total != want:
        sites = [(u.prim, u.axes, u.executions) for u in census]
        out.append(Finding(
            "CHK-COMM", ERROR, path, line,
            f"{label}: traced {total} collective executions, model says "
            f"{want} ({case.rounds} rounds x "
            f"{round_collectives(case.layout, case.kernel)} + "
            f"{setup_collectives(case.layout, case.kernel)} setup) — "
            f"census: {sites}"))

    mesh_axes = {"data", "model"}
    for u in census:
        bad = [a for a in u.axes if a not in mesh_axes]
        if bad:
            out.append(Finding(
                "CHK-AXIS", ERROR, path, line,
                f"{label}: {u.prim} over unknown mesh axis name(s) "
                f"{bad} — the shard_map mesh defines {sorted(mesh_axes)}"))
    return out


def _per_round(case: CommCase, census) -> float:
    """Collective executions attributable to rounds (setup removed),
    divided by the round count."""
    total = sum(u.executions for u in census)
    return (total - setup_collectives(case.layout, case.kernel)) \
        / case.rounds


def audit() -> List[Finding]:
    findings: List[Finding] = []
    per_round = {}
    for case in CASES:
        census = trace_case(case)
        findings.extend(audit_case(case, census))
        per_round[(case.problem, case.layout, case.kernel,
                   case.mode)] = _per_round(case, census)

    # the paper's claim: per H iterations, s-step communicates 1/s as
    # often as classical — equal per-ROUND cost, rounds reduced by s
    for (p, l) in SOLVERS:
        for k in KERNEL_NAMES:
            cl = per_round[(p, l, k, "classical")]
            ss = per_round[(p, l, k, "sstep")]
            if cl != ss:
                path, line = _anchor(CommCase(p, l, "sstep", k))
                findings.append(Finding(
                    "CHK-SSTEP", ERROR, path, line,
                    f"{p}/{l}/{k}: s-step trace runs {ss} collectives "
                    f"per round vs classical {cl} — total executions "
                    f"per {H} iterations must equal classical/{S}"))
    return findings


def run() -> List[Finding]:
    return audit()
