"""Pallas kernel sanitizer (DESIGN.md §11).

Enumerates every registered ``pl.pallas_call`` launch's BlockSpec index
maps over the CONCRETE grid and checks the properties the TPU pipeline
assumes but never verifies:

* CHK-RACE (error) — an output block written from more than one
  distinct projection onto the PARALLEL grid axes.  Parallel axes may
  execute concurrently (and on real hardware, on different cores), so
  two parallel grid points landing on the same out block is a write
  race; revisits that differ only along "arbitrary" (sequential) axes
  are the legal accumulate-in-scratch pattern and are not flagged.
* CHK-HOLE (error) — an output block no grid point ever writes: the
  kernel silently returns uninitialized HBM for that tile.
* CHK-ALIGN (warning) — a block shape violating the dtype-aware
  sublane/lane tiling ((8, 128) f32, (16, 128) bf16 — the same
  round-up ``kernels/gram.py`` applies); misaligned blocks force the
  mosaic compiler into relayouts or fail outright on hardware even
  when interpret=True passes.
* CHK-VMEM (warning) — the double-buffered working set (in + out
  blocks twice, plus scratch) priced by ``perf_model`` exceeds the
  16 MB/core VMEM budget: the launch cannot pipeline on hardware.
* CHK-SITE (warning) — a ``pallas_call`` site discovered by the AST
  walk that no registered entry point exercises: the sanitizer is
  blind to it (fix by registering it in ``registry.ENTRY_POINTS``).
* CHK-DMA (error) — static async-copy discipline for manually
  double-buffered kernels (``kernels/kmv_stream.py``): every
  ``make_async_copy`` semaphore that is ``.start()``-ed must also be
  ``.wait()``-ed in the same kernel (a buffer read before its copy
  lands is the classic overlap race, invisible in interpret mode), a
  ``.wait()`` needs a matching ``.start()`` (deadlock), and a
  prefetch ``.start()`` must not target the same slot expression a
  ``.wait()`` consumes — double-buffer indices must alternate.

Findings anchor to the ``pallas_call`` expression's line, so
suppressions sit next to the launch they waive.
"""
from __future__ import annotations

import ast
import math
import os
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.perf_model import (VMEM_BYTES, pallas_working_set_bytes,
                                   vmem_fits)
from repro.kernels.gram import _sublane

from .findings import ERROR, WARNING, Finding
from .registry import (KERNELS_DIR, CapturedCall, capture_entry_points,
                       discover_sites)

LANE = 128
GRID_ENUM_CAP = 1 << 20


def _grid_points(grid: Tuple[int, ...]):
    pts = [()]
    for extent in grid:
        pts = [p + (i,) for p in pts for i in range(extent)]
    return pts


def _as_index(idx) -> Tuple[int, ...]:
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(i) for i in idx)


def _check_out_spec(call: CapturedCall, k: int, spec) -> List[Finding]:
    out: List[Finding] = []
    sem = call.dimension_semantics or ("arbitrary",) * len(call.grid)
    par_axes = [a for a, s in enumerate(sem) if s == "parallel"]
    if spec.index_map is None or math.prod(call.grid) > GRID_ENUM_CAP:
        return out

    writes: Dict[Tuple[int, ...], Set[Tuple[int, ...]]] = {}
    for pt in _grid_points(call.grid):
        block = _as_index(spec.index_map(*pt))
        proj = tuple(pt[a] for a in par_axes)
        writes.setdefault(block, set()).add(proj)

    where = f"{call.function} out spec #{k}"
    for block, projs in sorted(writes.items()):
        if len(projs) > 1:
            out.append(Finding(
                "CHK-RACE", ERROR, call.path, call.line,
                f"{where}: block {block} written from {len(projs)} "
                f"distinct parallel-axis points (e.g. "
                f"{sorted(projs)[:2]}) — concurrent grid points race "
                f"on the same output tile"))

    expected = set(_grid_points(tuple(
        -(-d // b) for d, b in zip(spec.array_shape, spec.block_shape))))
    holes = sorted(expected - set(writes))
    if holes:
        out.append(Finding(
            "CHK-HOLE", ERROR, call.path, call.line,
            f"{where}: {len(holes)} of {len(expected)} output blocks "
            f"never written (first: {holes[0]}) — those tiles return "
            f"uninitialized memory"))
    return out


def _check_alignment(call: CapturedCall) -> List[Finding]:
    out: List[Finding] = []
    for role, specs in (("in", call.in_specs), ("out", call.out_specs)):
        for k, spec in enumerate(specs):
            if len(spec.block_shape) < 2:
                continue
            sub = _sublane(spec.dtype)
            lane_d, sub_d = spec.block_shape[-1], spec.block_shape[-2]
            bad = []
            if lane_d % LANE and lane_d != spec.array_shape[-1]:
                bad.append(f"lane dim {lane_d} % {LANE} != 0")
            if sub_d % sub and sub_d != 1 \
                    and sub_d != spec.array_shape[-2]:
                bad.append(f"sublane dim {sub_d} % {sub} != 0")
            if bad:
                out.append(Finding(
                    "CHK-ALIGN", WARNING, call.path, call.line,
                    f"{call.function} {role} spec #{k}: block "
                    f"{spec.block_shape} ({jnp_name(spec.dtype)}) — "
                    + "; ".join(bad)
                    + f" (TPU tiles are ({sub}, {LANE}) for this dtype)"))
    return out


def jnp_name(dtype) -> str:
    import jax.numpy as jnp
    return jnp.dtype(dtype).name


def _check_vmem(call: CapturedCall) -> List[Finding]:
    blocks = call.block_bytes()
    if vmem_fits(blocks, call.scratch_bytes):
        return []
    ws = pallas_working_set_bytes(blocks, call.scratch_bytes)
    return [Finding(
        "CHK-VMEM", WARNING, call.path, call.line,
        f"{call.function}: double-buffered working set {ws} B "
        f"({blocks} B blocks x2 + {call.scratch_bytes} B scratch) "
        f"exceeds the {VMEM_BYTES} B VMEM budget — the launch cannot "
        f"pipeline on hardware")]


def _dma_ops(fn_node: ast.FunctionDef) -> List[dict]:
    """Every ``make_async_copy(...).start()`` / ``.wait()`` expression
    under ``fn_node`` (nested loop bodies included), with its pairing
    key: the SEMAPHORE operand's base name and slot expression.  A DMA
    completes on its semaphore, so start/wait pairing — and the
    double-buffer alternation invariant — is per (semaphore, slot)."""
    ops = []
    for node in ast.walk(fn_node):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("start", "wait")
                and isinstance(node.func.value, ast.Call)):
            continue
        copy = node.func.value
        cf = copy.func
        cname = cf.attr if isinstance(cf, ast.Attribute) else \
            cf.id if isinstance(cf, ast.Name) else None
        if cname != "make_async_copy":
            continue
        sem = copy.args[-1] if copy.args else None
        base, slot, slot_const = None, None, False
        if isinstance(sem, ast.Subscript) \
                and isinstance(sem.value, ast.Attribute) \
                and sem.value.attr == "at":
            base = ast.unparse(sem.value.value)
            slot = ast.unparse(sem.slice)
            slot_const = isinstance(sem.slice, ast.Constant)
        elif sem is not None:
            base = ast.unparse(sem)
        ops.append({"kind": node.func.attr, "sem": base, "slot": slot,
                    "slot_const": slot_const, "line": node.lineno})
    return ops


def _check_dma(root: str = KERNELS_DIR) -> List[Finding]:
    """Static async-copy discipline over every kernel source file
    (module docstring, CHK-DMA).  Scope is the TOP-LEVEL kernel
    function: the warm-up ``.start()`` lives in the kernel body while
    the steady-state ``.wait()`` lives in a nested ``fori_loop`` body,
    so pairing must see both."""
    out: List[Finding] = []
    for dirpath, _dirs, files in os.walk(root):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.abspath(os.path.join(dirpath, fname))
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
            for fn in tree.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                ops = _dma_ops(fn)
                if not ops:
                    continue
                sems = sorted({o["sem"] for o in ops},
                              key=lambda s: (s is None, str(s)))
                for sem in sems:
                    mine = [o for o in ops if o["sem"] == sem]
                    starts = [o for o in mine if o["kind"] == "start"]
                    waits = [o for o in mine if o["kind"] == "wait"]
                    where = f"{fn.name} semaphore {sem!r}"
                    if starts and not waits:
                        out.append(Finding(
                            "CHK-DMA", ERROR, path, starts[0]["line"],
                            f"{where}: async copy started but never "
                            f"waited — the destination buffer can be "
                            f"read before the DMA lands (race is "
                            f"invisible under interpret mode)"))
                    if waits and not starts:
                        out.append(Finding(
                            "CHK-DMA", ERROR, path, waits[0]["line"],
                            f"{where}: async-copy wait with no "
                            f"matching start — the kernel deadlocks "
                            f"on an untriggered semaphore"))
                    # alternation: a NON-constant slot expression used
                    # by both a start and a wait means the prefetch
                    # targets the very slot this iteration consumes
                    # (constant slots are the warm-up fill — slot 0 is
                    # started at function scope and legitimately waited
                    # as rem(0, 2) in the first loop iteration)
                    ss = {o["slot"] for o in starts
                          if o["slot"] is not None
                          and not o["slot_const"]}
                    ws = {o["slot"] for o in waits
                          if o["slot"] is not None
                          and not o["slot_const"]}
                    for shared in sorted(ss & ws):
                        out.append(Finding(
                            "CHK-DMA", ERROR, path, waits[0]["line"],
                            f"{where}: prefetch start and consume "
                            f"wait both index slot ({shared}) — "
                            f"double-buffer slots must alternate or "
                            f"the in-flight copy overwrites the "
                            f"chunk being computed on"))
    return out


def analyze_calls(calls: Sequence[CapturedCall]) -> List[Finding]:
    """All per-launch checks over already-captured calls (the test
    fixtures enter here; ``run`` adds capture + site coverage)."""
    findings: List[Finding] = []
    seen = set()
    for call in calls:
        for f in (_check_alignment(call) + _check_vmem(call)
                  + [f for k, spec in enumerate(call.out_specs)
                     for f in _check_out_spec(call, k, spec)]):
            key = (f.check, f.path, f.line, f.message)
            if key not in seen:       # gram runs once per dtype entry
                seen.add(key)
                findings.append(f)
    return findings


def run() -> List[Finding]:
    calls = capture_entry_points()
    findings = analyze_calls(calls)
    findings.extend(_check_dma())
    covered = {c.site for c in calls}
    for path, line in discover_sites():
        if (path, line) not in covered:
            findings.append(Finding(
                "CHK-SITE", WARNING, path, line,
                "pallas_call site not exercised by any registered "
                "entry point — register it in "
                "repro.analysis.registry.ENTRY_POINTS"))
    return findings
