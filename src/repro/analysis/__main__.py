"""``python -m repro.analysis`` — run the static analyzers and exit
nonzero on any unsuppressed finding (the blocking CI entry point).

    python -m repro.analysis                 # all three analyzers
    python -m repro.analysis --only pallas   # subset
    python -m repro.analysis --list-checks   # the check catalog
    python -m repro.analysis --json          # machine-readable findings
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from . import ANALYZERS, CHECKS, render_report, run_all


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Pallas sanitizer + jit lint + collective auditor")
    ap.add_argument("--only", action="append", choices=ANALYZERS,
                    help="run a subset (repeatable)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON instead of the report")
    ap.add_argument("--list-checks", action="store_true",
                    help="print the check catalog and exit")
    args = ap.parse_args(argv)

    if args.list_checks:
        for check, (analyzer, sev, what) in sorted(CHECKS.items()):
            print(f"{check:12s} {analyzer:7s} {sev:8s} {what}")
        return 0

    findings = run_all(only=args.only)
    if args.json:
        print(json.dumps([dataclasses.asdict(f) for f in findings],
                         indent=2))
    else:
        print(render_report(findings))
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
