"""Pallas call-site capture and the kernel entry-point registry.

The sanitizer (``pallas_check``) needs every ``pl.pallas_call`` in the
tree with a CONCRETE grid and BlockSpecs — index maps are Python
lambdas over runtime-derived block counts, so they cannot be inspected
from source alone.  Two mechanisms cooperate:

* ``discover_sites`` AST-walks ``src/repro/kernels/`` for the
  ``pl.pallas_call`` call expressions — the ground truth of what exists.
* ``capture`` monkeypatches ``jax.experimental.pallas.pallas_call``
  with a recorder that snapshots (grid, specs, out_shape, scratch,
  dimension_semantics, caller file/line) and returns a stub runner
  producing zeros — so driving a kernel's UNJITTED entry point (via
  ``__wrapped__``, bypassing the jit cache) records its launch without
  compiling or executing anything.

``ENTRY_POINTS`` registers one representative concretization per public
kernel entry point.  To register a new kernel, append an ``EntryPoint``
whose thunk calls the new wrapper with shapes exercising every padding
branch (non-block-aligned dims, both dtypes if the kernel is
dtype-generic); ``pallas_check`` cross-references captured (file, line)
pairs against ``discover_sites`` and flags unexercised sites (CHK-SITE)
so a forgotten registration is itself a finding.
"""
from __future__ import annotations

import ast
import contextlib
import dataclasses
import inspect
import math
import os
from typing import Callable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
from jax.experimental import pallas as _pallas_mod

KERNELS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "kernels")


@dataclasses.dataclass
class SpecInfo:
    """One BlockSpec, concretized: ``block_shape`` (ints), ``index_map``
    (the live lambda), the shape/dtype of the array it blocks, and the
    declared ``memory_space`` (None = default pipelined VMEM)."""

    block_shape: Tuple[int, ...]
    index_map: Optional[Callable]
    array_shape: Tuple[int, ...]
    dtype: object
    memory_space: object = None

    @property
    def is_any_space(self) -> bool:
        """True for ``TPUMemorySpace.ANY`` specs: the array stays in
        HBM/host and the BlockSpec pipeline never stages it through
        VMEM (the kernel DMAs slices itself) — such inputs must not be
        priced against the VMEM block budget."""
        ms = self.memory_space
        return ms is not None and "any" in str(ms).lower()


@dataclasses.dataclass
class CapturedCall:
    """One recorded ``pl.pallas_call`` launch."""

    path: str
    function: str
    line: int
    grid: Tuple[int, ...]
    in_specs: List[SpecInfo]
    out_specs: List[SpecInfo]
    scratch_bytes: int
    dimension_semantics: Optional[Tuple[str, ...]]
    entry: str = ""

    @property
    def site(self) -> Tuple[str, int]:
        return (self.path, self.line)

    def block_bytes(self) -> int:
        """Per-grid-step VMEM block bytes (in + out blocks).  ANY-space
        specs are excluded: those arrays never transit the BlockSpec
        pipeline (the kernel's own scratch + DMA slots, counted in
        ``scratch_bytes``, are their VMEM footprint)."""
        return sum(
            math.prod(s.block_shape) * jnp.dtype(s.dtype).itemsize
            for s in self.in_specs + self.out_specs
            if not s.is_any_space)


def _as_list(x) -> list:
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


def _spec_infos(specs, arrays) -> List[SpecInfo]:
    out = []
    for spec, arr in zip(_as_list(specs), arrays):
        shape = tuple(jnp.shape(arr)) if not hasattr(arr, "shape") \
            else tuple(arr.shape)
        dtype = getattr(arr, "dtype", jnp.float32)
        block = getattr(spec, "block_shape", None)
        block = tuple(block) if block is not None else shape
        out.append(SpecInfo(block, getattr(spec, "index_map", None),
                            shape, dtype,
                            getattr(spec, "memory_space", None)))
    return out


def _scratch_bytes(scratch_shapes) -> int:
    total = 0
    for s in _as_list(scratch_shapes):
        shape = getattr(s, "shape", None)
        dtype = getattr(s, "dtype", None)
        if shape is not None and dtype is not None:
            total += math.prod(shape) * jnp.dtype(dtype).itemsize
    return total


@contextlib.contextmanager
def capture():
    """Swap ``pallas.pallas_call`` for a recorder; yields the list that
    accumulates ``CapturedCall`` rows.  The stub runner returns zeros of
    ``out_shape`` so wrapper code after the launch (slicing, reshape)
    still executes — drive only UNJITTED entry points under this, or
    the jit cache will skip the patched call."""
    calls: List[CapturedCall] = []
    real = _pallas_mod.pallas_call

    def fake(kernel, *, grid=None, in_specs=None, out_specs=None,
             out_shape=None, scratch_shapes=(), compiler_params=None,
             interpret=False, **kw):
        frame = inspect.currentframe().f_back
        site = (os.path.abspath(frame.f_code.co_filename),
                frame.f_code.co_name, frame.f_lineno)
        sem = getattr(compiler_params, "dimension_semantics", None)
        shapes = _as_list(out_shape)
        grid_t = tuple(grid) if isinstance(grid, (list, tuple)) else (grid,)

        def runner(*args):
            rec = CapturedCall(
                path=site[0],
                function=site[1],
                line=site[2],
                grid=tuple(int(g) for g in grid_t),
                in_specs=_spec_infos(in_specs, args),
                out_specs=_spec_infos(out_specs, shapes),
                scratch_bytes=_scratch_bytes(scratch_shapes),
                dimension_semantics=tuple(sem) if sem else None,
            )
            calls.append(rec)
            outs = [jnp.zeros(s.shape, s.dtype) for s in shapes]
            return outs if isinstance(out_shape, (list, tuple)) else outs[0]

        return runner

    _pallas_mod.pallas_call = fake
    try:
        yield calls
    finally:
        _pallas_mod.pallas_call = real


def _unwrap(fn):
    return getattr(fn, "__wrapped__", fn)


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """A registered kernel concretization: ``run`` drives the unjitted
    wrapper under ``capture`` with representative non-aligned shapes."""

    name: str
    run: Callable[[], None]


def _run_gram(dtype):
    def go():
        from repro.core.kernels import KernelConfig
        from repro.kernels import gram
        A = jnp.zeros((200, 700), dtype)
        B = jnp.zeros((136, 700), dtype)
        _unwrap(gram.gram_pallas)(A, B, KernelConfig(name="rbf"))
    return go


def _run_kmv(kernel_name, vec):
    def go():
        from repro.core.kernels import KernelConfig
        from repro.kernels import kmv
        A = jnp.zeros((200, 700), jnp.float32)
        B = jnp.zeros((136, 700), jnp.float32)
        X = jnp.zeros((200,) if vec else (200, 5), jnp.float32)
        _unwrap(kmv.kmv_pallas)(A, B, X, KernelConfig(name=kernel_name))
    return go


def _run_kmv_stream(kernel_name, c):
    def go():
        from repro.core.kernels import KernelConfig
        from repro.kernels import kmv_stream
        Xc = jnp.zeros((4, 24, 70), jnp.float32)    # ragged: 24 % 8,
        B = jnp.zeros((12, 70), jnp.float32)        # 70 % 128, 12 % 8
        Xvc = jnp.zeros((4, 24, c), jnp.float32)
        _unwrap(kmv_stream.kmv_stream_pallas)(
            Xc, B, Xvc, KernelConfig(name=kernel_name))
    return go


def _run_flash():
    from repro.kernels import flash_attention as fa
    BH, S, hd = 2, 512, 128
    q = jnp.zeros((BH, S, hd), jnp.float32)
    o, lse = _unwrap(fa.flash_fwd)(q, q, q, causal=True)
    _unwrap(fa.flash_bwd)(q, q, q, o, lse, q, causal=True)


def _run_rmsnorm():
    from repro.kernels import rmsnorm
    x = jnp.zeros((520, 256), jnp.float32)
    _unwrap(rmsnorm.rmsnorm_pallas)(x, jnp.zeros((256,), jnp.float32))


ENTRY_POINTS: Tuple[EntryPoint, ...] = (
    EntryPoint("gram_pallas[f32,rbf]", _run_gram(jnp.float32)),
    EntryPoint("gram_pallas[bf16,rbf]", _run_gram(jnp.bfloat16)),
    EntryPoint("kmv_pallas[rbf,mat]", _run_kmv("rbf", vec=False)),
    EntryPoint("kmv_pallas[linear,vec]", _run_kmv("linear", vec=True)),
    EntryPoint("kmv_stream_pallas[rbf]", _run_kmv_stream("rbf", c=5)),
    EntryPoint("kmv_stream_pallas[linear]",
               _run_kmv_stream("linear", c=1)),
    EntryPoint("flash_attention[fwd+bwd]", _run_flash),
    EntryPoint("rmsnorm_pallas", _run_rmsnorm),
)


def capture_entry_points(entries: Sequence[EntryPoint] = ENTRY_POINTS
                         ) -> List[CapturedCall]:
    """Drive every registered entry point under ``capture``; each
    captured call is tagged with the entry name that produced it."""
    out: List[CapturedCall] = []
    for ep in entries:
        with capture() as calls:
            ep.run()
        for c in calls:
            c.entry = ep.name
        out.extend(calls)
    return out


def discover_sites(root: str = KERNELS_DIR) -> List[Tuple[str, int]]:
    """AST ground truth: every ``pallas_call`` call expression under
    ``root`` as (abspath, lineno) — matched against captured calls to
    flag unexercised sites."""
    sites = []
    for dirpath, _dirs, files in os.walk(root):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.abspath(os.path.join(dirpath, fname))
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                name = fn.attr if isinstance(fn, ast.Attribute) else \
                    fn.id if isinstance(fn, ast.Name) else None
                if name == "pallas_call":
                    sites.append((path, node.lineno))
    return sites
