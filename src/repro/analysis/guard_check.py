"""Guarded-carry coverage auditor (DESIGN.md §§11-12).

The divergence guard is only as good as its health predicate: a carry
leaf the predicate does not read is a blind spot — NaN can live there
for the rest of the solve while the guard reports healthy rounds.  This
analyzer closes the loop SEMANTICALLY rather than syntactically: for
every guarded round-fn family (DCD/BDCD x classical/s-step) it

1. builds the family's real guarded carry on a tiny concrete problem,
2. runs one real round to obtain the post-round carry,
3. poisons each floating carry leaf with NaN, one leaf at a time, and
4. asserts ``resilience.guard.finite_health`` flags EVERY poisoned copy
   (and accepts the clean one).

* CHK-CARRY (error) — a carry leaf the health predicate misses (or a
  healthy carry it rejects).  Anchors to the family's factory ``def``
  line in ``core/``, where the guarded carry protocol is defined.

Because the audit executes the genuine factories and predicate, it
keeps passing (or failing) as carries evolve — adding a new leaf to a
guarded carry is automatically audited with zero registry edits.
"""
from __future__ import annotations

import inspect
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bdcd import KRRConfig, make_bdcd_round_fn
from repro.core.dcd import SVMConfig, make_dcd_round_fn
from repro.core.kernels import ExactGramOperator, KernelConfig
from repro.core.sstep_bdcd import make_sstep_bdcd_round_fn
from repro.core.sstep_dcd import make_sstep_dcd_round_fn
from repro.resilience.guard import finite_health

from .findings import ERROR, Finding

M, N, B, S = 16, 4, 2, 4                   # audit-problem concretization


def _problem():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((M, N)), jnp.float32)
    y = jnp.asarray(np.sign(rng.standard_normal(M)) + 0.0, jnp.float32)
    return A, y


def _families() -> List[Tuple[str, Callable, Callable, object]]:
    """(name, factory, round-runner, xs) per guarded family.  The runner
    drives ONE real round so leaves carry genuinely-computed values."""
    A, y = _problem()
    svm = SVMConfig(C=1.0, loss="l1", kernel=KernelConfig("linear"))
    krr = KRRConfig(lam=0.5, kernel=KernelConfig("linear"))
    i = jnp.asarray(3)
    idx_s = jnp.arange(S)
    valid = jnp.ones((S,), bool)
    blk = jnp.arange(B)
    blk_s = jnp.arange(S * B).reshape(S, B)
    valid_b = jnp.ones((S,), bool)

    def fam(name, factory, cfg, x, **kw):
        op = ExactGramOperator(
            (y[:, None] * A) if name.startswith("dcd") or "sstep_dcd" in name
            else A, cfg.kernel)
        rf = factory(A, y, cfg, op=op, guard=True, **kw)
        return name, factory, rf, x

    return [
        fam("dcd", make_dcd_round_fn, svm, i),
        fam("sstep_dcd", lambda A_, y_, c, **k:
            make_sstep_dcd_round_fn(A_, y_, c, S, **k), svm,
            (idx_s, valid)),
        fam("bdcd", make_bdcd_round_fn, krr, blk),
        fam("sstep_bdcd", lambda A_, y_, c, **k:
            make_sstep_bdcd_round_fn(A_, y_, c, S, **k), krr,
            (blk_s, valid_b)),
    ]


def _anchor(factory) -> Tuple[str, int]:
    """The factory's def line (unwrap the lambda shims to the real
    make_* function via its module)."""
    fn = factory
    if fn.__name__ == "<lambda>":
        mod = {"sstep_dcd": make_sstep_dcd_round_fn,
               "sstep_bdcd": make_sstep_bdcd_round_fn}
        # the lambda closes over exactly one make_* — find it
        for cand in mod.values():
            if cand.__name__ in inspect.getsource(fn):
                fn = cand
                break
    src = inspect.getsourcefile(fn)
    line = inspect.getsourcelines(fn)[1]
    return src, line


def run() -> List[Finding]:
    findings: List[Finding] = []
    for name, factory, rf, x in _families():
        path, line = _anchor(factory)
        alpha0 = jnp.zeros(M, jnp.float32)
        carry = (alpha0, jnp.zeros(M, jnp.float32))
        carry = rf(carry, x)               # one REAL round
        leaves, treedef = jax.tree_util.tree_flatten(carry)
        if not bool(finite_health(carry)):
            findings.append(Finding(
                "CHK-CARRY", ERROR, path, line,
                f"{name}: health predicate rejects a finite post-round "
                f"carry — guarded solves would freeze on round 0"))
            continue
        for k, leaf in enumerate(leaves):
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                continue
            poisoned = list(leaves)
            poisoned[k] = leaf.at[(0,) * leaf.ndim].set(jnp.nan)
            bad = jax.tree_util.tree_unflatten(treedef, poisoned)
            if bool(finite_health(bad)):
                findings.append(Finding(
                    "CHK-CARRY", ERROR, path, line,
                    f"{name}: carry leaf #{k} (shape {leaf.shape}) is "
                    f"NOT covered by the health predicate — a NaN there "
                    f"survives every guarded round undetected"))
    return findings
