"""Findings, severities, and the suppression protocol shared by every
analyzer in ``repro.analysis`` (DESIGN.md §11).

A finding is anchored to a (file, line) so it can be SUPPRESSED in
source with a justified noqa comment on the flagged line or in the
contiguous comment block immediately above it:

    # repro: noqa[CHK-STATIC] call sites only ever pass module-level
    #   functions here, so the per-closure retrace cannot trigger.

The justification is REQUIRED: a bare ``# repro: noqa[CHK-X]`` does not
suppress — it is itself reported as a CHK-NOQA error.  Several IDs may
be suppressed at once (``noqa[CHK-A,CHK-B] why``).  Suppressions are
per-line, never per-file, so a new instance of an old problem is always
a new finding.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

ERROR = "error"
WARNING = "warning"
INFO = "info"
SEVERITIES = (ERROR, WARNING, INFO)

NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\[([A-Z0-9\-,\s]+)\]\s*(.*)")


@dataclasses.dataclass
class Finding:
    """One analyzer result: ``check`` is the stable ID (catalogued in
    DESIGN.md §11), ``path``/``line`` anchor it for suppression."""

    check: str
    severity: str
    path: str
    line: int
    message: str
    suppressed: bool = False
    justification: str = ""

    def format(self) -> str:
        tag = "suppressed: " if self.suppressed else ""
        return (f"{self.path}:{self.line}: {tag}{self.severity} "
                f"[{self.check}] {self.message}")


def _noqa_at(lines: List[str], lineno: int
             ) -> Optional[Tuple[Tuple[str, ...], str]]:
    """The noqa directive governing ``lineno`` (1-based): on the line
    itself, or in the contiguous run of comment-only lines immediately
    above it.  Returns (check_ids, justification) or None.  The
    justification is the text after the bracket plus any continuation
    comment lines below the marker within the same comment block."""
    if not 1 <= lineno <= len(lines):
        return None

    def parse(i: int) -> Optional[Tuple[Tuple[str, ...], str]]:
        m = NOQA_RE.search(lines[i - 1])
        if not m:
            return None
        ids = tuple(s.strip() for s in m.group(1).split(",") if s.strip())
        just = m.group(2).strip()
        # continuation comment lines extend the justification
        j = i + 1
        while j <= len(lines) and j != lineno:
            stripped = lines[j - 1].strip()
            if not stripped.startswith("#") or NOQA_RE.search(stripped):
                break
            just = (just + " " + stripped.lstrip("# ")).strip()
            j += 1
        return ids, just

    hit = parse(lineno)
    if hit:
        return hit
    i = lineno - 1
    while i >= 1 and lines[i - 1].strip().startswith("#"):
        hit = parse(i)
        if hit:
            return hit
        i -= 1
    return None


def apply_suppressions(findings: Iterable[Finding],
                       sources: Optional[Dict[str, List[str]]] = None
                       ) -> List[Finding]:
    """Resolve noqa directives against each finding's source location.

    Suppressed findings are kept (marked, with their justification) so
    reports can show what was waived and why; a matching directive with
    an EMPTY justification converts the finding into a CHK-NOQA error
    at the directive's location.  ``sources`` maps path -> lines for
    testing; by default files are read from disk (unreadable files
    leave their findings unsuppressed).
    """
    cache: Dict[str, Optional[List[str]]] = dict(sources or {})
    out: List[Finding] = []
    for f in findings:
        if f.path not in cache:
            try:
                with open(f.path, encoding="utf-8") as fh:
                    cache[f.path] = fh.read().splitlines()
            except OSError:
                cache[f.path] = None
        lines = cache[f.path]
        hit = _noqa_at(lines, f.line) if lines else None
        if hit and f.check in hit[0]:
            ids, just = hit
            if not just:
                out.append(Finding(
                    "CHK-NOQA", ERROR, f.path, f.line,
                    f"suppression of {f.check} carries no justification "
                    f"— '# repro: noqa[{f.check}] <why>' is required"))
            else:
                out.append(dataclasses.replace(
                    f, suppressed=True, justification=just))
        else:
            out.append(f)
    return out


def render_report(findings: List[Finding]) -> str:
    """Human-readable report: active findings by severity, then the
    suppressed ones with their justifications, then a summary line."""
    active = [f for f in findings if not f.suppressed]
    supp = [f for f in findings if f.suppressed]
    rank = {s: i for i, s in enumerate(SEVERITIES)}
    active.sort(key=lambda f: (rank.get(f.severity, 99), f.path, f.line))
    lines = [f.format() for f in active]
    if supp:
        lines.append("")
        lines.append(f"-- {len(supp)} suppressed --")
        for f in sorted(supp, key=lambda f: (f.path, f.line)):
            lines.append(f"{f.format()}  ({f.justification})")
    counts = {s: sum(1 for f in active if f.severity == s)
              for s in SEVERITIES}
    lines.append("")
    lines.append(f"{len(active)} finding(s): "
                 f"{counts[ERROR]} error, {counts[WARNING]} warning, "
                 f"{counts[INFO]} info; {len(supp)} suppressed")
    return "\n".join(lines)
