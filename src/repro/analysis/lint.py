"""Jit-hygiene lint over ``src/repro/`` (DESIGN.md §11).

* CHK-TRACER (error) — Python-level branching (``if``/``while``/
  ternary) or host coercion (``bool()``/``float()``/``int()``) on a
  potentially-traced value inside a ROUND-FN closure (a function named
  ``round_fn`` or anything nested in a ``make_*round_fn`` factory).
  Round fns run under ``lax.scan``: host branching on a tracer raises
  ``TracerBoolConversionError`` at best and silently bakes in the
  trace-time value at worst.  Statically-safe tests are whitelisted:
  ``is``/``is not`` identity checks (the closure-wiring ``gram_fn is
  None`` pattern), comparisons whose subject is static array metadata
  (``.shape``/``.ndim``/``.dtype``/``.size``/``.name``), ``len()``,
  ``isinstance()``, and constants.
* CHK-PYTREE (error) — a dataclass carrying ``jnp.ndarray``-annotated
  fields that is NOT a registered pytree node: passing it across a jit
  boundary either fails or (as a static arg) hashes by object identity
  and retraces per instance.  NamedTuples are pytrees automatically
  and are skipped.
* CHK-STATIC (info) — ``static_argnames`` entries with Callable-typed
  parameters: jit caches by the callable's hash, so every lambda or
  local closure passed there silently recompiles.  Legitimate for
  module-level-function plumbing — suppress with the justification.
"""
from __future__ import annotations

import ast
import dataclasses as _dc
import importlib
import inspect
import os
import pkgutil
from typing import List, Optional, Tuple

from .findings import ERROR, INFO, Finding

SRC_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "name"}
_HOST_COERCIONS = {"bool", "float", "int"}


# ------------------------------------------------------- CHK-TRACER -----

def _is_static_expr(node: ast.expr) -> bool:
    """Conservatively: does this expression evaluate to a host value
    even when closure variables are tracers?"""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute):
        return node.attr in _STATIC_ATTRS
    if isinstance(node, ast.Subscript):
        return _is_static_expr(node.value)          # x.shape[0]
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return True
        # a comparison is host-valued only when its SUBJECT is static
        # metadata (x.shape[0] == n); tracer == constant is a tracer
        return _is_static_expr(node.left)
    if isinstance(node, ast.BoolOp):
        return all(_is_static_expr(v) for v in node.values)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
        return _is_static_expr(node.operand)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("len", "isinstance", "hasattr")
    if isinstance(node, ast.BinOp):
        return _is_static_expr(node.left) and _is_static_expr(node.right)
    return False


def _round_fn_nodes(tree: ast.AST):
    """Every function that is a round fn or lives inside a round-fn
    factory — the bodies ``lax.scan`` traces."""
    factories = [n for n in ast.walk(tree)
                 if isinstance(n, ast.FunctionDef)
                 and n.name.startswith("make_") and "round_fn" in n.name]
    seen = set()
    for fac in factories:
        for n in ast.walk(fac):
            if isinstance(n, ast.FunctionDef) and n is not fac:
                seen.add(id(n))
                yield n
    for n in ast.walk(tree):
        if isinstance(n, ast.FunctionDef) and n.name == "round_fn" \
                and id(n) not in seen:
            yield n


def _check_tracer(path: str, tree: ast.AST) -> List[Finding]:
    out = []
    for fn in _round_fn_nodes(tree):
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                test, what = node.test, type(node).__name__.lower()
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id in _HOST_COERCIONS and node.args):
                test, what = node.args[0], f"{node.func.id}()"
            else:
                continue
            if not _is_static_expr(test):
                out.append(Finding(
                    "CHK-TRACER", ERROR, path, node.lineno,
                    f"host-side {what} on a potentially traced value "
                    f"inside round fn '{fn.name}' — round fns run under "
                    f"lax.scan; use jnp.where/lax.cond or hoist the "
                    f"branch out of the traced closure"))
    return out


# ------------------------------------------------------- CHK-PYTREE -----

def _registered_pytree(cls) -> Optional[bool]:
    """True/False if the jax registry is inspectable, None if the
    private registry moved (then the check abstains rather than lies)."""
    try:
        from jax._src.tree_util import _registry
        return cls in _registry
    except Exception:
        return None


def _array_fields(cls) -> List[str]:
    names = []
    for f in _dc.fields(cls):
        ann = f.type if isinstance(f.type, str) else getattr(
            f.type, "__name__", str(f.type))
        if "ndarray" in ann or "Array" in ann:
            names.append(f.name)
    return names


def iter_repro_dataclasses():
    """Every dataclass DEFINED in a ``repro`` module (imports every
    submodule; they are all import-safe by the tier-1 suite)."""
    import repro
    for info in pkgutil.walk_packages(repro.__path__, "repro."):
        try:
            mod = importlib.import_module(info.name)
        except Exception:
            continue
        for obj in vars(mod).values():
            if (inspect.isclass(obj) and obj.__module__ == info.name
                    and _dc.is_dataclass(obj)
                    and not issubclass(obj, tuple)):
                yield mod, obj


def _check_pytree() -> List[Finding]:
    out = []
    seen = set()
    for mod, cls in iter_repro_dataclasses():
        if cls in seen:
            continue
        seen.add(cls)
        arrays = _array_fields(cls)
        if not arrays or _registered_pytree(cls) in (True, None):
            continue
        try:
            path = inspect.getsourcefile(cls)
            line = inspect.getsourcelines(cls)[1]
        except (OSError, TypeError):
            path, line = getattr(mod, "__file__", "<unknown>"), 1
        out.append(Finding(
            "CHK-PYTREE", ERROR, os.path.abspath(path), line,
            f"dataclass {cls.__name__} carries array fields "
            f"{arrays} but is not a registered pytree node — it "
            f"cannot cross a jit boundary (register via "
            f"jax.tree_util.register_dataclass, or suppress if it is "
            f"host-side only)"))
    return out


# ------------------------------------------------------- CHK-STATIC -----

def _static_argnames(dec: ast.expr) -> Optional[Tuple[int, List[str]]]:
    """(lineno, names) if ``dec`` is a partial(jax.jit, static_argnames=
    (...)) / jax.jit(static_argnames=...) decorator with literal names."""
    if not isinstance(dec, ast.Call):
        return None
    src = ast.unparse(dec.func)
    if not (src.endswith("partial") or src.endswith("jit")):
        return None
    if src.endswith("partial") and not any(
            "jit" in ast.unparse(a) for a in dec.args):
        return None
    for kw in dec.keywords:
        if kw.arg == "static_argnames":
            try:
                names = ast.literal_eval(kw.value)
            except ValueError:
                return None
            if isinstance(names, str):
                names = [names]
            return dec.lineno, list(names)
    return None


def _check_static(path: str, tree: ast.AST) -> List[Finding]:
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        for dec in fn.decorator_list:
            hit = _static_argnames(dec)
            if hit is None:
                continue
            line, names = hit
            callables = []
            for arg in fn.args.args + fn.args.kwonlyargs:
                if arg.arg in names and arg.annotation is not None \
                        and "Callable" in ast.unparse(arg.annotation):
                    callables.append(arg.arg)
            if callables:
                out.append(Finding(
                    "CHK-STATIC", INFO, path, line,
                    f"{fn.name}: Callable-typed static argnames "
                    f"{callables} — jit caches on callable identity, so "
                    f"each distinct closure retraces; pass module-level "
                    f"functions only (or suppress with the reason)"))
    return out


# ------------------------------------------------------------- entry -----

def run(root: str = SRC_ROOT) -> List[Finding]:
    findings: List[Finding] = []
    for dirpath, _dirs, files in os.walk(root):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.abspath(os.path.join(dirpath, fname))
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
            findings.extend(_check_tracer(path, tree))
            findings.extend(_check_static(path, tree))
    findings.extend(_check_pytree())
    return findings
