from .synthetic import (classification_dataset, regression_dataset,
                        sparse_classification_dataset)
from .tokens import TokenPipeline
