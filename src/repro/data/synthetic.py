"""Synthetic datasets shaped like the paper's LIBSVM benchmarks (Table 2/3).

LIBSVM files are not available offline, so the convergence and performance
experiments use generators that match the *type* (binary classification /
regression), the (m, n) scale, and the sparsity of the originals:

    duke-like:   m=44,   n=7129  dense, binary labels
    diabetes:    m=768,  n=8     dense, binary labels
    abalone:     m=4177, n=8     dense, regression
    bodyfat:     m=252,  n=14    dense, regression
    news20-like: sparse, ~0.03% density, binary labels
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def classification_dataset(key: jax.Array, m: int, n: int,
                           margin: float = 0.5, dtype=jnp.float32):
    """Two Gaussian blobs separated along a random direction, labels +-1.
    Features are scaled to unit-ish norms so RBF sigma=1 is sensible."""
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, (n,), dtype)
    w = w / jnp.linalg.norm(w)
    y = jnp.where(jax.random.bernoulli(k2, 0.5, (m,)), 1.0, -1.0).astype(dtype)
    X = jax.random.normal(k3, (m, n), dtype) / jnp.sqrt(n).astype(dtype)
    X = X + margin * y[:, None] * w[None, :] / jnp.sqrt(n).astype(dtype)
    return X, y


def regression_dataset(key: jax.Array, m: int, n: int,
                       noise: float = 0.1, dtype=jnp.float32):
    """y = sin(Xw) + noise — nonlinear so kernel methods beat linear ones."""
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (m, n), dtype) / jnp.sqrt(n).astype(dtype)
    w = jax.random.normal(k2, (n,), dtype)
    y = jnp.sin(X @ w) + noise * jax.random.normal(k3, (m,), dtype)
    return X, y


def sparse_classification_dataset(key: jax.Array, m: int, n: int,
                                  density: float = 0.001, dtype=jnp.float32):
    """Dense array with news20-like sparsity pattern (uniform nnz placement,
    paper section 4.1's load-balanced assumption).  TPU has no sparse MXU
    path so the framework computes on dense tiles; density only changes the
    effective flop count (see DESIGN.md)."""
    k1, k2, k3 = jax.random.split(key, 3)
    mask = jax.random.bernoulli(k1, density, (m, n))
    vals = jax.random.normal(k2, (m, n), dtype)
    X = jnp.where(mask, vals, 0.0)
    y = jnp.where(jax.random.bernoulli(k3, 0.5, (m,)), 1.0, -1.0).astype(dtype)
    return X, y


# The paper's dataset inventory, reproduced at matching scales.
PAPER_DATASETS = {
    "duke": dict(kind="classification", m=44, n=7129),
    "diabetes": dict(kind="classification", m=768, n=8),
    "abalone": dict(kind="regression", m=4177, n=8),
    "bodyfat": dict(kind="regression", m=252, n=14),
    "colon-cancer": dict(kind="classification", m=62, n=2000),
    "news20-like": dict(kind="sparse", m=19996, n=8192, density=0.0003),
    "synthetic-sparse": dict(kind="sparse", m=2000, n=8192, density=0.01),
}


def load(name: str, key=None, dtype=jnp.float32):
    spec = dict(PAPER_DATASETS[name])
    kind = spec.pop("kind")
    key = key if key is not None else jax.random.key(0)
    if kind == "classification":
        return classification_dataset(key, dtype=dtype, **spec)
    if kind == "regression":
        return regression_dataset(key, dtype=dtype, **spec)
    return sparse_classification_dataset(key, dtype=dtype, **spec)
