"""Deterministic synthetic token pipeline for LM training.

Design goals (1000+ node deployments):
  * **Stateless / index-derived**: batch ``k`` is a pure function of
    ``(seed, k)`` — any worker can reconstruct any batch, so restarts and
    elastic re-sharding never need data-loader state in the checkpoint.
  * **Shardable**: ``global_batch`` is laid out on the (pod, data) mesh axes
    via ``jax.make_array_from_callback``-style per-shard generation.
  * Synthetic corpus: a mixture of Zipfian unigram draws and shifted
    repeats, giving a learnable (non-uniform) next-token distribution so
    loss actually decreases in the end-to-end example.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def _zipf_logits(self) -> np.ndarray:
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        return (-1.1 * np.log(ranks)).astype(np.float32)

    def batch(self, step: int) -> dict:
        """Host-side global batch for step ``step`` (tokens + labels)."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2 = jax.random.split(key)
        logits = jnp.asarray(self._zipf_logits())
        toks = jax.random.categorical(
            k1, logits, shape=(self.global_batch, self.seq_len + 1))
        # Inject copy structure: second half repeats the first half for a
        # random subset of rows -> learnable induction pattern.
        half = (self.seq_len + 1) // 2
        copy_rows = jax.random.bernoulli(k2, 0.5, (self.global_batch, 1))
        copied = jnp.concatenate([toks[:, :half], toks[:, :self.seq_len + 1 - half]], axis=1)
        toks = jnp.where(copy_rows, copied, toks)
        return {
            "tokens": toks[:, :-1].astype(jnp.int32),
            "labels": toks[:, 1:].astype(jnp.int32),
        }

    def shard_batch(self, step: int, sharding) -> dict:
        """Device-side batch placed with the given NamedSharding."""
        host = self.batch(step)
        return {k: jax.device_put(v, sharding) for k, v in host.items()}
