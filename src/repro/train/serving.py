"""Continuous-batching serving engine.

Production serving runs a fixed-shape decode step (jit-compiled once) over
a slot matrix; requests stream in and out of slots between steps:

  * admit: a free slot gets the new request's prompt (teacher-forced
    prefill via the same decode step — no separate prefill graph needed
    at this scale);
  * step: one batched decode for all active slots;
  * retire: slots whose sequence hit EOS / max length free up.

State (KV caches / SSM states) is slot-indexed, so admissions never
reshape or recompile anything — the fixed (B_slots, S_max) decode step is
what the decode_32k / long_500k dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step, init_decode_state


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 max_seq: int = 128, eos_id: Optional[int] = None,
                 rules=None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.state = init_decode_state(cfg, n_slots, max_seq,
                                       with_encoder=bool(cfg.encoder_layers))
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.pending: List[Request] = []
        # per-slot cursor into the prompt (-1 = generating)
        self._prompt_pos = [0] * n_slots
        self._tokens = jnp.zeros((n_slots, 1), jnp.int32)

        def step(params, state, tokens):
            logits, state = decode_step(params, cfg, state, tokens,
                                        rules=rules)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return nxt, state

        self._step = jax.jit(step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.pending.append(req)

    def _reset_slot_state(self, i):
        """Zero the caches of slot i (cheap: masked where on slot axis)."""
        def zero_slot(x):
            if x.ndim >= 2 and x.shape[1] == self.n_slots:   # (L, B, ...)
                mask = (jnp.arange(self.n_slots) == i)
                mask = mask.reshape((1, self.n_slots) + (1,) * (x.ndim - 2))
                return jnp.where(mask, jnp.zeros_like(x), x)
            return x
        st = {k: jax.tree.map(zero_slot, v) for k, v in self.state.items()
              if k != "pos"}
        st["pos"] = self.state["pos"].at[i].set(0)
        self.state = st

    def _admit(self):
        for i in range(self.n_slots):
            if self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                self._reset_slot_state(i)
                self._prompt_pos[i] = 0
                self._tokens = self._tokens.at[i, 0].set(req.prompt[0])

    def step(self) -> Dict[int, int]:
        """One engine step.  Returns {rid: emitted_token} for slots that
        produced a NEW (non-prompt) token this step."""
        self._admit()
        if all(s is None for s in self.slots):
            return {}
        nxt, self.state = self._step(self.params, self.state, self._tokens)
        emitted = {}
        nxt_host = jax.device_get(nxt)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            pp = self._prompt_pos[i]
            if pp >= 0 and pp + 1 < len(req.prompt):
                # still teacher-forcing the prompt
                self._prompt_pos[i] = pp + 1
                self._tokens = self._tokens.at[i, 0].set(req.prompt[pp + 1])
                continue
            self._prompt_pos[i] = -1
            tok = int(nxt_host[i])
            req.generated.append(tok)
            emitted[req.rid] = tok
            self._tokens = self._tokens.at[i, 0].set(tok)
            seq_len = int(self.state["pos"][i])
            if (len(req.generated) >= req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)
                    or seq_len >= self.max_seq - 1):
                req.done = True
                self.slots[i] = None
        return emitted

    def run_until_done(self, max_steps: int = 10000):
        out = []
        for _ in range(max_steps):
            if not self.pending and all(s is None for s in self.slots):
                break
            self.step()
        return out
