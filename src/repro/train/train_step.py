"""Training steps.

Two distribution modes:

* ``make_train_step`` — GSPMD/jit path (FSDP + TP via sharding
  annotations).  Used by the dry-run for every (arch x shape x mesh) cell.
  Microbatching runs as a ``lax.scan`` so activation memory is bounded and
  HLO size is O(1) in the number of microbatches.

* ``make_defer_train_step`` — the paper's s-step schedule applied to LM
  data parallelism: a partial-auto ``shard_map`` keeps the (pod, data)
  axes MANUAL, so each data shard accumulates LOCAL gradients for
  ``defer_s`` microbatches and issues ONE psum per sync — the exact
  collective-count reduction (H -> H/s) of s-step DCD, visible in the
  lowered HLO.  With ``defer_s=1`` it degenerates to the classical
  communicate-every-iteration schedule (the paper's baseline).  The model
  axis stays AUTO (GSPMD handles TP inside), mirroring how the paper
  composes the s-step schedule with its 1D feature partition.
  Optionally composes int8 error-feedback compression on the synced
  gradient.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.models import ModelConfig, loss_fn, tree_shardings
from repro.models.sharding import MeshRules
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import error_feedback_compress


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    defer_s: int = 1            # sync gradients every defer_s microbatches
    compress_int8: bool = False


def _microbatch(batch, nm):
    def split(x):
        B = x.shape[0]
        assert B % nm == 0, (B, nm)
        return x.reshape(nm, B // nm, *x.shape[1:])

    # positions for mrope have a leading 3-axis; split on the batch dim
    out = {}
    for k, v in batch.items():
        if k == "positions" and v.ndim == 3:
            out[k] = jnp.moveaxis(split(jnp.moveaxis(v, 0, 1)), 2, 1)
        else:
            out[k] = split(v)
    return out


def _grad_accum_scan(params, cfg, mbatches, nm, rules, unroll=False):
    """sum of per-microbatch grads via scan (memory-bounded)."""

    def body(acc, mb):
        loss, g = jax.value_and_grad(loss_fn)(params, cfg, mb, rules=rules,
                                              unroll=unroll)
        acc_g, acc_l = acc
        return (jax.tree.map(jnp.add, acc_g, g), acc_l + loss), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum), _ = jax.lax.scan(body, (zero, 0.0), mbatches)
    inv = 1.0 / nm
    return jax.tree.map(lambda g: g * inv, gsum), lsum * inv


def make_train_step(cfg: ModelConfig, acfg: AdamWConfig,
                    tcfg: TrainConfig, rules: Optional[MeshRules] = None,
                    unroll: bool = False):
    """GSPMD train step: (params, opt_state, batch) -> (params, opt, metrics).

    Call ``.lower(...).compile()`` with ShapeDtypeStructs for the dry-run or
    with real arrays for execution; shardings ride on the avals.
    """

    def step(params, opt_state, batch):
        nm = tcfg.microbatches
        if nm > 1:
            mb = _microbatch(batch, nm)
            grads, loss = _grad_accum_scan(params, cfg, mb, nm, rules,
                                           unroll)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(
                params, cfg, batch, rules=rules, unroll=unroll)
        new_params, new_opt, om = adamw_update(acfg, params, grads,
                                               opt_state)
        return new_params, new_opt, {"loss": loss, **om}

    if rules is None:
        return jax.jit(step, donate_argnums=(0, 1))
    return jax.jit(step, donate_argnums=(0, 1))


def make_defer_train_step(cfg: ModelConfig, acfg: AdamWConfig,
                          tcfg: TrainConfig, rules: MeshRules):
    """s-step deferred-allreduce train step (paper schedule on DP).

    Params are replicated over (pod, data) and TP-sharded over model (the
    defer_s schedule trades ZeRO param sharding for local gradient
    accumulation — same trade the paper makes by replicating alpha on
    every rank).
    """
    mesh = rules.mesh
    dp_axes = rules.batch_axes
    nm, s = tcfg.microbatches, tcfg.defer_s
    assert nm % s == 0, (nm, s)

    batch_spec = P(dp_axes)

    # partial-manual shard_map: (pod, data) axes are MANUAL (we control the
    # psum cadence), the model axis stays AUTO (GSPMD does TP inside).
    @partial(shard_map, mesh=mesh,
             in_specs=(P(), P(), batch_spec), out_specs=(P(), P(), P()),
             axis_names=frozenset(dp_axes), check_vma=False)
    def step(params, opt_state, batch):
        mb = _microbatch(batch, nm)
        rounds = jax.tree.map(
            lambda x: x.reshape(nm // s, s, *x.shape[1:]), mb)

        def outer(carry, round_mb):
            params_c, acc, resid = carry

            def inner(acc_l, one_mb):
                loss, g = jax.value_and_grad(loss_fn)(
                    params_c, cfg, one_mb, rules=None)
                gacc, lacc = acc_l
                return (jax.tree.map(jnp.add, gacc, g), lacc + loss), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params_c)
            (g_local, l_local), _ = jax.lax.scan(inner, (zero, 0.0),
                                                 round_mb)
            if tcfg.compress_int8:
                # int8 + error feedback: only the quantized payload crosses
                # the wire; the residual stays local across rounds.
                g_local, resid = error_feedback_compress(g_local, resid)
            # THE s-step moment: one collective per s microbatches
            g_sync = jax.tree.map(
                lambda g: jax.lax.psum(g, dp_axes), g_local)
            l_sync = jax.lax.psum(l_local, dp_axes)
            return (params_c, (jax.tree.map(jnp.add, acc[0], g_sync),
                               acc[1] + l_sync), resid), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        zero_r = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        (_, (gsum, lsum), _), _ = jax.lax.scan(
            outer, (params, (zero, 0.0), zero_r), rounds)
        ndev = 1
        for a in dp_axes:
            ndev *= mesh.shape[a]
        inv = 1.0 / (nm * ndev)
        grads = jax.tree.map(lambda g: g * inv, gsum)
        new_params, new_opt, om = adamw_update(acfg, params, grads,
                                               opt_state)
        return new_params, new_opt, {"loss": lsum * inv, **om}

    return jax.jit(step, donate_argnums=(0, 1))


def init_train_state(key, cfg: ModelConfig, acfg: AdamWConfig,
                     rules: Optional[MeshRules] = None):
    from repro.models import init_params
    params = init_params(key, cfg)
    opt = adamw_init(params)
    if rules is not None:
        params = jax.device_put(params, tree_shardings(rules, params))
        opt = jax.device_put(
            opt, {"m": tree_shardings(rules, opt["m"]),
                  "v": tree_shardings(rules, opt["v"]),
                  "step": NamedSharding(rules.mesh, P())})
    return params, opt
