"""Serving: jitted single-token decode step + a simple generation loop.

The decode step is what the ``decode_32k`` / ``long_500k`` dry-run cells
lower: one new token against a KV cache (or SSM state) of ``seq_len``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step
from repro.models.sharding import MeshRules


def make_serve_step(cfg: ModelConfig, rules: Optional[MeshRules] = None,
                    temperature: float = 0.0):
    """(params, state, tokens(B,1), key) -> (next_tokens(B,1), state)."""

    def step(params, state, tokens, key):
        logits, state = decode_step(params, cfg, state, tokens, rules=rules)
        if temperature > 0.0:
            nxt = jax.random.categorical(key, logits / temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        return nxt[:, None].astype(jnp.int32), state

    return jax.jit(step, donate_argnums=(1,))


def greedy_generate(params, cfg: ModelConfig, state, prompt, n_tokens: int,
                    rules=None, temperature: float = 0.0, key=None):
    """Feed ``prompt`` (B, P) token-by-token, then generate ``n_tokens``."""
    step = make_serve_step(cfg, rules, temperature)
    key = key if key is not None else jax.random.key(0)
    B, P = prompt.shape
    tok = prompt[:, :1]
    outs = []
    for t in range(P + n_tokens - 1):
        key, sub = jax.random.split(key)
        nxt, state = step(params, state, tok, sub)
        tok = prompt[:, t + 1:t + 2] if t + 1 < P else nxt
        if t + 1 >= P:
            outs.append(tok)
    return jnp.concatenate(outs, axis=1) if outs else prompt[:, :0], state
