from .train_step import TrainConfig, make_train_step, make_defer_train_step
from .serve_step import make_serve_step, greedy_generate
from .checkpoint import (CheckpointManager, load_checkpoint,
                         save_checkpoint)
