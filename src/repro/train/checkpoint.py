"""Checkpointing: atomic, async-capable, elastic (mesh-independent).

Layout (one directory per step):
    <dir>/step_0000042.tmp/...   -> os.rename -> <dir>/step_0000042/
        meta.json                   step, config name, leaf index
        leaf_00000.npy ...          one .npy per pytree leaf (host arrays)

Design points for 1000+ nodes (DESIGN.md §5):
  * checkpoints store the LOGICAL pytree, not the physical layout — on
    restore the arrays are device_put with whatever sharding the *current*
    mesh prescribes, so you can restart 2-pod state on 1 pod (elastic
    downscale) or reshard to a new topology;
  * atomic rename makes a partially-written checkpoint invisible to
    resume-latest (preemption-safe);
  * the async writer snapshots to host (device_get) on the caller thread
    — cheap — and does file IO on a background thread, off the step
    critical path;
  * keep_last garbage collection bounds disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _key_str(k) -> str:
    # jax path keys carry their payload under different attribute names:
    # DictKey/FlattenedIndexKey -> .key, SequenceKey -> .idx,
    # GetAttrKey (registered dataclasses / *_with_keys pytrees) -> .name.
    # The old fallback str(k) turned GetAttrKey into ".A" — garbage paths
    # for every registered GramOperator leaf.
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _paths_and_leaves(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(_key_str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves


def _to_npy(arr: np.ndarray):
    """(savable_array, dtype_str).  Extension dtypes (bfloat16, float8_*
    from ml_dtypes — numpy kind 'V') are not representable in the .npy
    header and silently round-trip as raw void bytes; store them
    bit-exactly as a same-itemsize uint view and record the true dtype
    in meta so ``_from_npy`` can reinterpret."""
    dtype = str(arr.dtype)
    if arr.dtype.kind == "V":
        arr = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32,
                        8: np.uint64}[arr.dtype.itemsize])
    return arr, dtype


def _from_npy(arr: np.ndarray, dtype: Optional[str]) -> np.ndarray:
    if dtype is None or str(arr.dtype) == dtype:
        return arr
    return arr.view(np.dtype(dtype))     # bit-exact reinterpretation


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Blocking save.  Returns the final checkpoint path."""
    paths, leaves = _paths_and_leaves(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    dtypes = []
    for i, arr in enumerate(host):
        savable, dtype = _to_npy(arr)
        dtypes.append(dtype)
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), savable)
    meta = {"step": step, "paths": paths, "dtypes": dtypes,
            "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, step: Optional[int] = None,
                    template: Any = None, shardings: Any = None):
    """Load (latest by default).  ``template`` supplies the treedef;
    ``shardings`` (optional pytree of NamedSharding) reshards elastically
    onto the current mesh."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    dtypes = meta.get("dtypes") or [None] * len(meta["paths"])
    arrs = [_from_npy(np.load(os.path.join(path, f"leaf_{i:05d}.npy")), dt)
            for i, dt in zip(range(len(meta["paths"])), dtypes)]
    if template is not None:
        treedef = jax.tree_util.tree_structure(template)
        tree = jax.tree_util.tree_unflatten(treedef, arrs)
    else:
        tree = arrs
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta


def available_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            out.append(int(name[5:]))
    return sorted(out)


class CheckpointManager:
    """Async checkpointing with keep-last-k GC and resume-latest."""

    def __init__(self, directory: str, keep_last: int = 3,
                 save_every: int = 100):
        self.directory = directory
        self.keep_last = keep_last
        self.save_every = save_every
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save_async(self, step: int, tree: Any, extra=None):
        """Snapshot on the caller thread, write on a background thread."""
        self.wait()                       # one in-flight write at a time
        paths, leaves = _paths_and_leaves(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        treedef = jax.tree_util.tree_structure(tree)
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save_checkpoint(self.directory, step, snapshot, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = available_steps(self.directory)
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, template, shardings=None):
        self.wait()
        if not available_steps(self.directory):
            return None, None
        return load_checkpoint(self.directory, template=template,
                               shardings=shardings)
