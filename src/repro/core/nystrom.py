"""Nystrom kernel approximation — the paper's stated future work
("we plan to further optimize the s-step methods' kernel computation ...
by approximating the sampled kernel matrix (for example using the Nystrom
method)", Conclusion).

K is approximated with l landmark rows:  K ~= Phi Phi^T  where
Phi = K(., L) K_LL^{-1/2} in R^{m x l}.  Because our DCD/BDCD solvers
consume kernels only through a ``GramOperator``, Nystrom-(B)DCD is the
LINEAR-kernel reduction over the factor Phi — packaged as
``kernels.LowRankGramOperator`` — so the per-round slab cost drops from
O(s*b*f*m*n / P) to O(s*b*m*l / P) flops and the stored dataset from
fmn/P to ml/P words, at the accuracy cost bounded by the kernel's
spectral tail (rank-l approximation error, ``nystrom_kernel_error``).

Prefer the ``repro.api`` facade over hand-wiring this module:
``SolverOptions(approx="nystrom", landmarks=l)`` builds the feature map
and the ``LowRankGramOperator`` once, fits through it on any layout, and
serves predictions through the same operator (``core/predict.py``,
DESIGN.md §9).  The functions below are the building blocks the facade —
and the parity tests — compose.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bdcd import KRRConfig
from .kernels import KernelConfig, LowRankGramOperator, gram_slab

LANDMARK_METHODS = ("uniform", "kmeans")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NystromMap:
    """Fitted Nystrom feature map ``phi(x) = K(x, L) @ K_LL^{-1/2}``.

    A registered pytree (landmarks + transform are data, the kernel
    config is static), so it can ride inside a ``LowRankGramOperator``
    across jit boundaries — the serving path maps query blocks with it.
    """

    landmarks: jnp.ndarray                 # (l, n)
    transform: jnp.ndarray                 # (l, l) = K_LL^{-1/2}
    kernel: KernelConfig = dataclasses.field(
        default_factory=KernelConfig,
        metadata=dict(static=True))

    def __call__(self, X: jnp.ndarray) -> jnp.ndarray:
        """phi(X): (q, n) -> (q, l)."""
        return gram_slab(X, self.landmarks, self.kernel) @ self.transform

    @property
    def rank(self) -> int:
        return self.landmarks.shape[0]


@partial(jax.jit, static_argnames=("cfg", "jitter"))
def nystrom_map(A: jnp.ndarray, landmarks: jnp.ndarray,
                cfg: KernelConfig, jitter: float = 1e-6) -> jnp.ndarray:
    """Phi = K(A, L) @ K_LL^{-1/2}  (symmetric inverse square root via
    eigendecomposition, eigenvalue-floored for stability)."""
    K_al = gram_slab(A, landmarks, cfg)               # (m, l)
    return K_al @ _inv_sqrt_gram(landmarks, cfg, jitter)


def _inv_sqrt_gram(landmarks: jnp.ndarray, cfg: KernelConfig,
                   jitter: float) -> jnp.ndarray:
    K_ll = gram_slab(landmarks, landmarks, cfg)       # (l, l)
    w, V = jnp.linalg.eigh(K_ll)
    w = jnp.maximum(w, jitter)
    return (V * (w ** -0.5)) @ V.T


def kmeans_landmarks(key, A: jnp.ndarray, l: int,
                     iters: int = 10) -> jnp.ndarray:
    """Lloyd's-algorithm landmarks (fixed iteration count, pure lax):
    cluster centroids cover the data manifold far better than uniform
    draws when the data is clustered, which is exactly when the kernel
    spectrum decays fast and Nystrom shines (Zhang & Kwok, 2008).

    Initialization is farthest-first traversal (the deterministic
    kmeans++ variant): uniform seeding routinely drops whole clusters —
    duplicated seeds merge and the empty-cluster rule keeps them stale —
    which costs O(sqrt(cluster mass / total)) in kernel error per miss.

    ``key`` is the ONLY source of randomness (it draws the first
    center; everything after is deterministic), so landmark choice —
    and with it the whole Nystrom fit — replays exactly from the facade
    seed: ``SolverOptions.seed`` folds into the landmark key in
    ``api._build_representation`` just like the schedule key
    (tests/test_tune.py::test_nystrom_seed_reproducible_end_to_end).
    """
    m = A.shape[0]
    a_sq = jnp.sum(A * A, axis=1)                     # loop-invariant

    def _sq_dist_to(c):
        return jnp.maximum(a_sq + jnp.sum(c * c) - 2.0 * A @ c, 0.0)

    def seed(carry, _):
        centers, mind, k = carry
        nxt = A[jnp.argmax(mind)]
        centers = centers.at[k].set(nxt)
        return (centers, jnp.minimum(mind, _sq_dist_to(nxt)), k + 1), None

    first = A[jax.random.randint(key, (), 0, m)]
    init0 = jnp.zeros((l, A.shape[1]), A.dtype).at[0].set(first)
    (init, _, _), _ = jax.lax.scan(
        seed, (init0, _sq_dist_to(first), 1), None, length=l - 1)

    def step(centers, _):
        d = (a_sq[:, None] + jnp.sum(centers * centers, axis=1)[None, :]
             - 2.0 * A @ centers.T)                   # (m, l) sq dists
        assign = jnp.argmin(d, axis=1)
        onehot = (assign[:, None] == jnp.arange(l)[None, :]).astype(A.dtype)
        counts = jnp.sum(onehot, axis=0)              # (l,)
        sums = onehot.T @ A                           # (l, n)
        new = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts, 1.0)[:, None], centers)
        return new, None

    centers, _ = jax.lax.scan(step, init, None, length=iters)
    return centers


def choose_landmarks(key, A: jnp.ndarray, l: int,
                     method: str = "uniform") -> jnp.ndarray:
    """Landmark selection: ``"uniform"`` row sampling (paper-adjacent
    baseline) or ``"kmeans"`` centroids (``kmeans_landmarks``);
    leverage-score sampling is a further refinement."""
    if method not in LANDMARK_METHODS:
        raise ValueError(f"landmark method must be one of "
                         f"{LANDMARK_METHODS}, got {method!r}")
    if method == "kmeans":
        return kmeans_landmarks(key, A, l)
    idx = jax.random.choice(key, A.shape[0], (l,), replace=False)
    return A[idx]


def fit_nystrom(key, A: jnp.ndarray, cfg: KernelConfig, l: int,
                method: str = "uniform", jitter: float = 1e-6) -> NystromMap:
    """Choose landmarks and fit the feature map in one step — the
    representation build the ``repro.api`` facade performs once per
    ``fit`` (and reuses at predict time)."""
    landmarks = choose_landmarks(key, A, l, method=method)
    return NystromMap(landmarks=landmarks,
                      transform=_inv_sqrt_gram(landmarks, cfg, jitter),
                      kernel=cfg)


def lowrank_operator(fmap: NystromMap, A: jnp.ndarray
                     ) -> LowRankGramOperator:
    """``LowRankGramOperator`` over ``Phi = fmap(A)`` — the pluggable
    backend the solvers and the predict subsystem consume."""
    return LowRankGramOperator(Phi=fmap(A), fmap=fmap)


def nystrom_kernel_error(A, landmarks, cfg: KernelConfig) -> float:
    """||K - Phi Phi^T||_F / ||K||_F — the rank-l approximation error."""
    K = gram_slab(A, A, cfg)
    Phi = nystrom_map(A, landmarks, cfg)
    return float(jnp.linalg.norm(K - Phi @ Phi.T) / jnp.linalg.norm(K))


class NystromKRRSetup(NamedTuple):
    """Everything ``nystrom_krr_setup`` produced: run any BDCD variant on
    (Phi, y) with ``cfg``, and keep ``landmarks`` / ``feature_map`` — the
    predict path needs them to map queries into the same feature space
    (the old bare (Phi, cfg) tuple lost them)."""

    Phi: jnp.ndarray                       # (m, l) training features
    cfg: KRRConfig                         # linear-kernel KRR config
    landmarks: jnp.ndarray                 # (l, n)
    feature_map: NystromMap


def nystrom_krr_setup(key, A, cfg: KRRConfig, l: int,
                      method: str = "uniform") -> NystromKRRSetup:
    """Returns ``NystromKRRSetup(Phi, cfg, landmarks, feature_map)``: run
    any of the BDCD / s-step BDCD solvers (serial or distributed) on
    (Phi, y) with the returned linear-kernel config and you are solving
    K-RR under the Nystrom kernel.

    The s-step communication structure is untouched — this composes with
    the paper's schedule (the slab GEMM just got cheaper), which is
    exactly the paper's proposed combination.
    """
    fmap = fit_nystrom(key, A, cfg.kernel, l, method=method)
    lin_cfg = KRRConfig(lam=cfg.lam, kernel=KernelConfig("linear"))
    return NystromKRRSetup(Phi=fmap(A), cfg=lin_cfg,
                           landmarks=fmap.landmarks, feature_map=fmap)
