"""Nystrom kernel approximation — the paper's stated future work
("we plan to further optimize the s-step methods' kernel computation ...
by approximating the sampled kernel matrix (for example using the Nystrom
method)", Conclusion).

K is approximated with l landmark rows:  K ~= Phi Phi^T  where
Phi = K(., L) K_LL^{-1/2} in R^{m x l}.  Because our DCD/BDCD solvers take
an arbitrary ``gram_fn``, Nystrom-BDCD is simply the LINEAR-kernel solver
on the feature map Phi — the per-round slab cost drops from
O(s*b*f*m*n / P) to O(s*b*m*l / P) flops and the stored dataset from
fmn/P to ml/P words, at the accuracy cost bounded by the kernel's
spectral tail (rank-l approximation error).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .bdcd import KRRConfig
from .kernels import KernelConfig, gram_slab


@partial(jax.jit, static_argnames=("cfg", "jitter"))
def nystrom_map(A: jnp.ndarray, landmarks: jnp.ndarray,
                cfg: KernelConfig, jitter: float = 1e-6) -> jnp.ndarray:
    """Phi = K(A, L) @ K_LL^{-1/2}  (symmetric inverse square root via
    eigendecomposition, eigenvalue-floored for stability)."""
    K_al = gram_slab(A, landmarks, cfg)               # (m, l)
    K_ll = gram_slab(landmarks, landmarks, cfg)       # (l, l)
    w, V = jnp.linalg.eigh(K_ll)
    w = jnp.maximum(w, jitter)
    inv_sqrt = (V * (w ** -0.5)) @ V.T
    return K_al @ inv_sqrt


def choose_landmarks(key, A: jnp.ndarray, l: int) -> jnp.ndarray:
    """Uniform landmark sampling (paper-adjacent baseline; leverage-score
    sampling is a further refinement)."""
    idx = jax.random.choice(key, A.shape[0], (l,), replace=False)
    return A[idx]


def nystrom_kernel_error(A, landmarks, cfg: KernelConfig) -> float:
    """||K - Phi Phi^T||_F / ||K||_F — the rank-l approximation error."""
    K = gram_slab(A, A, cfg)
    Phi = nystrom_map(A, landmarks, cfg)
    return float(jnp.linalg.norm(K - Phi @ Phi.T) / jnp.linalg.norm(K))


def nystrom_krr_setup(key, A, cfg: KRRConfig, l: int
                      ) -> Tuple[jnp.ndarray, KRRConfig]:
    """Returns (Phi, linear-kernel KRRConfig): run any of the BDCD /
    s-step BDCD solvers (serial or distributed) on (Phi, y) with the
    returned config and you are solving K-RR under the Nystrom kernel.

    The s-step communication structure is untouched — this composes with
    the paper's schedule (the slab GEMM just got cheaper), which is
    exactly the paper's proposed combination.
    """
    landmarks = choose_landmarks(key, A, l)
    Phi = nystrom_map(A, landmarks, cfg.kernel)
    lin_cfg = KRRConfig(lam=cfg.lam, kernel=KernelConfig("linear"))
    return Phi, lin_cfg
