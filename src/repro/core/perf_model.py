"""Hockney-model performance analysis (paper Section 4, Theorems 1-2).

T = gamma*F + beta*W + phi*L  with per-iteration costs:

  BDCD:        F = b*f*m*n/P + mu*b*m + b^3 + b*m      W = b*m      L = log P
  s-step BDCD: per OUTER round (s inner solves):
               F = s*b*f*m*n/P + mu*s*b*m + s*b^3 + C(s,2)*b^2 + s*b*m
               W = s*b*m                               L = log P

DCD (K-SVM) is the b=1 specialization.  These closed forms power the
strong-scaling predictions (benchmarks/fig3) that mirror the paper's Cray
EX experiments, calibrated with machine parameters measured on this host
(gamma) and standard HPC interconnect constants (beta, phi).

Both kernel *representations* are priced (DESIGN.md §9): exact rounds at
data width n with the kernel's epilogue cost mu, low-rank (Nystrom)
rounds at width l with linear-kernel mu plus the one-time
``lowrank_setup_cost``; ``modeled_predict_cost`` prices serving for both
(and the SV fraction for compacted K-SVM models).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Machine:
    gamma: float = 1.0 / 50e9     # s/flop  (~50 GFLOP/s per core, DGEMM)
    beta: float = 8.0 / 25e9      # s/word  (8B words over 25 GB/s links)
    phi: float = 2.0e-6           # s/message (Cray EX / Slingshot-ish)
    mu: float = 20.0              # non-linear kernel op cost in flop units


@dataclasses.dataclass(frozen=True)
class Problem:
    m: int
    n: int
    f: float = 1.0                # nnz density
    b: int = 1
    H: int = 1000                 # total (inner) iterations
    kernel: str = "rbf"


def _mu(mach: Machine, prob) -> float:
    """Kernel-epilogue op cost in flop units; accepts a Problem or name."""
    kernel = prob if isinstance(prob, str) else prob.kernel
    return {"linear": 1.0, "polynomial": mach.mu / 2, "rbf": mach.mu}[
        kernel]


def bdcd_cost(prob: Problem, mach: Machine, P: int) -> dict:
    """Classical BDCD total cost for H iterations on P processors."""
    b, m, n, f, H = prob.b, prob.m, prob.n, prob.f, prob.H
    mu = _mu(mach, prob)
    F = H * (b * f * m * n / P + mu * b * m + b ** 3 + b * m)
    W = H * b * m
    L = H * math.log2(max(P, 2))
    return {"flops": F, "words": W, "msgs": L,
            "time": mach.gamma * F + mach.beta * W + mach.phi * L,
            "t_comp": mach.gamma * F, "t_band": mach.beta * W,
            "t_lat": mach.phi * L}


def sstep_bdcd_cost(prob: Problem, mach: Machine, P: int, s: int) -> dict:
    """s-step BDCD total cost for H inner iterations (H/s outer rounds)."""
    b, m, n, f, H = prob.b, prob.m, prob.n, prob.f, prob.H
    mu = _mu(mach, prob)
    rounds = H / s
    F = rounds * (s * b * f * m * n / P + mu * s * b * m + s * b ** 3
                  + math.comb(s, 2) * b ** 2 + s * b * m)
    W = rounds * (s * b * m)
    L = rounds * math.log2(max(P, 2))
    return {"flops": F, "words": W, "msgs": L,
            "time": mach.gamma * F + mach.beta * W + mach.phi * L,
            "t_comp": mach.gamma * F, "t_band": mach.beta * W,
            "t_lat": mach.phi * L}


def best_s(prob: Problem, mach: Machine, P: int,
           candidates=(1, 2, 4, 8, 16, 32, 64, 128, 256),
           hbm_bytes: int = 16 * 2 ** 30, word: int = 4,
           return_frontier: bool = False) -> tuple:
    """Offline tuning of s (paper 5.2.1): best predicted time among the
    FEASIBLE candidates — an s whose per-round KMV working set (the
    ``m x s*b`` slab bound of ``slab_fits_hbm``) cannot be resident is
    excluded, so callers (the repro.tune autotuner) never get a plan the
    memory system cannot execute.  s=1 is always kept as a fallback
    (the classical schedule's m x b column set is the solver's floor).

    ``return_frontier=True`` additionally returns the searched frontier:
    ``[{"s", "time", "feasible"}, ...]`` over ALL candidates (infeasible
    ones carry their modeled time too — the frontier shows what the
    memory ceiling cost us).
    """
    frontier = []
    for s in candidates:
        feasible = s == 1 or slab_fits_hbm(prob.m, s * prob.b,
                                           hbm_bytes, word)
        frontier.append({"s": s,
                         "time": sstep_bdcd_cost(prob, mach, P, s)["time"],
                         "feasible": feasible})
    feas = [f for f in frontier if f["feasible"]]
    best = min(feas, key=lambda f: f["time"])
    if return_frontier:
        return best["s"], best["time"], frontier
    return best["s"], best["time"]


def storage_words(prob: Problem, P: int, s: int = 1) -> float:
    """Theorem 1/2 storage: fmn/P + s*b*m."""
    return prob.f * prob.m * prob.n / P + s * prob.b * prob.m


def lowrank_setup_cost(m: int, n: int, l: int, kernel: str,
                       mach: Machine = None, P: int = 1) -> dict:
    """One-time cost of building the rank-l Nystrom representation:
    the ``K(A, L)`` slab (m*l*n MACs + epilogue), the l x l
    eigendecomposition (~10 l^3 — LAPACK's classic constant), and the
    ``m x l x l`` feature-map GEMM.  The m-scaled terms shard over P
    (rows are embarrassingly parallel); the eigh is redundant per rank.
    """
    mach = mach or Machine()
    mu = _mu(mach, kernel)
    F = (m * l * n + mu * m * l + m * l * l) / P + 10.0 * l ** 3
    return {"flops": F, "time": mach.gamma * F}


def modeled_fit_cost(m: int, n: int, kernel: str, *, b: int = 1,
                     s: int = 1, iters: int = 1, P: int = 1,
                     mach: Machine = None, approx: str = None,
                     landmarks: int = 0) -> dict:
    """Hockney-model cost summary for a completed solver run — the
    ``FitResult.comm`` payload of the ``repro.api`` facade.  ``iters`` is
    the number of INNER iterations actually executed (early stopping
    shrinks it), ``P`` the processor count implied by the layout; ``s=1``
    prices the classical per-iteration collective schedule.

    ``approx="nystrom"`` prices the LOW-RANK representation instead: the
    per-round slab GEMM runs over the rank-``landmarks`` linear factor
    Phi (width l, mu = 1 — no nonlinear epilogue in the round loop), the
    one-time ``lowrank_setup_cost`` is folded into flops/time and
    reported separately under ``setup_flops``/``setup_time``, and the
    psum payload is the CONTRACTED ``(s*b, s*b+1)`` words the linear
    all-reduce operator actually moves per round — not the Theorem-2
    ``s*b*m`` pre-epilogue payload, which only nonlinear kernels must
    psum (exact-path pricing keeps the paper's model for fidelity).
    """
    mach = mach or Machine()
    # price whole communication rounds: a ragged final round (pad-and-
    # mask) still issues a full-size collective, so round iters up to
    # ceil(iters/s) rounds — keeping comm['msgs'] consistent with the
    # FitResult.rounds_run reported for the same run.
    H = max(iters, 1) if s <= 1 else -(-max(iters, 1) // s) * s
    if approx:
        prob = Problem(m=m, n=max(landmarks, 1), b=max(b, 1), H=H,
                       kernel="linear")
    else:
        prob = Problem(m=m, n=n, b=max(b, 1), H=H, kernel=kernel)
    cost = (bdcd_cost(prob, mach, P) if s <= 1
            else sstep_bdcd_cost(prob, mach, P, s))
    # problem identity rides along so downstream consumers (the
    # repro.obs audit re-pricing guard overhead) need only this dict
    cost = dict(cost, m=m, n=n, kernel=kernel, b=b,
                P=P, s=s, iters=iters, approx=approx,
                landmarks=landmarks if approx else 0)
    if approx:
        setup = lowrank_setup_cost(m, n, max(landmarks, 1), kernel,
                                   mach, P)
        cost["setup_flops"] = setup["flops"]
        cost["setup_time"] = setup["time"]
        cost["flops"] += setup["flops"]
        cost["t_comp"] += setup["time"]
        # linear-factor rounds psum only the contracted quantities
        sb = max(s, 1) * max(b, 1)
        rounds = H if s <= 1 else H / s
        cost["words"] = rounds * sb * (sb + 1)
        cost["t_band"] = mach.beta * cost["words"]
        cost["time"] = cost["t_comp"] + cost["t_band"] + cost["t_lat"]
    return cost


def fleet_fit_cost(m: int, n: int, kernel: str, F: int, *, b: int = 1,
                   s: int = 1, iters: int = 1, P: int = 1,
                   mach: Machine = None, approx: str = None,
                   landmarks: int = 0) -> dict:
    """Hockney-model cost of a vmapped F-member solver fleet
    (repro.tune.solve_fleet, DESIGN.md §10) vs F sequential fits.

    The fleet shares ONE operator, so per round the slab GEMM and its
    nonlinear epilogue — the paper's dominant terms — are computed once
    for the whole fleet (under ``jax.vmap`` the operator leaves are
    unbatched; only the per-member ``U^T alpha_f`` contraction, the
    O((sb)^2) correction solves, and the state updates batch by F).
    Sequential fits pay everything F times.  The modeled ratio
    ``sequential_time / time`` is the fleet speedup ``benchmarks/
    fig7_sweep.py`` measures.
    """
    mach = mach or Machine()
    single = modeled_fit_cost(m, n, kernel, b=b, s=s, iters=iters, P=P,
                              mach=mach, approx=approx,
                              landmarks=landmarks)
    H = max(iters, 1) if s <= 1 else -(-max(iters, 1) // s) * s
    rounds = H if s <= 1 else H / s
    width = max(landmarks, 1) if approx else n
    mu = 1.0 if approx else _mu(mach, kernel)
    sb = max(s, 1) * max(b, 1)
    # shared once per round: slab GEMM + epilogue (+ one-time setup)
    shared = rounds * (sb * m * width / P + mu * sb * m)
    setup = single.get("setup_flops", 0.0)
    # batched per member: U^T alpha (sb*m), the sb^2-scale correction
    # solves, and the state update
    per_member = rounds * (sb * m + s * max(b, 1) ** 3
                           + math.comb(max(s, 1), 2) * max(b, 1) ** 2
                           + sb * m)
    flops = shared + setup + F * per_member
    # the collective payload batches by F only for the contracted
    # (low-rank / linear) quantities; the pre-epilogue m x sb psum of the
    # nonlinear exact path is SHARED — same words as a single solve
    words = single["words"] * (F if approx else 1)
    msgs = single["msgs"]
    time = mach.gamma * flops + mach.beta * words + mach.phi * msgs
    return {"flops": flops, "words": words, "msgs": msgs, "time": time,
            "t_comp": mach.gamma * flops, "t_band": mach.beta * words,
            "t_lat": mach.phi * msgs, "F": F, "P": P, "s": s,
            "iters": iters, "approx": approx,
            "landmarks": landmarks if approx else 0,
            "sequential_time": F * single["time"],
            "modeled_speedup": F * single["time"] / time}


def modeled_predict_cost(m: int, n: int, q: int, kernel: str, *,
                         approx: str = None, landmarks: int = 0,
                         sv_fraction: float = 1.0,
                         mach: Machine = None, stream: int = 0,
                         word: int = 4,
                         dma_bps: float = None) -> dict:
    """Per-batch serving cost (DESIGN.md §9) for ``q`` queries against an
    ``m``-sample model: exact representations pay the ``q x m_sv`` kernel
    block (KMV-streamed, never materialized — flops only, zero slab
    words), low-rank ones pay the O(l)-per-query feature map.  The
    crossover ``l < sv_fraction * m * n / (n + l)`` is the serving
    argument for Nystrom (Hsieh et al., CA-SVM lineage).

    ``stream=chunk_rows`` prices OUT-OF-CORE query batches (DESIGN.md
    §14): the query stream arrives in (chunk_rows x n) host chunks DMA'd
    through the same double-buffered pipe as training, so the block pays
    ``max(t_comp, t_dma)`` per chunk plus the warm-up DMA instead of
    pure compute — the added keys ``t_dma``/``t_overlap``/
    ``compute_bound`` expose the regime."""
    mach = mach or Machine()
    mu = _mu(mach, kernel)
    if approx:
        l = max(landmarks, 1)
        # phi(Xq): q*l*n MACs + epilogue, transform q*l*l, dot q*l
        F = q * l * n + mu * q * l + q * l * l + q * l
    else:
        msv = max(1, int(sv_fraction * m))
        F = q * msv * n + mu * q * msv + q * msv
    t_comp = mach.gamma * F
    cost = {"flops": F, "time": t_comp,
            "flops_per_query": F / max(q, 1)}
    if stream and stream > 0:
        bps = STREAM_DMA_BPS if dma_bps is None else dma_bps
        n_chunks = max(1, -(-q // stream))
        t_dma = word * q * n / bps           # total query-chunk DMA
        per_comp, per_dma = t_comp / n_chunks, t_dma / n_chunks
        time = per_dma + n_chunks * max(per_comp, per_dma)
        cost.update(time=time, t_dma=t_dma,
                    t_overlap=time - t_comp,
                    stream_chunks=n_chunks,
                    compute_bound=per_comp >= per_dma)
    return cost


# --------------------------------------------------------------------------
# Serving latency model (DESIGN.md §13): continuous batching with a full
# drain per engine step.  Each step admits every queued request (up to
# the ``slots`` admission window) and serves them as ONE bucketed block
# through ``core/predict.py``; the step duration IS the batch window.
# Deterministic-drain queueing: a request arrives uniformly within the
# current step, waits for the step boundary, and is served by the next
# step — latency in (T, 2T] for steady step time T, so p50 = 1.5 T and
# p99 = 1.99 T.  The steady step time follows the predictor's
# power-of-two BUCKETS (an admitted batch of 13 pads to 16 and costs
# 16), so the model iterates the bucketed drain recurrence instead of
# assuming a linear T(b).  ``benchmarks/fig9_serve.py`` measures the
# engine against exactly this model (with gamma/dispatch calibrated
# on-host).
# --------------------------------------------------------------------------

SERVE_DISPATCH_S = 50e-6           # per-block host->device dispatch cost


def serve_bucket(q: float, slots: int) -> int:
    """The power-of-two block shape a q-row admission pads to (mirrors
    ``BatchedPredictor.block_shape``: minimum bucket 8, capped at the
    admission window)."""
    q = max(int(-(-q // 1)), 1)
    if q >= slots:
        return slots
    return min(slots, max(8, 1 << (q - 1).bit_length()))


def serve_block_time(q: int, m: int, n: int, kernel: str, *,
                     approx: str = None, landmarks: int = 0,
                     sv_fraction: float = 1.0, mach: Machine = None,
                     dispatch_s: float = SERVE_DISPATCH_S) -> float:
    """Modeled wall time of ONE q-query block through the batched
    predictor: the representation's per-query flops
    (``modeled_predict_cost``) plus a fixed per-block dispatch cost —
    the term that makes batching win (F flops amortize, dispatch does
    not)."""
    cost = modeled_predict_cost(m, n, max(q, 1), kernel, approx=approx,
                                landmarks=landmarks,
                                sv_fraction=sv_fraction, mach=mach)
    return cost["time"] + dispatch_s


def modeled_serve_latency(rate_qps: float, slots: int, m: int, n: int,
                          kernel: str, *, approx: str = None,
                          landmarks: int = 0, sv_fraction: float = 1.0,
                          mach: Machine = None,
                          dispatch_s: float = SERVE_DISPATCH_S,
                          ticket_s: float = 0.0,
                          tail_factor: float = 1.0) -> dict:
    """Steady-state latency/throughput of the continuous-batching engine
    at ``rate_qps`` with an admission window of ``slots`` queries/step.

    The steady batch is the fixed point of the drain recurrence
    ``b_{k+1} = rate * T(b_k)`` with the BUCKETED step time
    ``T(b) = dispatch + ticket * b + bucket(b) * t_q``: the device pays
    per padded-bucket row (t_q — the predictor serves the full
    power-of-two block whether its tail is real or zeros), the host
    pays per REAL ticket (``ticket_s`` — admission, buffer fill,
    result scatter; zero by default for the pure device model).  The
    recurrence is iterated to its limit cycle, since padding makes the
    device term piecewise-constant and the limit may be a short cycle
    straddling a bucket edge rather than a fixed point.  The engine
    saturates when the rate exceeds the full-window capacity; then
    every step serves a FULL window and the excess is shed by the
    bounded queue.

    A ticket's latency is the residue of the step it arrived during
    plus the full step that serves it — uniform in (T, 2T] when T is
    deterministic, so p50 = 1.5 T and p99 = 1.99 T.  Real hosts jitter:
    the MEDIAN latency is robust to it, but the p99 inherits the
    step-time tail, so callers with a measured step-time distribution
    pass ``tail_factor`` = q99(T)/median(T) (1.0 keeps the
    deterministic tail).

    Returns p50/p99 latency, sustained throughput, the steady batch and
    step time (limit-cycle averages), and ``saturated``.
    """
    mach = mach or Machine()
    t_q = serve_block_time(1, m, n, kernel, approx=approx,
                           landmarks=landmarks, sv_fraction=sv_fraction,
                           mach=mach, dispatch_s=0.0)
    t_full = serve_block_time(slots, m, n, kernel, approx=approx,
                              landmarks=landmarks,
                              sv_fraction=sv_fraction, mach=mach,
                              dispatch_s=dispatch_s) + slots * ticket_s
    capacity = slots / t_full          # qps when every step is full
    saturated = (rate_qps * (t_q + ticket_s) >= 1.0
                 or rate_qps >= capacity)
    if saturated:
        b_star, t_step, throughput = float(slots), t_full, capacity
    else:
        # bucketed drain recurrence (fluid): admit min(queue, slots),
        # pay the padded bucket (device) plus the real rows (host),
        # arrivals accumulate meanwhile.  Burn in, then average the
        # limit cycle.
        q_len, b_hist, t_hist = 0.0, [], []
        for k in range(200):
            b = min(q_len, float(slots))
            if b < 1.0:                # idle: fast-forward to the next
                q_len = 1.0            # arrival (the driver does too)
                continue
            dt = (dispatch_s + ticket_s * b
                  + serve_bucket(b, slots) * t_q)
            q_len = q_len - b + rate_qps * dt
            if k >= 100:
                b_hist.append(b)
                t_hist.append(dt)
        b_star = sum(b_hist) / len(b_hist)
        t_step = sum(t_hist) / len(t_hist)
        throughput = rate_qps
    return {"p50_s": 1.5 * t_step,
            "p99_s": 1.99 * t_step * tail_factor,
            "t_step_s": t_step, "batch": b_star,
            "throughput_qps": throughput, "capacity_qps": capacity,
            "saturated": saturated, "slots": slots,
            "dispatch_s": dispatch_s, "t_query_s": t_q,
            "ticket_s": ticket_s}


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """The engine sizing ``choose_serve_plan`` resolved: admission
    window (= the slot-matrix height = the largest predictor bucket),
    the modeled latency summary at the target rate, and the frontier of
    every candidate considered."""

    slots: int
    model: dict                    # modeled_serve_latency at the choice
    frontier: tuple                # ({"slots", "p99_s", ...}, ...)


def choose_serve_plan(m: int, n: int, kernel: str, *, rate_qps: float,
                      slo_p99_s: float = float("inf"),
                      approx: str = None, landmarks: int = 0,
                      sv_fraction: float = 1.0, mach: Machine = None,
                      dispatch_s: float = SERVE_DISPATCH_S,
                      candidates=(8, 16, 32, 64, 128, 256, 512, 1024,
                                  2048, 4096)) -> ServePlan:
    """Size the serving engine from the perf model: the SMALLEST
    power-of-two admission window that sustains ``rate_qps`` without
    saturating (bigger windows only stretch the batch window, and with
    it p99).  Among unsaturated candidates any that meet the p99 SLO
    are preferred; if none can, the plan falls back to the highest-
    capacity window (shed-and-degrade beats OOM — the engine's bounded
    queue enforces it)."""
    frontier = []
    for s in candidates:
        lat = modeled_serve_latency(rate_qps, s, m, n, kernel,
                                    approx=approx, landmarks=landmarks,
                                    sv_fraction=sv_fraction, mach=mach,
                                    dispatch_s=dispatch_s)
        frontier.append(dict(lat, slots=s))
    ok = [f for f in frontier if not f["saturated"]
          and f["p99_s"] <= slo_p99_s]
    if ok:
        best = min(ok, key=lambda f: f["slots"])
    else:
        unsat = [f for f in frontier if not f["saturated"]]
        pool = unsat or frontier
        best = max(pool, key=lambda f: f["capacity_qps"])
    return ServePlan(slots=best["slots"], model=best,
                     frontier=tuple(frontier))


# --------------------------------------------------------------------------
# On-chip traffic model (EXPERIMENTS.md §Perf): HBM bytes per outer round.
# The network Hockney model above prices the collective; these two price
# the local memory system, where the materialized m x sb slab is the
# dominant term the slab-free KMV kernel deletes.
# --------------------------------------------------------------------------

def slab_round_hbm_bytes(m: int, n: int, sb: int, c: int = 1,
                         word: int = 4) -> int:
    """Materialized-slab s-step round (fused-epilogue gram kernel +
    separate consumers):

      gram:     read A (m*n) + read B (sb*n), write slab (m*sb)
      U^T x:    re-read slab (m*sb) + read x (c*m), write (c*sb)
      Gblk:     gather sb slab rows (sb*sb)

    The 2*m*sb slab round-trip dominates for m >> n, sb.
    """
    gram = m * n + sb * n + m * sb
    consume = m * sb + c * m + c * sb + sb * sb
    return word * (gram + consume)


def kmv_round_hbm_bytes(m: int, n: int, sb: int, c: int = 1,
                        word: int = 4) -> int:
    """Slab-free s-step round (fused KMV kernel + small cross-block gram):

      KMV:      read A (m*n) + read B (sb*n) + read x (c*m), write (c*sb)
      Gblk:     read B twice (2*sb*n), write sb*sb

    Zero m x sb traffic: the slab lives only in VMEM tiles.
    """
    kmv = m * n + sb * n + c * m + c * sb
    cross = 2 * sb * n + sb * sb
    return word * (kmv + cross)


def slab_fits_hbm(m: int, sb: int, hbm_bytes: int = 16 * 2 ** 30,
                  word: int = 4) -> bool:
    """Whether the materialized m x sb slab ALONE fits the HBM budget
    (A's own footprint is not counted, so this is an optimistic bound) —
    the slab-free path has no such ceiling on m."""
    return word * m * sb < hbm_bytes


# --------------------------------------------------------------------------
# Streaming pipeline model (DESIGN.md §14): the double-buffered
# out-of-core KMV (kernels/kmv_stream.py) DMAs (chunk_rows x n) row
# blocks from slow memory while the previous block contracts, so the
# steady-state pipe pays max(t_dma, t_comp) per chunk instead of the
# sum.  These closed forms (a) price that overlap, (b) bound the
# double-buffered VMEM working set a chunk size implies, and (c) decide
# when streaming is REQUIRED — the resident working set exceeding the
# device-memory budget — which is the autotuner's trigger for
# ``chunk_rows="auto"`` resolution.
# --------------------------------------------------------------------------

STREAM_DMA_BPS = 800e9             # HBM-class chunk DMA bandwidth (B/s)


def stream_chunk_cost(chunk_rows: int, n: int, sb: int, kernel: str, *,
                      c: int = 1, mach: Machine = None, word: int = 4,
                      dma_bps: float = STREAM_DMA_BPS) -> dict:
    """One pipeline stage: DMA of a (chunk_rows x n) data block plus its
    (chunk_rows x c) right-hand-side block vs the (GEMM + epilogue +
    contract) compute on the previous block.  ``compute_bound`` is the
    overlap regime where the DMA is (nearly) free."""
    mach = mach or Machine()
    mu = _mu(mach, kernel)
    bytes_in = word * (chunk_rows * n + chunk_rows * c)
    t_dma = bytes_in / dma_bps
    flops = (chunk_rows * sb * n        # dots = chunk @ B^T
             + mu * chunk_rows * sb     # Table-1 epilogue
             + chunk_rows * sb * c)     # acc += ktile^T @ x
    t_comp = mach.gamma * flops
    return {"bytes": bytes_in, "flops": flops, "t_dma": t_dma,
            "t_comp": t_comp, "compute_bound": t_comp >= t_dma}


def stream_pipeline_cost(m: int, n: int, sb: int, chunk_rows: int,
                         kernel: str, *, c: int = 1, mach: Machine = None,
                         word: int = 4,
                         dma_bps: float = STREAM_DMA_BPS) -> dict:
    """Whole streamed KMV: warm-up DMA of chunk 0, then ``n_chunks``
    steady stages at ``max(t_dma, t_comp)`` each (double-buffered
    overlap).  ``time_unoverlapped`` is the same pipe with blocking
    copies (the sum per stage) and ``resident_time`` the pure-compute
    bound of an HBM-resident KMV — ``streamed_over_resident`` is the
    modeled slowdown factor fig10's measured gate mirrors (~1.0 when
    compute-bound, up to t_dma/t_comp when DMA-bound)."""
    n_chunks = -(-m // chunk_rows)
    per = stream_chunk_cost(chunk_rows, n, sb, kernel, c=c, mach=mach,
                            word=word, dma_bps=dma_bps)
    steady = max(per["t_dma"], per["t_comp"])
    time = per["t_dma"] + n_chunks * steady
    unoverlapped = n_chunks * (per["t_dma"] + per["t_comp"])
    resident = max(n_chunks * per["t_comp"], 1e-30)
    return dict(per, n_chunks=n_chunks, time=time,
                time_unoverlapped=unoverlapped,
                resident_time=resident,
                streamed_over_resident=time / resident,
                overlap_speedup=unoverlapped / max(time, 1e-30))


def stream_working_set_bytes(chunk_rows: int, n: int, sb: int, *,
                             c: int = 1, word: int = 4) -> int:
    """On-chip bytes the streamed contraction keeps live: TWO slots of
    the data chunk and of its right-hand-side chunk (double buffering),
    the (sb x n) sampled rows, the transient (chunk_rows x sb) kernel
    tile, and the (sb x c) accumulator."""
    return word * (2 * chunk_rows * n + 2 * chunk_rows * c
                   + sb * n + chunk_rows * sb + sb * c)


def stream_chunk_fits(chunk_rows: int, n: int, sb: int, *, c: int = 1,
                      word: int = 4,
                      budget_bytes: int = None) -> bool:
    """Whether a chunk size's double-buffered working set fits the
    on-chip budget (default: ``VMEM_BYTES``) — the feasibility
    constraint ``choose_chunk_rows`` (and the streaming tests)
    enforce."""
    if budget_bytes is None:
        budget_bytes = VMEM_BYTES
    return stream_working_set_bytes(chunk_rows, n, sb, c=c,
                                    word=word) <= budget_bytes


def streaming_required(m: int, n: int, sb: int, *, c: int = 1,
                       word: int = 4,
                       device_bytes: int = 16 * 2 ** 30) -> bool:
    """Whether the RESIDENT slab-free round — X (m x n) plus the KMV
    round set (the x vector, the sampled rows, the contracted outputs) —
    exceeds the device-memory budget: the gate between "fits in HBM"
    and the streamed pipeline (ISSUE/ROADMAP's out-of-core axis)."""
    resident = word * (m * n + c * m + sb * n + sb * c)
    return resident > device_bytes


STREAM_CHUNK_CANDIDATES = (128, 256, 512, 1024, 2048, 4096, 8192)


def choose_chunk_rows(m: int, n: int, sb: int, kernel: str, *, c: int = 1,
                      mach: Machine = None, word: int = 4,
                      dma_bps: float = STREAM_DMA_BPS,
                      budget_bytes: int = None,
                      candidates=STREAM_CHUNK_CANDIDATES,
                      return_frontier: bool = False):
    """Resolve ``chunk_rows="auto"``: the best modeled pipeline time
    among chunk sizes whose double-buffered working set fits the
    on-chip budget (ties break toward the smaller working set).  The
    smallest candidate is always kept as a floor so the search cannot
    come back empty.  Mirrors ``best_s``'s frontier contract."""
    cands = sorted({min(cr, max(8, m)) for cr in candidates})
    frontier = []
    for i, cr in enumerate(cands):
        feasible = i == 0 or stream_chunk_fits(cr, n, sb, c=c, word=word,
                                               budget_bytes=budget_bytes)
        cost = stream_pipeline_cost(m, n, sb, cr, kernel, c=c, mach=mach,
                                    word=word, dma_bps=dma_bps)
        frontier.append({"chunk_rows": cr, "time": cost["time"],
                         "compute_bound": cost["compute_bound"],
                         "working_set_bytes": stream_working_set_bytes(
                             cr, n, sb, c=c, word=word),
                         "feasible": feasible})
    feas = [f for f in frontier if f["feasible"]]
    best = min(feas, key=lambda f: (f["time"], f["working_set_bytes"]))
    if return_frontier:
        return best["chunk_rows"], frontier
    return best["chunk_rows"]


# --------------------------------------------------------------------------
# Structural comm model (DESIGN.md §11): COUNTS of collectives, not bytes.
# The Hockney L term above prices one latency unit per round; these two
# expose the underlying per-round collective schedule as checkable
# integers, so the static comm auditor (repro.analysis.comm_check) can
# assert the traced jaxpr executes EXACTLY the modeled schedule — the
# paper's H/s communication-round claim as a machine-checked invariant.
# --------------------------------------------------------------------------

def round_collectives(layout: str, kernel: str) -> int:
    """Collectives per OUTER ROUND of the slab-free solvers by layout.

    serial: 0.  1d: ONE model-axis psum per round regardless of kernel
    (linear psums the contracted (sb, sb+1) words, nonlinear the
    pre-epilogue m x sb block with the cross terms riding along — see
    ``core.distributed.AllreduceGramOperator``).  2d: three — the
    sampled-row gather over ``data``, the fused ``model`` reduction, and
    the fused contracted-quantities psum back over ``data``
    (``dist_sstep_*_2d`` docstrings).  The classical solvers are the
    s=1 specialization: SAME per-round counts, s times the rounds.
    """
    if layout not in ("serial", "1d", "2d"):
        raise ValueError(f"unknown layout {layout!r}")
    return {"serial": 0, "1d": 1, "2d": 3}[layout]


def setup_collectives(layout: str, kernel: str) -> int:
    """One-time (loop-invariant) collectives per solve: the psummed RBF
    row squared-norms (``_psummed_row_sqnorms``) — hoisted out of the
    round loop precisely so they don't scale with H.  Zero for linear
    and polynomial kernels (no row-norm term) and for serial runs."""
    if layout == "serial":
        return 0
    return 1 if kernel == "rbf" else 0


# --------------------------------------------------------------------------
# Guarded-solve overhead model (DESIGN.md §12): drift correction costs one
# EXACT full matvec f = K @ alpha every ``recompute_every`` rounds — the
# one part of the guarded protocol that is not free (the per-round
# residual recurrence reuses the m x sb block the round already
# evaluates, and the health predicate is O(m) elementwise).  These
# closed forms let the autotuner pick the largest drift-correction
# cadence that keeps modeled overhead under a budget.
# --------------------------------------------------------------------------

GUARD_OVERHEAD_BUDGET = 0.10       # default: <= 10% modeled overhead


def guard_round_flops(m: int, n: int, kernel: str, *, b: int = 1,
                      s: int = 1, P: int = 1, f: float = 1.0,
                      mach: Machine = None) -> float:
    """Flops of ONE outer round of the (s-step) solver — the denominator
    of the overhead ratio (the guarded round itself adds only the O(m*sb)
    recurrence update, already inside this count's epilogue term)."""
    mach = mach or Machine()
    mu = _mu(mach, kernel)
    return (s * b * f * m * n / P + mu * s * b * m + s * b ** 3
            + math.comb(s, 2) * b ** 2 + s * b * m)


def recompute_flops(m: int, n: int, kernel: str, *, P: int = 1,
                    f: float = 1.0, approx: str = None, landmarks: int = 0,
                    mach: Machine = None) -> float:
    """Flops of one exact residual recompute ``f = K @ alpha``: the full
    m x m gram streamed block-wise through the operator (never stored)
    for the exact representation, two O(m l) linear contractions for the
    low-rank one."""
    if approx:
        return 2.0 * m * landmarks
    mach = mach or Machine()
    mu = _mu(mach, kernel)
    return f * m * m * n / P + mu * m * m


def choose_recompute_every(m: int, n: int, kernel: str, *, b: int = 1,
                           s: int = 1, P: int = 1, f: float = 1.0,
                           approx: str = None, landmarks: int = 0,
                           budget: float = GUARD_OVERHEAD_BUDGET,
                           mach: Machine = None) -> int:
    """Smallest drift-correction cadence (in outer rounds) whose modeled
    amortized overhead stays within ``budget``: recomputing every r
    rounds costs ``recompute/ (r * round)`` extra, so r >= recompute /
    (budget * round).  More frequent correction is strictly better for
    drift, so the floor IS the choice."""
    if budget <= 0:
        raise ValueError(f"budget must be > 0, got {budget!r}")
    per_round = guard_round_flops(m, n, kernel, b=b, s=s, P=P, f=f,
                                  mach=mach)
    rec = recompute_flops(m, n, kernel, P=P, f=f, approx=approx,
                          landmarks=landmarks, mach=mach)
    return max(1, math.ceil(rec / (budget * per_round)))


def guard_overhead(m: int, n: int, kernel: str, *, b: int = 1, s: int = 1,
                   P: int = 1, f: float = 1.0, recompute_every: int = 0,
                   approx: str = None, landmarks: int = 0,
                   mach: Machine = None) -> float:
    """Modeled fractional flop overhead of guarded mode at a given
    cadence (0 = drift correction off => only the free recurrence)."""
    if recompute_every < 1:
        return 0.0
    per_round = guard_round_flops(m, n, kernel, b=b, s=s, P=P, f=f,
                                  mach=mach)
    rec = recompute_flops(m, n, kernel, P=P, f=f, approx=approx,
                          landmarks=landmarks, mach=mach)
    return rec / (recompute_every * per_round)


# --------------------------------------------------------------------------
# VMEM working-set model: prices a Pallas kernel's on-chip footprint so
# the kernel sanitizer (repro.analysis.pallas_check) can flag launches
# whose pipelined blocks + scratch cannot be VMEM-resident.
# --------------------------------------------------------------------------

VMEM_BYTES = 16 * 2 ** 20          # per-core VMEM (TPU v4/v5 class)


def pallas_working_set_bytes(block_bytes: int, scratch_bytes: int = 0,
                             double_buffer: bool = True) -> int:
    """On-chip bytes a Pallas launch keeps live: the in/out block set —
    DOUBLED by default, because the pipelined grid prefetches the next
    block of every spec while the current one computes — plus scratch
    (scratch is persistent across grid steps, never double-buffered)."""
    mult = 2 if double_buffer else 1
    return mult * block_bytes + scratch_bytes


def vmem_fits(block_bytes: int, scratch_bytes: int = 0,
              vmem_bytes: int = VMEM_BYTES,
              double_buffer: bool = True) -> bool:
    """Whether the working set fits the VMEM budget."""
    return pallas_working_set_bytes(
        block_bytes, scratch_bytes, double_buffer) <= vmem_bytes
