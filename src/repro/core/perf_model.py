"""Hockney-model performance analysis (paper Section 4, Theorems 1-2).

T = gamma*F + beta*W + phi*L  with per-iteration costs:

  BDCD:        F = b*f*m*n/P + mu*b*m + b^3 + b*m      W = b*m      L = log P
  s-step BDCD: per OUTER round (s inner solves):
               F = s*b*f*m*n/P + mu*s*b*m + s*b^3 + C(s,2)*b^2 + s*b*m
               W = s*b*m                               L = log P

DCD (K-SVM) is the b=1 specialization.  These closed forms power the
strong-scaling predictions (benchmarks/fig3) that mirror the paper's Cray
EX experiments, calibrated with machine parameters measured on this host
(gamma) and standard HPC interconnect constants (beta, phi).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Machine:
    gamma: float = 1.0 / 50e9     # s/flop  (~50 GFLOP/s per core, DGEMM)
    beta: float = 8.0 / 25e9      # s/word  (8B words over 25 GB/s links)
    phi: float = 2.0e-6           # s/message (Cray EX / Slingshot-ish)
    mu: float = 20.0              # non-linear kernel op cost in flop units


@dataclasses.dataclass(frozen=True)
class Problem:
    m: int
    n: int
    f: float = 1.0                # nnz density
    b: int = 1
    H: int = 1000                 # total (inner) iterations
    kernel: str = "rbf"


def _mu(mach: Machine, prob: Problem) -> float:
    return {"linear": 1.0, "polynomial": mach.mu / 2, "rbf": mach.mu}[
        prob.kernel]


def bdcd_cost(prob: Problem, mach: Machine, P: int) -> dict:
    """Classical BDCD total cost for H iterations on P processors."""
    b, m, n, f, H = prob.b, prob.m, prob.n, prob.f, prob.H
    mu = _mu(mach, prob)
    F = H * (b * f * m * n / P + mu * b * m + b ** 3 + b * m)
    W = H * b * m
    L = H * math.log2(max(P, 2))
    return {"flops": F, "words": W, "msgs": L,
            "time": mach.gamma * F + mach.beta * W + mach.phi * L,
            "t_comp": mach.gamma * F, "t_band": mach.beta * W,
            "t_lat": mach.phi * L}


def sstep_bdcd_cost(prob: Problem, mach: Machine, P: int, s: int) -> dict:
    """s-step BDCD total cost for H inner iterations (H/s outer rounds)."""
    b, m, n, f, H = prob.b, prob.m, prob.n, prob.f, prob.H
    mu = _mu(mach, prob)
    rounds = H / s
    F = rounds * (s * b * f * m * n / P + mu * s * b * m + s * b ** 3
                  + math.comb(s, 2) * b ** 2 + s * b * m)
    W = rounds * (s * b * m)
    L = rounds * math.log2(max(P, 2))
    return {"flops": F, "words": W, "msgs": L,
            "time": mach.gamma * F + mach.beta * W + mach.phi * L,
            "t_comp": mach.gamma * F, "t_band": mach.beta * W,
            "t_lat": mach.phi * L}


def best_s(prob: Problem, mach: Machine, P: int,
           candidates=(1, 2, 4, 8, 16, 32, 64, 128, 256)) -> tuple:
    """Offline tuning of s (paper 5.2.1): best predicted time."""
    times = {s: sstep_bdcd_cost(prob, mach, P, s)["time"]
             for s in candidates}
    s = min(times, key=times.get)
    return s, times[s]


def storage_words(prob: Problem, P: int, s: int = 1) -> float:
    """Theorem 1/2 storage: fmn/P + s*b*m."""
    return prob.f * prob.m * prob.n / P + s * prob.b * prob.m


def modeled_fit_cost(m: int, n: int, kernel: str, *, b: int = 1,
                     s: int = 1, iters: int = 1, P: int = 1,
                     mach: Machine = None) -> dict:
    """Hockney-model cost summary for a completed solver run — the
    ``FitResult.comm`` payload of the ``repro.api`` facade.  ``iters`` is
    the number of INNER iterations actually executed (early stopping
    shrinks it), ``P`` the processor count implied by the layout; ``s=1``
    prices the classical per-iteration collective schedule."""
    mach = mach or Machine()
    # price whole communication rounds: a ragged final round (pad-and-
    # mask) still issues a full-size collective, so round iters up to
    # ceil(iters/s) rounds — keeping comm['msgs'] consistent with the
    # FitResult.rounds_run reported for the same run.
    H = max(iters, 1) if s <= 1 else -(-max(iters, 1) // s) * s
    prob = Problem(m=m, n=n, b=max(b, 1), H=H, kernel=kernel)
    cost = (bdcd_cost(prob, mach, P) if s <= 1
            else sstep_bdcd_cost(prob, mach, P, s))
    return dict(cost, P=P, s=s, iters=iters)


# --------------------------------------------------------------------------
# On-chip traffic model (EXPERIMENTS.md §Perf): HBM bytes per outer round.
# The network Hockney model above prices the collective; these two price
# the local memory system, where the materialized m x sb slab is the
# dominant term the slab-free KMV kernel deletes.
# --------------------------------------------------------------------------

def slab_round_hbm_bytes(m: int, n: int, sb: int, c: int = 1,
                         word: int = 4) -> int:
    """Materialized-slab s-step round (fused-epilogue gram kernel +
    separate consumers):

      gram:     read A (m*n) + read B (sb*n), write slab (m*sb)
      U^T x:    re-read slab (m*sb) + read x (c*m), write (c*sb)
      Gblk:     gather sb slab rows (sb*sb)

    The 2*m*sb slab round-trip dominates for m >> n, sb.
    """
    gram = m * n + sb * n + m * sb
    consume = m * sb + c * m + c * sb + sb * sb
    return word * (gram + consume)


def kmv_round_hbm_bytes(m: int, n: int, sb: int, c: int = 1,
                        word: int = 4) -> int:
    """Slab-free s-step round (fused KMV kernel + small cross-block gram):

      KMV:      read A (m*n) + read B (sb*n) + read x (c*m), write (c*sb)
      Gblk:     read B twice (2*sb*n), write sb*sb

    Zero m x sb traffic: the slab lives only in VMEM tiles.
    """
    kmv = m * n + sb * n + c * m + c * sb
    cross = 2 * sb * n + sb * sb
    return word * (kmv + cross)


def slab_fits_hbm(m: int, sb: int, hbm_bytes: int = 16 * 2 ** 30,
                  word: int = 4) -> bool:
    """Whether the materialized m x sb slab ALONE fits the HBM budget
    (A's own footprint is not counted, so this is an optimistic bound) —
    the slab-free path has no such ceiling on m."""
    return word * m * sb < hbm_bytes
