"""Shared round-protocol loop for every solver variant (DESIGN.md §8).

All eight solver variants — {DCD, s-step DCD, BDCD, s-step BDCD} x
{serial, shard_map} — share the same outer structure: a state pytree
(alpha), a per-round transition ``round_fn(state, xs_k) -> state``, and a
schedule of per-round data ``xs``.  ``run_rounds`` is the single driver:

  * fast path (``metric_fn=None``): one ``lax.scan`` — bit-compatible
    with the legacy hand-written loops, optionally stacking per-round
    states for the convergence benchmarks;
  * tolerance path (``metric_fn`` given): one ``lax.while_loop`` that
    evaluates ``metric_fn(state)`` every ``check_every`` rounds (and at
    the final round), records it into a fixed-size history buffer, and
    stops as soon as the metric falls to ``tol``.

``run_rounds_fleet`` is the multi-problem twin (repro.tune, DESIGN.md
§10): the state carries a leading fleet axis F, the metric is
per-member, and the while-loop path maintains a vmap-safe per-member
``done`` mask — converged members are frozen in place and the loop only
exits when all F members are done.

``pad_rounds`` removes the old ``H % s == 0`` restriction: the schedule
is padded to a whole number of s-step rounds and a per-slot validity
mask rides along, so the final short round computes masked (zero)
updates for the padded slots — the iterates match the classical solver
at every ragged H (tests/test_api.py::TestRaggedTail).

The round_fns driven here are representation-agnostic: they read kernel
data only through a ``GramOperator`` (exact, low-rank, or a distributed
all-reduce operator — DESIGN.md §9), injected per fit via the
factories' ``op``/``op_factory`` parameters.

``run_rounds`` optionally threads a GUARD through the protocol
(repro.resilience, DESIGN.md §12): a ``GuardSpec`` adds (a) a jit-safe
per-round health check — a round producing a non-finite carry is
DISCARDED (the pre-round state is kept, done-mask style) and the loop
freezes with ``diverged_round``/``diverged_kind`` stamped for the host
to act on (escalation ladder in ``repro.api``); (b) periodic residual
replacement — every ``correct_every`` rounds ``correct_fn`` recomputes
the carried recurrence exactly and the observed drift is recorded into
a fixed-size buffer; (c) metric blow-up detection against the best
value seen so far.  ``guard=None`` is bit-compatible with the
pre-guard driver.

Everything here is pure ``lax``; the driver runs identically inside
``jax.jit`` and inside ``shard_map`` bodies (core/distributed.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

NO_TOL = float("-inf")        # sentinel: record the metric, never stop early

# LoopResult.diverged_kind codes (0 = healthy throughout)
DIVERGED_NONE = 0
DIVERGED_NONFINITE = 1        # round_fn produced a non-finite carry leaf
DIVERGED_METRIC = 2           # metric went non-finite or blew up vs best


class GuardSpec(NamedTuple):
    """Guard hooks for ``run_rounds`` (repro.resilience, DESIGN.md §12).

    health_fn:      state -> scalar bool, True = healthy.  Runs on the
                    FULL post-round carry every round; an unhealthy
                    round is discarded and the loop freezes.  Must cover
                    every carry leaf (``repro.analysis`` CHK-CARRY
                    pokes NaNs into each leaf to verify it does).
    correct_fn:     state -> (corrected_state, drift) — residual
                    replacement: recompute the carried recurrence
                    exactly and report the observed relative drift.
    correct_every:  cadence of ``correct_fn`` in rounds (0 = never).
    metric_blowup:  freeze when a checked metric exceeds
                    ``metric_blowup * best_so_far`` (inf disables).
    """

    health_fn: Callable
    correct_fn: Optional[Callable] = None
    correct_every: int = 0
    metric_blowup: float = 1e4


class LoopResult(NamedTuple):
    """Output of ``run_rounds`` (a pytree, so it can cross a jit boundary).

    state:       final solver state (alpha).
    state_hist:  per-round stacked states (scan mode + record_state) or None.
    metric_hist: (n_check_slots,) metric values (while mode; only the
                 first ``checks_run`` slots were evaluated — slice with
                 it, values may legitimately be inf/nan) or None (scan).
    checks_run:  number of metric evaluations actually performed.
    rounds_run:  number of rounds actually executed.
    converged:   metric <= tol at some check point (``run_rounds_fleet``:
                 the (F,) per-member mask; metric_hist is (n_checks, F)).

    Guard extras (``guard=`` runs only; None otherwise — trailing
    defaults keep every pre-guard construction site valid):

    drift_hist:     (n_corrections,) observed relative drift at each
                    residual replacement (only the first ``corrections``
                    slots were evaluated).
    corrections:    number of drift corrections performed.
    diverged_round: 0-based index of the first unhealthy round, or -1.
                    On non-finite divergence ``state`` is the LAST GOOD
                    (pre-round) carry; the unhealthy update was never
                    applied.
    diverged_kind:  DIVERGED_NONE / DIVERGED_NONFINITE / DIVERGED_METRIC.
    """

    state: Any
    state_hist: Optional[Any]
    metric_hist: Optional[jnp.ndarray]
    checks_run: jnp.ndarray
    rounds_run: jnp.ndarray
    converged: jnp.ndarray
    drift_hist: Optional[jnp.ndarray] = None
    corrections: Optional[jnp.ndarray] = None
    diverged_round: Optional[jnp.ndarray] = None
    diverged_kind: Optional[jnp.ndarray] = None

    def metric_history(self) -> Optional[jnp.ndarray]:
        """The evaluated prefix ``metric_hist[:checks_run]``.

        HOST-SYNC: ``int(self.checks_run)`` blocks on the device value
        — calling this inside a traced function raises (it is a result
        accessor, not loop code), and calling it on a freshly returned
        result synchronizes the dispatch stream.  ``None`` when no
        metric was recorded (scan mode).  Edge cases: ``checks_run ==
        0`` (e.g. an empty schedule — the while loop never ran) returns
        the empty ``(0,)`` slice, not None; fleet results
        (``run_rounds_fleet``) slice the same way with the check axis
        leading — shape ``(checks_run, F)``."""
        if self.metric_hist is None:
            return None
        return self.metric_hist[:int(self.checks_run)]

    def drift_history(self) -> Optional[jnp.ndarray]:
        """The evaluated drift prefix ``drift_hist[:corrections]``.

        HOST-SYNC like ``metric_history`` (``int(self.corrections)``
        blocks).  ``None`` when the run was unguarded or had no
        residual-replacement cadence (``correct_every == 0`` — the
        guard then records no drift buffer at all); a guarded run whose
        cadence never fired returns the empty ``(0,)`` slice."""
        if self.drift_hist is None:
            return None
        return self.drift_hist[:int(self.corrections)]


def pad_rounds(schedule: jnp.ndarray, s: int):
    """Reshape an (H, ...) schedule into ((R, s, ...), (R, s)) rounds +
    validity mask with R = ceil(H/s); padded slots carry index 0 and
    valid 0.0, so masked round_fns make them exact no-ops."""
    H = schedule.shape[0]
    R = -(-H // s)
    pad = R * s - H
    if pad:
        schedule = jnp.concatenate(
            [schedule, jnp.zeros((pad,) + schedule.shape[1:],
                                 schedule.dtype)], axis=0)
    valid = (jnp.arange(R * s) < H).astype(jnp.float32)
    return (schedule.reshape((R, s) + schedule.shape[1:]),
            valid.reshape(R, s))


def run_rounds(round_fn: Callable, state0: Any, xs: Any, *,
               tol: float = NO_TOL, check_every: int = 1,
               metric_fn: Optional[Callable] = None,
               record_state: bool = False,
               guard: Optional[GuardSpec] = None,
               marks: bool = False) -> LoopResult:
    """Drive ``R = len(xs)`` rounds of ``round_fn`` (see module docstring).

    xs is a pytree of arrays with a shared leading round axis.  With
    ``metric_fn=None`` this is exactly the legacy ``lax.scan`` loop;
    otherwise a ``lax.while_loop`` with early stopping at ``tol``
    (pass ``tol=NO_TOL`` to record the metric without ever stopping).
    ``guard`` switches to the guarded while-loop driver (module
    docstring; works with or without a metric).

    ``marks`` (static) threads telemetry marks (repro.obs, DESIGN.md
    §15) into the EXISTING sync points only — the tolerance-check and
    drift-correction cond branches of the while-loop drivers; the scan
    fast path has no sync points and is never instrumented.  With
    ``marks=False`` (the default) the traced code is byte-identical to
    the pre-telemetry driver: zero added ops, jaxpr-identical
    (tests/test_obs.py asserts this).
    """
    R = jax.tree_util.tree_leaves(xs)[0].shape[0]

    if guard is not None:
        if record_state:
            raise ValueError("guard= and record_state= are mutually "
                             "exclusive (guarded runs use the while-loop "
                             "driver, which stacks no per-round states)")
        return _run_rounds_guarded(round_fn, state0, xs, R, tol=tol,
                                   check_every=check_every,
                                   metric_fn=metric_fn, guard=guard,
                                   marks=marks)

    if metric_fn is None:
        def body(state, x):
            new = round_fn(state, x)
            return new, (new if record_state else 0.0)

        state, ys = jax.lax.scan(body, state0, xs)
        return LoopResult(state, ys if record_state else None, None,
                          jnp.asarray(0), jnp.asarray(R),
                          jnp.asarray(False))

    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    if marks:
        from repro.obs.spans import span_begin, span_end
    n_checks = -(-R // check_every)
    mdtype = jax.eval_shape(metric_fn, state0).dtype
    hist0 = jnp.full((n_checks,), jnp.inf, mdtype)
    tol_v = jnp.asarray(tol, mdtype)

    def cond(carry):
        k, _, _, _, conv = carry
        return (k < R) & jnp.logical_not(conv)

    def body(carry):
        k, state, hist, nchk, _ = carry
        x = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, k, 0, keepdims=False),
            xs)
        state = round_fn(state, x)
        do_check = ((k + 1) % check_every == 0) | (k + 1 == R)

        def check(args):
            st, h, n = args
            if marks:                       # static: absent when False
                span_begin("metric_check")
            v = metric_fn(st)
            if marks:
                # no traced operand on the end mark: shipping the
                # metric value through the callback roughly doubles
                # its cost (the value is in hist already)
                span_end("metric_check")
            return h.at[n].set(v), n + 1, v <= tol_v

        def skip(args):
            return args[1], args[2], jnp.asarray(False)

        hist, nchk, conv = jax.lax.cond(do_check, check, skip,
                                        (state, hist, nchk))
        return k + 1, state, hist, nchk, conv

    k, state, hist, nchk, conv = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), state0, hist0, jnp.asarray(0),
                     jnp.asarray(False)))
    return LoopResult(state, None, hist, nchk, k, conv)


def _run_rounds_guarded(round_fn: Callable, state0: Any, xs: Any, R: int,
                        *, tol: float, check_every: int,
                        metric_fn: Optional[Callable],
                        guard: GuardSpec,
                        marks: bool = False) -> LoopResult:
    """The guarded while-loop driver behind ``run_rounds(guard=...)``.

    Divergence handling follows the fleet freeze idiom: the unhealthy
    round's update is DISCARDED (``jnp.where`` keeps the pre-round
    carry), the first bad round index and kind are stamped, and the
    loop condition exits — the host (repro.api's escalation ladder)
    decides what to run next from the last good state.
    """
    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    if marks:
        from repro.obs.spans import span_begin, span_end
    has_metric = metric_fn is not None
    n_checks = -(-R // check_every) if has_metric else 1
    if has_metric:
        mdtype = jax.eval_shape(metric_fn, state0).dtype
    else:
        mdtype = jnp.asarray(0.0).dtype
    hist0 = jnp.full((n_checks,), jnp.inf, mdtype)
    tol_v = jnp.asarray(tol, mdtype)
    blowup = jnp.asarray(guard.metric_blowup, mdtype)

    has_corr = (guard.correct_fn is not None and guard.correct_every >= 1)
    n_corr = -(-R // guard.correct_every) if has_corr else 1
    if has_corr:
        ddtype = jax.eval_shape(guard.correct_fn, state0)[1].dtype
    else:
        ddtype = mdtype
    drift0 = jnp.zeros((n_corr,), ddtype)

    def cond(carry):
        k, _, _, _, conv, _, _, _, div, _ = carry
        return (k < R) & jnp.logical_not(conv) & (div < 0)

    def body(carry):
        k, state, hist, nchk, _, best, dhist, ncorr, div, kind = carry
        x = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, k, 0, keepdims=False),
            xs)
        new = round_fn(state, x)
        ok = guard.health_fn(new)
        # freeze idiom: an unhealthy update is discarded wholesale —
        # the carry the host resumes from is the last good state
        state = jax.tree_util.tree_map(
            lambda nw, old: jnp.where(ok, nw, old), new, state)
        div = jnp.where(ok, div, k)
        kind = jnp.where(ok, kind, DIVERGED_NONFINITE)

        if has_corr:
            do_corr = ok & ((k + 1) % guard.correct_every == 0)

            def correct(args):
                st, dh, nc = args
                if marks:                   # static: absent when False
                    span_begin("drift_correction")
                st2, drift = guard.correct_fn(st)
                if marks:
                    # operand-free (see metric_check): drift lands in
                    # dhist / SolveHealth.drift, not the mark
                    span_end("drift_correction")
                return st2, dh.at[nc].set(drift), nc + 1

            state, dhist, ncorr = jax.lax.cond(
                do_corr, correct, lambda args: args, (state, dhist, ncorr))

        conv = jnp.asarray(False)
        if has_metric:
            do_check = ok & (((k + 1) % check_every == 0) | (k + 1 == R))

            def check(args):
                st, h, n = args
                if marks:                   # static: absent when False
                    span_begin("metric_check")
                v = metric_fn(st)
                if marks:
                    span_end("metric_check")  # operand-free, see above
                finite = jnp.isfinite(v)
                blown = jnp.isfinite(best) & (v > blowup * best)
                return (h.at[n].set(v), n + 1, finite & (v <= tol_v),
                        jnp.logical_not(finite) | blown,
                        jnp.where(finite, jnp.minimum(best, v), best))

            def skip(args):
                return (args[1], args[2], jnp.asarray(False),
                        jnp.asarray(False), best)

            hist, nchk, conv, bad, best = jax.lax.cond(
                do_check, check, skip, (state, hist, nchk))
            div = jnp.where(bad & (div < 0), k, div)
            kind = jnp.where(bad & (kind == DIVERGED_NONE),
                             DIVERGED_METRIC, kind)

        return (k + 1, state, hist, nchk, conv, best, dhist, ncorr, div,
                kind)

    init = (jnp.asarray(0), state0, hist0, jnp.asarray(0),
            jnp.asarray(False), jnp.asarray(jnp.inf, mdtype), drift0,
            jnp.asarray(0), jnp.asarray(-1), jnp.asarray(DIVERGED_NONE))
    (k, state, hist, nchk, conv, _, dhist, ncorr, div,
     kind) = jax.lax.while_loop(cond, body, init)
    return LoopResult(state, None, hist if has_metric else None, nchk, k,
                      conv, dhist if has_corr else None,
                      ncorr if has_corr else None, div, kind)


def run_rounds_fleet(round_fn: Callable, state0: Any, xs: Any, *,
                     tol: float = NO_TOL, check_every: int = 1,
                     metric_fn: Optional[Callable] = None) -> LoopResult:
    """Fleet variant of ``run_rounds``: one round protocol driving F
    independent problems in lockstep (repro.tune, DESIGN.md §10).

    ``state0`` is a pytree whose leaves carry a leading fleet axis F
    (e.g. alpha: (F, m)); ``round_fn(state, xs_k) -> state`` advances
    every member at once (typically a ``jax.vmap``-ed per-member round —
    leaves of the shared operator stay unbatched, so the gram work is
    computed ONCE per round for the whole fleet).  ``xs`` is shared
    across members (one schedule, F problems).

    ``metric_fn(state) -> (F,)`` gives per-member convergence values.
    The while-loop path keeps a per-member ``done`` mask: members at or
    below ``tol`` are FROZEN — subsequent rounds compute their update in
    lockstep but ``jnp.where`` discards it, so a converged member's
    state never drifts — and the loop exits once every member is done
    (vmap-safe masking: no data-dependent shapes, no per-member early
    exit).  ``metric_hist`` is ``(n_checks, F)``; ``converged`` is the
    final ``(F,)`` mask.

    The scan path (``metric_fn=None``) is the plain lockstep schedule —
    bit-comparable with F independent ``run_rounds`` scans.
    """
    R = jax.tree_util.tree_leaves(xs)[0].shape[0]

    if metric_fn is None:
        def body(state, x):
            return round_fn(state, x), 0.0

        state, _ = jax.lax.scan(body, state0, xs)
        F = jax.tree_util.tree_leaves(state0)[0].shape[0]
        return LoopResult(state, None, None, jnp.asarray(0),
                          jnp.asarray(R), jnp.zeros((F,), bool))

    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    n_checks = -(-R // check_every)
    mshape = jax.eval_shape(metric_fn, state0)
    F = mshape.shape[0]
    hist0 = jnp.full((n_checks, F), jnp.inf, mshape.dtype)
    tol_v = jnp.asarray(tol, mshape.dtype)

    def freeze(done, old, new):
        """Per-member where over a leading-F pytree leaf."""
        def leaf(o, nw):
            return jnp.where(done.reshape((F,) + (1,) * (nw.ndim - 1)),
                             o, nw)
        return jax.tree_util.tree_map(leaf, old, new)

    def cond(carry):
        k, _, _, _, done = carry
        return (k < R) & jnp.logical_not(jnp.all(done))

    def body(carry):
        k, state, hist, nchk, done = carry
        x = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, k, 0, keepdims=False),
            xs)
        state = freeze(done, state, round_fn(state, x))
        do_check = ((k + 1) % check_every == 0) | (k + 1 == R)

        def check(args):
            st, h, n, d = args
            v = metric_fn(st)                        # (F,)
            return h.at[n].set(v), n + 1, d | (v <= tol_v)

        def skip(args):
            return args[1], args[2], args[3]

        hist, nchk, done = jax.lax.cond(do_check, check, skip,
                                        (state, hist, nchk, done))
        return k + 1, state, hist, nchk, done

    k, state, hist, nchk, done = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), state0, hist0, jnp.asarray(0),
                     jnp.zeros((F,), bool)))
    return LoopResult(state, None, hist, nchk, k, done)
