"""Shared round-protocol loop for every solver variant (DESIGN.md §8).

All eight solver variants — {DCD, s-step DCD, BDCD, s-step BDCD} x
{serial, shard_map} — share the same outer structure: a state pytree
(alpha), a per-round transition ``round_fn(state, xs_k) -> state``, and a
schedule of per-round data ``xs``.  ``run_rounds`` is the single driver:

  * fast path (``metric_fn=None``): one ``lax.scan`` — bit-compatible
    with the legacy hand-written loops, optionally stacking per-round
    states for the convergence benchmarks;
  * tolerance path (``metric_fn`` given): one ``lax.while_loop`` that
    evaluates ``metric_fn(state)`` every ``check_every`` rounds (and at
    the final round), records it into a fixed-size history buffer, and
    stops as soon as the metric falls to ``tol``.

``run_rounds_fleet`` is the multi-problem twin (repro.tune, DESIGN.md
§10): the state carries a leading fleet axis F, the metric is
per-member, and the while-loop path maintains a vmap-safe per-member
``done`` mask — converged members are frozen in place and the loop only
exits when all F members are done.

``pad_rounds`` removes the old ``H % s == 0`` restriction: the schedule
is padded to a whole number of s-step rounds and a per-slot validity
mask rides along, so the final short round computes masked (zero)
updates for the padded slots — the iterates match the classical solver
at every ragged H (tests/test_api.py::TestRaggedTail).

The round_fns driven here are representation-agnostic: they read kernel
data only through a ``GramOperator`` (exact, low-rank, or a distributed
all-reduce operator — DESIGN.md §9), injected per fit via the
factories' ``op``/``op_factory`` parameters.

Everything here is pure ``lax``; the driver runs identically inside
``jax.jit`` and inside ``shard_map`` bodies (core/distributed.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

NO_TOL = float("-inf")        # sentinel: record the metric, never stop early


class LoopResult(NamedTuple):
    """Output of ``run_rounds`` (a pytree, so it can cross a jit boundary).

    state:       final solver state (alpha).
    state_hist:  per-round stacked states (scan mode + record_state) or None.
    metric_hist: (n_check_slots,) metric values (while mode; only the
                 first ``checks_run`` slots were evaluated — slice with
                 it, values may legitimately be inf/nan) or None (scan).
    checks_run:  number of metric evaluations actually performed.
    rounds_run:  number of rounds actually executed.
    converged:   metric <= tol at some check point (``run_rounds_fleet``:
                 the (F,) per-member mask; metric_hist is (n_checks, F)).
    """

    state: Any
    state_hist: Optional[Any]
    metric_hist: Optional[jnp.ndarray]
    checks_run: jnp.ndarray
    rounds_run: jnp.ndarray
    converged: jnp.ndarray

    def metric_history(self) -> Optional[jnp.ndarray]:
        """The evaluated prefix ``metric_hist[:checks_run]`` (host-side:
        forces ``checks_run``).  ``None`` when no metric was recorded.
        Fleet results slice the same way — the check axis leads."""
        if self.metric_hist is None:
            return None
        return self.metric_hist[:int(self.checks_run)]


def pad_rounds(schedule: jnp.ndarray, s: int):
    """Reshape an (H, ...) schedule into ((R, s, ...), (R, s)) rounds +
    validity mask with R = ceil(H/s); padded slots carry index 0 and
    valid 0.0, so masked round_fns make them exact no-ops."""
    H = schedule.shape[0]
    R = -(-H // s)
    pad = R * s - H
    if pad:
        schedule = jnp.concatenate(
            [schedule, jnp.zeros((pad,) + schedule.shape[1:],
                                 schedule.dtype)], axis=0)
    valid = (jnp.arange(R * s) < H).astype(jnp.float32)
    return (schedule.reshape((R, s) + schedule.shape[1:]),
            valid.reshape(R, s))


def run_rounds(round_fn: Callable, state0: Any, xs: Any, *,
               tol: float = NO_TOL, check_every: int = 1,
               metric_fn: Optional[Callable] = None,
               record_state: bool = False) -> LoopResult:
    """Drive ``R = len(xs)`` rounds of ``round_fn`` (see module docstring).

    xs is a pytree of arrays with a shared leading round axis.  With
    ``metric_fn=None`` this is exactly the legacy ``lax.scan`` loop;
    otherwise a ``lax.while_loop`` with early stopping at ``tol``
    (pass ``tol=NO_TOL`` to record the metric without ever stopping).
    """
    R = jax.tree_util.tree_leaves(xs)[0].shape[0]

    if metric_fn is None:
        def body(state, x):
            new = round_fn(state, x)
            return new, (new if record_state else 0.0)

        state, ys = jax.lax.scan(body, state0, xs)
        return LoopResult(state, ys if record_state else None, None,
                          jnp.asarray(0), jnp.asarray(R),
                          jnp.asarray(False))

    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    n_checks = -(-R // check_every)
    mdtype = jax.eval_shape(metric_fn, state0).dtype
    hist0 = jnp.full((n_checks,), jnp.inf, mdtype)
    tol_v = jnp.asarray(tol, mdtype)

    def cond(carry):
        k, _, _, _, conv = carry
        return (k < R) & jnp.logical_not(conv)

    def body(carry):
        k, state, hist, nchk, _ = carry
        x = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, k, 0, keepdims=False),
            xs)
        state = round_fn(state, x)
        do_check = ((k + 1) % check_every == 0) | (k + 1 == R)

        def check(args):
            st, h, n = args
            v = metric_fn(st)
            return h.at[n].set(v), n + 1, v <= tol_v

        def skip(args):
            return args[1], args[2], jnp.asarray(False)

        hist, nchk, conv = jax.lax.cond(do_check, check, skip,
                                        (state, hist, nchk))
        return k + 1, state, hist, nchk, conv

    k, state, hist, nchk, conv = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), state0, hist0, jnp.asarray(0),
                     jnp.asarray(False)))
    return LoopResult(state, None, hist, nchk, k, conv)


def run_rounds_fleet(round_fn: Callable, state0: Any, xs: Any, *,
                     tol: float = NO_TOL, check_every: int = 1,
                     metric_fn: Optional[Callable] = None) -> LoopResult:
    """Fleet variant of ``run_rounds``: one round protocol driving F
    independent problems in lockstep (repro.tune, DESIGN.md §10).

    ``state0`` is a pytree whose leaves carry a leading fleet axis F
    (e.g. alpha: (F, m)); ``round_fn(state, xs_k) -> state`` advances
    every member at once (typically a ``jax.vmap``-ed per-member round —
    leaves of the shared operator stay unbatched, so the gram work is
    computed ONCE per round for the whole fleet).  ``xs`` is shared
    across members (one schedule, F problems).

    ``metric_fn(state) -> (F,)`` gives per-member convergence values.
    The while-loop path keeps a per-member ``done`` mask: members at or
    below ``tol`` are FROZEN — subsequent rounds compute their update in
    lockstep but ``jnp.where`` discards it, so a converged member's
    state never drifts — and the loop exits once every member is done
    (vmap-safe masking: no data-dependent shapes, no per-member early
    exit).  ``metric_hist`` is ``(n_checks, F)``; ``converged`` is the
    final ``(F,)`` mask.

    The scan path (``metric_fn=None``) is the plain lockstep schedule —
    bit-comparable with F independent ``run_rounds`` scans.
    """
    R = jax.tree_util.tree_leaves(xs)[0].shape[0]

    if metric_fn is None:
        def body(state, x):
            return round_fn(state, x), 0.0

        state, _ = jax.lax.scan(body, state0, xs)
        F = jax.tree_util.tree_leaves(state0)[0].shape[0]
        return LoopResult(state, None, None, jnp.asarray(0),
                          jnp.asarray(R), jnp.zeros((F,), bool))

    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    n_checks = -(-R // check_every)
    mshape = jax.eval_shape(metric_fn, state0)
    F = mshape.shape[0]
    hist0 = jnp.full((n_checks, F), jnp.inf, mshape.dtype)
    tol_v = jnp.asarray(tol, mshape.dtype)

    def freeze(done, old, new):
        """Per-member where over a leading-F pytree leaf."""
        def leaf(o, nw):
            return jnp.where(done.reshape((F,) + (1,) * (nw.ndim - 1)),
                             o, nw)
        return jax.tree_util.tree_map(leaf, old, new)

    def cond(carry):
        k, _, _, _, done = carry
        return (k < R) & jnp.logical_not(jnp.all(done))

    def body(carry):
        k, state, hist, nchk, done = carry
        x = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, k, 0, keepdims=False),
            xs)
        state = freeze(done, state, round_fn(state, x))
        do_check = ((k + 1) % check_every == 0) | (k + 1 == R)

        def check(args):
            st, h, n, d = args
            v = metric_fn(st)                        # (F,)
            return h.at[n].set(v), n + 1, d | (v <= tol_v)

        def skip(args):
            return args[1], args[2], args[3]

        hist, nchk, done = jax.lax.cond(do_check, check, skip,
                                        (state, hist, nchk, done))
        return k + 1, state, hist, nchk, done

    k, state, hist, nchk, done = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), state0, hist0, jnp.asarray(0),
                     jnp.zeros((F,), bool)))
    return LoopResult(state, None, hist, nchk, k, done)
