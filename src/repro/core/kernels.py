"""Kernel functions (paper Table 1) and gram-slab computation.

The paper's hot spot is ``K(A, Omega_k^T A)`` — an ``m x (s*b)`` slab of the
full ``m x m`` kernel matrix.  On TPU this is a GEMM (MXU) followed by a
pointwise epilogue (VPU).  ``gram_slab`` below is the pure-jnp reference
path; the Pallas fused kernel lives in ``repro.kernels.gram`` and is
numerically validated against this implementation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

LINEAR = "linear"
POLYNOMIAL = "polynomial"
RBF = "rbf"

_VALID = (LINEAR, POLYNOMIAL, RBF)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Configuration of the kernel function K (paper Table 1).

    linear:      K(x, z) = x.z
    polynomial:  K(x, z) = (c + x.z)^d          (c >= 0, d >= 2)
    rbf:         K(x, z) = exp(-sigma ||x-z||^2) (sigma > 0)
    """

    name: str = RBF
    degree: int = 3
    coef0: float = 0.0
    sigma: float = 1.0

    def __post_init__(self):
        if self.name not in _VALID:
            raise ValueError(f"unknown kernel {self.name!r}; expected one of {_VALID}")


def apply_epilogue(dots: jnp.ndarray, cfg: KernelConfig,
                   row_sqnorms: Optional[jnp.ndarray] = None,
                   col_sqnorms: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pointwise kernel epilogue applied to a block of dot products.

    ``dots[i, j] = a_i . b_j``.  For RBF the squared norms of the rows of A
    (``row_sqnorms``) and of B (``col_sqnorms``) must be supplied so that
    ``||a_i - b_j||^2 = ||a_i||^2 + ||b_j||^2 - 2 a_i.b_j``.
    """
    if cfg.name == LINEAR:
        return dots
    if cfg.name == POLYNOMIAL:
        return (cfg.coef0 + dots) ** cfg.degree
    # RBF
    assert row_sqnorms is not None and col_sqnorms is not None
    sq = row_sqnorms[:, None] + col_sqnorms[None, :] - 2.0 * dots
    # Clamp tiny negative values produced by cancellation so exp stays <= 1
    sq = jnp.maximum(sq, 0.0)
    return jnp.exp(-cfg.sigma * sq)


@partial(jax.jit, static_argnames=("cfg",))
def gram_slab(A: jnp.ndarray, B: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """Compute the kernel slab ``K(A, B) in R^{m x r}``.

    A: (m, n) full (or feature-sharded) data matrix.
    B: (r, n) the sampled rows ``Omega_k^T A`` (same feature layout as A).
    """
    dots = A @ B.T
    if cfg.name == RBF:
        rs = jnp.sum(A * A, axis=1)
        cs = jnp.sum(B * B, axis=1)
        return apply_epilogue(dots, cfg, rs, cs)
    return apply_epilogue(dots, cfg)


def gram_full(A: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """Full m x m kernel matrix (only for oracles / closed-form solves)."""
    return gram_slab(A, A, cfg)


def kernel_diag(B: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """``diag K(B, B)`` without forming the block: (r,) for B: (r, n)."""
    sq = jnp.sum(B * B, axis=1)
    if cfg.name == LINEAR:
        return sq
    if cfg.name == POLYNOMIAL:
        return (cfg.coef0 + sq) ** cfg.degree
    return jnp.ones_like(sq)                     # RBF: K(x, x) = 1


def kmv_slab_free(A: jnp.ndarray, B: jnp.ndarray, X: jnp.ndarray,
                  cfg: KernelConfig, block: int = 2048) -> jnp.ndarray:
    """``U^T X`` with ``U = K(A, B)`` — without an ``m x r`` slab (DESIGN.md
    §2).

    linear:    U^T X = B (A^T X) — pure algebra, the slab never exists.
    poly/rbf:  blocked scan over m; each (block x r) kernel tile is built,
               contracted against its X chunk, and discarded, so peak extra
               memory is O(block * r) instead of O(m * r).  The Pallas KMV
               kernel (``repro.kernels.kmv``) is the fused on-chip version
               of exactly this loop.

    X: (m,) or (m, c) right-hand vectors; returns (r,) / (r, c).
    """
    vec = X.ndim == 1
    Xc = X[:, None] if vec else X
    if cfg.name == LINEAR:
        out = B @ (A.T @ Xc)                            # (r, c)
    else:
        m, n = A.shape
        r = B.shape[0]
        c = Xc.shape[1]
        blk = min(block, m)
        pad = (-m) % blk
        Ap = jnp.pad(A, ((0, pad), (0, 0)))
        Xp = jnp.pad(Xc, ((0, pad), (0, 0)))            # zero rows: no-op
        cs = jnp.sum(B * B, axis=1) if cfg.name == RBF else None

        def body(acc, chunk):
            a_blk, x_blk = chunk
            dots = a_blk @ B.T                          # (blk, r)
            if cfg.name == RBF:
                Kb = apply_epilogue(dots, cfg,
                                    jnp.sum(a_blk * a_blk, axis=1), cs)
            else:
                Kb = apply_epilogue(dots, cfg)
            return acc + Kb.T @ x_blk, None

        out, _ = jax.lax.scan(
            body, jnp.zeros((r, c), Xc.dtype),
            (Ap.reshape(-1, blk, n), Xp.reshape(-1, blk, c)))
    return out[:, 0] if vec else out


@dataclasses.dataclass(frozen=True)
class GramOperator:
    """Implicit gram-slab operator: slab-free access to ``U = K(A, A[idx])``.

    Every solver in ``repro.core`` consumes the ``m x (s*b)`` slab through
    exactly three reductions, so exposing only those lets backends (fused
    Pallas KMV, shard_map all-reduce) never materialize ``U`` in HBM:

      ``matvec(idx, X)``    -> ``U^T X``            (s*b,) or (s*b, c)
      ``cross_block(idx)``  -> ``U[idx, :]``        (s*b, s*b) sampled gram
      ``diag(idx)``         -> ``diag K`` at idx    (s*b,)

    ``round_data(idx, X)`` bundles (cross_block, matvec) — the per-round
    needs of the s-step solvers — so distributed implementations can fuse
    both into one collective (see ``core.distributed``).

    ``matvec_impl(A, B, X, cfg)`` overrides the contraction backend, e.g.
    with ``repro.kernels.kmv.kmv_pallas`` via ``kernels.ops``.
    """

    A: jnp.ndarray
    cfg: KernelConfig
    matvec_impl: Optional[callable] = None
    block: int = 2048

    def rows(self, idx: jnp.ndarray) -> jnp.ndarray:
        return self.A[idx]

    def matvec(self, idx: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
        B = self.A[idx]
        if self.matvec_impl is not None:
            return self.matvec_impl(self.A, B, X, self.cfg)
        return kmv_slab_free(self.A, B, X, self.cfg, block=self.block)

    def cross_block(self, idx: jnp.ndarray) -> jnp.ndarray:
        B = self.A[idx]
        return gram_slab(B, B, self.cfg)

    def diag(self, idx: jnp.ndarray) -> jnp.ndarray:
        return kernel_diag(self.A[idx], self.cfg)

    def round_data(self, idx: jnp.ndarray, X: jnp.ndarray):
        """(cross_block, matvec) for one s-step round."""
        return self.cross_block(idx), self.matvec(idx, X)
