"""Kernel functions (paper Table 1), gram-slab computation, and the
``GramOperator`` representation hierarchy (DESIGN.md §2/§9).

The paper's hot spot is ``K(A, Omega_k^T A)`` — an ``m x (s*b)`` slab of the
full ``m x m`` kernel matrix.  On TPU this is a GEMM (MXU) followed by a
pointwise epilogue (VPU).  ``gram_slab`` below is the pure-jnp reference
path; the Pallas fused kernel lives in ``repro.kernels.gram`` and is
numerically validated against this implementation.

Solvers and the predict subsystem never consume slabs directly: they go
through a ``GramOperator`` — ``ExactGramOperator`` (raw features +
kernel config, KMV-streamed) or ``LowRankGramOperator`` (Nystrom/feature
factor ``Phi``, every reduction O(l)-wide) — so the kernel
*representation* swaps without touching solver or serving math.

Operators are registered pytrees, which is also what makes solver
FLEETS cheap (repro.tune, DESIGN.md §10): under ``jax.vmap`` an
operator closed over (or passed) unbatched stays unbatched, so the slab
GEMM and epilogue of ``matvec``/``round_data`` are computed once per
round for all F vmapped members — only the contraction against the
batched right-hand side replicates.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

LINEAR = "linear"
POLYNOMIAL = "polynomial"
RBF = "rbf"

_VALID = (LINEAR, POLYNOMIAL, RBF)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Configuration of the kernel function K (paper Table 1).

    linear:      K(x, z) = x.z
    polynomial:  K(x, z) = (c + x.z)^d          (c >= 0, d >= 2)
    rbf:         K(x, z) = exp(-sigma ||x-z||^2) (sigma > 0)
    """

    name: str = RBF
    degree: int = 3
    coef0: float = 0.0
    sigma: float = 1.0

    def __post_init__(self):
        if self.name not in _VALID:
            raise ValueError(f"unknown kernel {self.name!r}; expected one of {_VALID}")


def apply_epilogue(dots: jnp.ndarray, cfg: KernelConfig,
                   row_sqnorms: Optional[jnp.ndarray] = None,
                   col_sqnorms: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pointwise kernel epilogue applied to a block of dot products.

    ``dots[i, j] = a_i . b_j``.  For RBF the squared norms of the rows of A
    (``row_sqnorms``) and of B (``col_sqnorms``) must be supplied so that
    ``||a_i - b_j||^2 = ||a_i||^2 + ||b_j||^2 - 2 a_i.b_j``.
    """
    if cfg.name == LINEAR:
        return dots
    if cfg.name == POLYNOMIAL:
        return (cfg.coef0 + dots) ** cfg.degree
    # RBF
    assert row_sqnorms is not None and col_sqnorms is not None
    sq = row_sqnorms[:, None] + col_sqnorms[None, :] - 2.0 * dots
    # Clamp tiny negative values produced by cancellation so exp stays <= 1
    sq = jnp.maximum(sq, 0.0)
    return jnp.exp(-cfg.sigma * sq)


@partial(jax.jit, static_argnames=("cfg",))
def gram_slab(A: jnp.ndarray, B: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """Compute the kernel slab ``K(A, B) in R^{m x r}``.

    A: (m, n) full (or feature-sharded) data matrix.
    B: (r, n) the sampled rows ``Omega_k^T A`` (same feature layout as A).
    """
    dots = A @ B.T
    if cfg.name == RBF:
        rs = jnp.sum(A * A, axis=1)
        cs = jnp.sum(B * B, axis=1)
        return apply_epilogue(dots, cfg, rs, cs)
    return apply_epilogue(dots, cfg)


def gram_full(A: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """Full m x m kernel matrix (only for oracles / closed-form solves)."""
    return gram_slab(A, A, cfg)


def kernel_diag(B: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """``diag K(B, B)`` without forming the block: (r,) for B: (r, n)."""
    sq = jnp.sum(B * B, axis=1)
    if cfg.name == LINEAR:
        return sq
    if cfg.name == POLYNOMIAL:
        return (cfg.coef0 + sq) ** cfg.degree
    return jnp.ones_like(sq)                     # RBF: K(x, x) = 1


def kmv_slab_free(A: jnp.ndarray, B: jnp.ndarray, X: jnp.ndarray,
                  cfg: KernelConfig, block: int = 2048) -> jnp.ndarray:
    """``U^T X`` with ``U = K(A, B)`` — without an ``m x r`` slab (DESIGN.md
    §2).

    linear:    U^T X = B (A^T X) — pure algebra, the slab never exists.
    poly/rbf:  blocked scan over m; each (block x r) kernel tile is built,
               contracted against its X chunk, and discarded, so peak extra
               memory is O(block * r) instead of O(m * r).  The Pallas KMV
               kernel (``repro.kernels.kmv``) is the fused on-chip version
               of exactly this loop.

    X: (m,) or (m, c) right-hand vectors; returns (r,) / (r, c).
    """
    vec = X.ndim == 1
    Xc = X[:, None] if vec else X
    if cfg.name == LINEAR:
        out = B @ (A.T @ Xc)                            # (r, c)
    else:
        m, n = A.shape
        r = B.shape[0]
        c = Xc.shape[1]
        blk = min(block, m)
        pad = (-m) % blk
        Ap = jnp.pad(A, ((0, pad), (0, 0)))
        Xp = jnp.pad(Xc, ((0, pad), (0, 0)))            # zero rows: no-op
        cs = jnp.sum(B * B, axis=1) if cfg.name == RBF else None

        def body(acc, chunk):
            a_blk, x_blk = chunk
            dots = a_blk @ B.T                          # (blk, r)
            if cfg.name == RBF:
                Kb = apply_epilogue(dots, cfg,
                                    jnp.sum(a_blk * a_blk, axis=1), cs)
            else:
                Kb = apply_epilogue(dots, cfg)
            return acc + Kb.T @ x_blk, None

        out, _ = jax.lax.scan(
            body, jnp.zeros((r, c), Xc.dtype),
            (Ap.reshape(-1, blk, n), Xp.reshape(-1, blk, c)))
    return out[:, 0] if vec else out


def kmv_apply(A: jnp.ndarray, B: jnp.ndarray, w: jnp.ndarray,
              cfg: KernelConfig, block: int = 2048) -> jnp.ndarray:
    """``K(A, B) @ w`` — the adjoint of ``kmv_slab_free``'s reduction,
    without an ``m x r`` slab (DESIGN.md §12).

    This is the residual-recurrence update of the guarded solvers:
    after a round changes ``alpha`` by ``w`` on the sampled coordinates,
    ``f = K alpha`` advances by ``K[:, idx] @ w = K(A, A[idx]) @ w``.
    Kernel-evaluation count is identical to the ``U^T alpha`` matvec the
    recurrence replaces (m x r either way), so guarded rounds stay
    cost-neutral between drift corrections.

    linear:    K(A, B) w = A (B^T w) — pure algebra, slab-free.
    poly/rbf:  blocked scan over m; each (block x r) kernel tile is
               built, applied to w, and discarded.

    w: (r,) or (r, c); returns (m,) / (m, c).
    """
    vec = w.ndim == 1
    Wc = w[:, None] if vec else w
    if cfg.name == LINEAR:
        out = A @ (B.T @ Wc)                            # (m, c)
    else:
        m, n = A.shape
        blk = min(block, m)
        pad = (-m) % blk
        Ap = jnp.pad(A, ((0, pad), (0, 0)))
        cs = jnp.sum(B * B, axis=1) if cfg.name == RBF else None

        def body(carry, a_blk):
            dots = a_blk @ B.T                          # (blk, r)
            if cfg.name == RBF:
                Kb = apply_epilogue(dots, cfg,
                                    jnp.sum(a_blk * a_blk, axis=1), cs)
            else:
                Kb = apply_epilogue(dots, cfg)
            return carry, Kb @ Wc                       # (blk, c)

        _, tiles = jax.lax.scan(body, 0.0, Ap.reshape(-1, blk, n))
        out = tiles.reshape(-1, Wc.shape[1])[:m]
    return out[:, 0] if vec else out


class GramOperator:
    """Abstract kernel *representation*: slab-free access to the gram
    matrix ``K`` of a fixed training set (DESIGN.md §9).

    Every solver in ``repro.core`` consumes the ``m x (s*b)`` slab
    ``U = K(A, A[idx])`` through exactly three reductions, so exposing only
    those lets backends (fused Pallas KMV, shard_map all-reduce, low-rank
    feature maps) never materialize ``U`` in HBM:

      ``matvec(idx, X)``    -> ``U^T X``            (s*b,) or (s*b, c)
      ``cross_block(idx)``  -> ``U[idx, :]``        (s*b, s*b) sampled gram
      ``diag(idx)``         -> ``diag K`` at idx    (s*b,)

    ``round_data(idx, X)`` bundles (cross_block, matvec) — the per-round
    needs of the s-step solvers — so distributed implementations can fuse
    both into one collective (see ``core.distributed``).

    The serving surface (``core/predict.py``) adds two more reductions:

      ``serve_weights(w)``     -> representation-side precompute of the
                                  model weights (identity for exact,
                                  ``Phi^T w`` — (l,) words — for low-rank)
      ``serve_block(Xq, sw)``  -> ``K(Xq, train) @ w`` for one query block

    plus ``scale_rows(y)`` (the solvers' ``diag(y)`` data scaling) and
    ``take(idx)`` (support-vector compaction), both returning a NEW
    operator over the transformed representation.

    Concrete backends: ``ExactGramOperator`` (raw features + kernel
    config), ``LowRankGramOperator`` (Nystrom/feature-map factor ``Phi``),
    and ``core.distributed.AllreduceGramOperator`` (1D shard_map psum
    fusion, round_data only).  All are registered jax pytrees, so a
    prebuilt operator can cross ``jit`` boundaries as a plain argument.
    """

    def __init__(self, *args, **kwargs):
        raise TypeError(
            "GramOperator is the abstract representation interface "
            "(DESIGN.md §9); construct a concrete backend instead — "
            "ExactGramOperator(A, cfg, ...) is the former concrete "
            "GramOperator")

    def rows(self, idx: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def matvec(self, idx: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def cross_block(self, idx: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def diag(self, idx: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    @property
    def n_samples(self) -> int:
        raise NotImplementedError

    @property
    def feature_dim(self) -> Optional[int]:
        """Width of the RAW query rows ``serve_block`` accepts, or None
        when the representation cannot serve new points (a low-rank
        factor without its feature map).  The serve-side eager
        validators (``core.predict.validate_queries``,
        ``serve.engine.ServingEngine.submit``) check incoming queries
        against this instead of letting a shape mismatch explode inside
        jit with an unattributable error."""
        raise NotImplementedError

    @property
    def dtype(self):
        """Dtype of the representation's data leaves — what query blocks
        must arrive as (serving never silently up/down-casts)."""
        raise NotImplementedError

    def scale_rows(self, y: jnp.ndarray) -> "GramOperator":
        raise NotImplementedError

    def take(self, idx) -> "GramOperator":
        raise NotImplementedError

    def serve_weights(self, w: jnp.ndarray) -> jnp.ndarray:
        """Representation-side precompute for serving (default: identity).

        ``w`` may be one model (m,) or F stacked models (m, F) — e.g. a
        solver fleet's solutions (repro.tune): the precompute and every
        ``serve_block`` call then serve ALL F models in one sweep (the
        cross-validation scorer grades a whole regularization grid with
        a single KMV per validation fold)."""
        return w

    def serve_block(self, Xq: jnp.ndarray, sw: jnp.ndarray) -> jnp.ndarray:
        """``K(Xq, train) @ w`` for one (q, n) query block, slab-free;
        (q,) for one model, (q, F) for stacked fleet weights."""
        raise NotImplementedError

    def round_data(self, idx: jnp.ndarray, X: jnp.ndarray):
        """(cross_block, matvec) for one s-step round."""
        return self.cross_block(idx), self.matvec(idx, X)

    # -- guarded-solve surface (repro.resilience, DESIGN.md §12) --------

    def apply_at(self, idx: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """``K[:, idx] @ w`` — the residual-recurrence update: after a
        round adds ``w`` to ``alpha[idx]``, ``f = K alpha`` advances by
        exactly this column combination.  (m,) for w: (s*b,)."""
        raise NotImplementedError

    def full_matvec(self, X: jnp.ndarray) -> jnp.ndarray:
        """``K @ X`` computed EXACTLY (one full kernel matvec) — the
        drift-correction / residual-replacement primitive and the
        residual initializer for warm starts.  (m,) for X: (m,)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ExactGramOperator(GramOperator):
    """Exact-kernel representation: raw features + kernel config; the
    reductions stream the slab through ``kmv_slab_free`` (or a Pallas KMV
    backend via ``matvec_impl(A, B, X, cfg)``, see ``kernels.ops``)."""

    A: jnp.ndarray
    cfg: KernelConfig
    matvec_impl: Optional[callable] = None
    block: int = 2048

    def rows(self, idx: jnp.ndarray) -> jnp.ndarray:
        return self.A[idx]

    def matvec(self, idx: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
        B = self.A[idx]
        if self.matvec_impl is not None:
            return self.matvec_impl(self.A, B, X, self.cfg)
        return kmv_slab_free(self.A, B, X, self.cfg, block=self.block)

    def cross_block(self, idx: jnp.ndarray) -> jnp.ndarray:
        B = self.A[idx]
        return gram_slab(B, B, self.cfg)

    def diag(self, idx: jnp.ndarray) -> jnp.ndarray:
        return kernel_diag(self.A[idx], self.cfg)

    @property
    def n_samples(self) -> int:
        return self.A.shape[0]

    @property
    def feature_dim(self) -> int:
        return self.A.shape[1]

    @property
    def dtype(self):
        return self.A.dtype

    def scale_rows(self, y: jnp.ndarray) -> "ExactGramOperator":
        """Operator over ``diag(y) A`` — the solvers' K-SVM data scaling
        (the paper implementation's convention, preserved verbatim).
        NOTE: for nonlinear kernels ``K(diag(y) A)`` is NOT
        ``diag(y) K diag(y)`` — see ``LowRankGramOperator.scale_rows``
        for the semantic consequence."""
        return dataclasses.replace(self, A=y[:, None] * self.A)

    def take(self, idx) -> "ExactGramOperator":
        return dataclasses.replace(self, A=self.A[idx])

    def serve_block(self, Xq: jnp.ndarray, sw: jnp.ndarray) -> jnp.ndarray:
        # K(A, Xq)^T sw == K(Xq, A) @ sw: one KMV with the queries as the
        # sampled rows — slab-free over the (large) training dimension.
        if self.matvec_impl is not None:
            return self.matvec_impl(self.A, Xq, sw, self.cfg)
        return kmv_slab_free(self.A, Xq, sw, self.cfg, block=self.block)

    def apply_at(self, idx: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        # always streams through the jnp KMV adjoint: the Pallas
        # matvec_impl accelerates the U^T X reduction only — apply_at's
        # tile loop runs over the m axis instead and its tiles are the
        # same size, so there is nothing kernel-shaped to gain here
        return kmv_apply(self.A, self.A[idx], w, self.cfg,
                         block=self.block)

    def full_matvec(self, X: jnp.ndarray) -> jnp.ndarray:
        # K symmetric: K @ X == K(A, A)^T X — one full-width KMV
        if self.matvec_impl is not None:
            return self.matvec_impl(self.A, self.A, X, self.cfg)
        return kmv_slab_free(self.A, self.A, X, self.cfg,
                             block=self.block)


@dataclasses.dataclass(frozen=True)
class LowRankGramOperator(GramOperator):
    """Low-rank representation ``K ~= Phi Phi^T`` (Nystrom, random
    features, ...): every reduction is an O(l)-width *linear*-kernel
    contraction over the factor ``Phi in R^{m x l}`` — the slab, the
    cross block, and the diagonal never touch the raw features or the
    nonlinear epilogue again.

    ``fmap`` (optional, e.g. ``nystrom.NystromMap``) maps NEW points into
    the same feature space; it is required only by the serving surface
    (``serve_block``), not by training.
    """

    Phi: jnp.ndarray
    fmap: Optional[object] = None

    def rows(self, idx: jnp.ndarray) -> jnp.ndarray:
        return self.Phi[idx]

    def matvec(self, idx: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
        return self.Phi[idx] @ (self.Phi.T @ X)

    def cross_block(self, idx: jnp.ndarray) -> jnp.ndarray:
        R = self.Phi[idx]
        return R @ R.T

    def diag(self, idx: jnp.ndarray) -> jnp.ndarray:
        R = self.Phi[idx]
        return jnp.sum(R * R, axis=1)

    @property
    def n_samples(self) -> int:
        return self.Phi.shape[0]

    @property
    def rank(self) -> int:
        return self.Phi.shape[1]

    @property
    def feature_dim(self) -> Optional[int]:
        # queries arrive in RAW feature space and go through the map;
        # without a map the operator cannot serve new points at all
        if self.fmap is None:
            return None
        return self.fmap.landmarks.shape[1]

    @property
    def dtype(self):
        return self.Phi.dtype

    def scale_rows(self, y: jnp.ndarray) -> "LowRankGramOperator":
        """``diag(y) K~ diag(y) == (diag(y) Phi)(diag(y) Phi)^T``
        exactly — the textbook K-SVM dual scaling, consistent with
        ``objectives._Qbar`` and the serving expansion.  This differs
        from the exact path's ``K(diag(y) A)`` convention for NONLINEAR
        kernels (where feature scaling does not commute with the
        epilogue), so exact vs low-rank K-SVM solutions are directly
        comparable only for the linear kernel; each path is internally
        consistent (training dual == stopping metric == serving)."""
        return dataclasses.replace(self, Phi=y[:, None] * self.Phi)

    def take(self, idx) -> "LowRankGramOperator":
        return dataclasses.replace(self, Phi=self.Phi[idx])

    def serve_weights(self, w: jnp.ndarray) -> jnp.ndarray:
        return self.Phi.T @ w                     # (l,) — the whole model

    def serve_block(self, Xq: jnp.ndarray, sw: jnp.ndarray) -> jnp.ndarray:
        if self.fmap is None:
            raise ValueError(
                "LowRankGramOperator has no feature map (fmap=None): "
                "serving new points needs one — build the operator via "
                "repro.core.nystrom.fit_nystrom / the repro.api facade "
                "(SolverOptions(approx='nystrom'))")
        return self.fmap(Xq) @ sw                 # O(l) per query

    def apply_at(self, idx: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        return self.Phi @ (self.Phi[idx].T @ w)   # O(m l), no slab

    def full_matvec(self, X: jnp.ndarray) -> jnp.ndarray:
        return self.Phi @ (self.Phi.T @ X)        # O(m l) exact in K~


def _chunk(X, chunk_rows: int):
    """(m, ...) -> (nc, chunk_rows, ...) with a zero-padded tail chunk."""
    m = X.shape[0]
    nc = -(-m // chunk_rows)
    pad = nc * chunk_rows - m
    if pad:
        X = jnp.pad(X, ((0, pad),) + ((0, 0),) * (X.ndim - 1))
    return X.reshape((nc, chunk_rows) + X.shape[1:])


@dataclasses.dataclass(frozen=True)
class StreamingGramOperator(GramOperator):
    """Out-of-core exact-kernel representation (DESIGN.md §14): the data
    lives CHUNKED as ``Xc: (n_chunks, chunk_rows, n)`` row blocks — on
    device this is the layout the double-buffered streaming KMV kernel
    (``kernels/kmv_stream.py``) DMAs from ANY/HBM memory two slots at a
    time, so no reduction ever holds the full X (or any m-tall slab) in
    its working set.  Every ``GramOperator`` reduction is a scan over
    the chunk axis:

      ``matvec``/``serve_block``/``full_matvec``  accumulate
          ``K(chunk_i, B)^T x_i`` chunk by chunk (the streamed KMV —
          fused in the Pallas kernel when ``matvec_impl`` is set);
      ``apply_at``  emits ``K(chunk_i, B) @ w`` piece by piece (the
          guard path's residual recurrence);
      ``cross_block``/``diag``/``rows``  gather only the sampled
          ``sb`` rows (two tiny index ops per chunk-crossing gather).

    The tail chunk is zero-padded; padded rows are contraction-safe
    (their right-hand-side rows are zero) and sliced off wherever rows
    are EMITTED.  ``m`` is the true row count.  A registered pytree like
    the resident operators, so it crosses jit boundaries, vmaps
    unbatched under solver fleets, and drops into all four round-fn
    factories, the guard, and the batched predictor unchanged.
    """

    Xc: jnp.ndarray                        # (nc, chunk_rows, n)
    cfg: KernelConfig
    m: int                                 # true rows (static)
    matvec_impl: Optional[callable] = None  # (Xc, B, Xvc, cfg) -> (r, c)

    @classmethod
    def from_dense(cls, A: jnp.ndarray, cfg: KernelConfig,
                   chunk_rows: int, matvec_impl=None
                   ) -> "StreamingGramOperator":
        if not isinstance(chunk_rows, int) or chunk_rows < 1:
            raise ValueError(f"chunk_rows must be a positive int, got "
                             f"{chunk_rows!r}")
        chunk_rows = min(chunk_rows, A.shape[0])
        return cls(_chunk(A, chunk_rows), cfg, A.shape[0],
                   matvec_impl=matvec_impl)

    @property
    def chunk_rows(self) -> int:
        return self.Xc.shape[1]

    @property
    def n_chunks(self) -> int:
        return self.Xc.shape[0]

    def rows(self, idx: jnp.ndarray) -> jnp.ndarray:
        cr = self.chunk_rows
        return self.Xc[idx // cr, idx % cr]

    def _chunk_rhs(self, X):
        """Chunk an (m, c) right-hand side to the Xc layout."""
        return _chunk(X, self.chunk_rows)

    def _stream_kmv(self, B: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
        """``K(A, B)^T X`` streamed over the chunk axis: the core
        contraction behind matvec / serve_block / full_matvec."""
        vec = X.ndim == 1
        Xvc = self._chunk_rhs(X[:, None] if vec else X)  # (nc, cr, c)
        if self.matvec_impl is not None:
            out = self.matvec_impl(self.Xc, B, Xvc, self.cfg)
        else:
            cfg = self.cfg
            cs = jnp.sum(B * B, axis=1) if cfg.name == RBF else None

            def body(acc, chunk):
                a_blk, x_blk = chunk
                dots = a_blk @ B.T                       # (cr, r)
                if cfg.name == RBF:
                    Kb = apply_epilogue(dots, cfg,
                                        jnp.sum(a_blk * a_blk, axis=1),
                                        cs)
                else:
                    Kb = apply_epilogue(dots, cfg)
                return acc + Kb.T @ x_blk, None

            out, _ = jax.lax.scan(
                body, jnp.zeros((B.shape[0], Xvc.shape[2]), X.dtype),
                (self.Xc, Xvc))
        out = out.astype(X.dtype)
        return out[:, 0] if vec else out

    def matvec(self, idx: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
        return self._stream_kmv(self.rows(idx), X)

    def cross_block(self, idx: jnp.ndarray) -> jnp.ndarray:
        B = self.rows(idx)
        return gram_slab(B, B, self.cfg)

    def diag(self, idx: jnp.ndarray) -> jnp.ndarray:
        return kernel_diag(self.rows(idx), self.cfg)

    @property
    def n_samples(self) -> int:
        return self.m

    @property
    def feature_dim(self) -> int:
        return self.Xc.shape[2]

    @property
    def dtype(self):
        return self.Xc.dtype

    def scale_rows(self, y: jnp.ndarray) -> "StreamingGramOperator":
        """Operator over ``diag(y) A`` (K-SVM scaling, same convention
        as ``ExactGramOperator.scale_rows``), chunked in place — the
        padded tail rows of y are zero, so padded data rows stay zero."""
        yc = self._chunk_rhs(y[:, None])                 # (nc, cr, 1)
        return dataclasses.replace(self, Xc=yc * self.Xc)

    def take(self, idx) -> "StreamingGramOperator":
        """Support-vector compaction (host-side, concrete idx): gather
        the kept rows and re-chunk."""
        kept = self.rows(jnp.asarray(idx))
        cr = min(self.chunk_rows, kept.shape[0])
        return dataclasses.replace(self, Xc=_chunk(kept, cr),
                                   m=kept.shape[0])

    def serve_block(self, Xq: jnp.ndarray, sw: jnp.ndarray) -> jnp.ndarray:
        # K(Xq, A) @ sw == K(A, Xq)^T sw: the queries ARE the sampled
        # rows — one streamed KMV, same pipe as training
        return self._stream_kmv(Xq, sw)

    def apply_at(self, idx: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """``K[:, idx] @ w`` emitted chunk by chunk (the guard path's
        residual recurrence): each chunk builds its (cr, sb) kernel tile
        against the sampled rows, applies w, and is discarded."""
        B = self.rows(idx)
        cfg = self.cfg
        vec = w.ndim == 1
        Wc = w[:, None] if vec else w
        cs = jnp.sum(B * B, axis=1) if cfg.name == RBF else None

        def body(carry, a_blk):
            dots = a_blk @ B.T                           # (cr, sb)
            if cfg.name == RBF:
                Kb = apply_epilogue(dots, cfg,
                                    jnp.sum(a_blk * a_blk, axis=1), cs)
            else:
                Kb = apply_epilogue(dots, cfg)
            return carry, Kb @ Wc                        # (cr, c)

        _, tiles = jax.lax.scan(body, 0.0, self.Xc)
        out = tiles.reshape(-1, Wc.shape[1])[:self.m].astype(Wc.dtype)
        return out[:, 0] if vec else out

    def full_matvec(self, X: jnp.ndarray) -> jnp.ndarray:
        """``K @ X`` exactly, chunk x chunk: the j-th output piece is
        ``K(chunk_j, A) @ X = K(A, chunk_j)^T X`` — one streamed KMV per
        chunk (nc^2 tiles total, never more than one in flight)."""
        vec = X.ndim == 1

        def piece(_, b_blk):
            return _, self._stream_kmv(b_blk, X)         # (cr,) / (cr, c)

        _, tiles = jax.lax.scan(piece, 0.0, self.Xc)
        out = (tiles.reshape(-1) if vec
               else tiles.reshape(-1, X.shape[1]))[:self.m]
        return out.astype(X.dtype)


jax.tree_util.register_dataclass(
    ExactGramOperator, data_fields=("A",),
    meta_fields=("cfg", "matvec_impl", "block"))
jax.tree_util.register_dataclass(
    LowRankGramOperator, data_fields=("Phi", "fmap"), meta_fields=())
jax.tree_util.register_dataclass(
    StreamingGramOperator, data_fields=("Xc",),
    meta_fields=("cfg", "m", "matvec_impl"))
