"""Kernel functions (paper Table 1) and gram-slab computation.

The paper's hot spot is ``K(A, Omega_k^T A)`` — an ``m x (s*b)`` slab of the
full ``m x m`` kernel matrix.  On TPU this is a GEMM (MXU) followed by a
pointwise epilogue (VPU).  ``gram_slab`` below is the pure-jnp reference
path; the Pallas fused kernel lives in ``repro.kernels.gram`` and is
numerically validated against this implementation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

LINEAR = "linear"
POLYNOMIAL = "polynomial"
RBF = "rbf"

_VALID = (LINEAR, POLYNOMIAL, RBF)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Configuration of the kernel function K (paper Table 1).

    linear:      K(x, z) = x.z
    polynomial:  K(x, z) = (c + x.z)^d          (c >= 0, d >= 2)
    rbf:         K(x, z) = exp(-sigma ||x-z||^2) (sigma > 0)
    """

    name: str = RBF
    degree: int = 3
    coef0: float = 0.0
    sigma: float = 1.0

    def __post_init__(self):
        if self.name not in _VALID:
            raise ValueError(f"unknown kernel {self.name!r}; expected one of {_VALID}")


def apply_epilogue(dots: jnp.ndarray, cfg: KernelConfig,
                   row_sqnorms: Optional[jnp.ndarray] = None,
                   col_sqnorms: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pointwise kernel epilogue applied to a block of dot products.

    ``dots[i, j] = a_i . b_j``.  For RBF the squared norms of the rows of A
    (``row_sqnorms``) and of B (``col_sqnorms``) must be supplied so that
    ``||a_i - b_j||^2 = ||a_i||^2 + ||b_j||^2 - 2 a_i.b_j``.
    """
    if cfg.name == LINEAR:
        return dots
    if cfg.name == POLYNOMIAL:
        return (cfg.coef0 + dots) ** cfg.degree
    # RBF
    assert row_sqnorms is not None and col_sqnorms is not None
    sq = row_sqnorms[:, None] + col_sqnorms[None, :] - 2.0 * dots
    # Clamp tiny negative values produced by cancellation so exp stays <= 1
    sq = jnp.maximum(sq, 0.0)
    return jnp.exp(-cfg.sigma * sq)


@partial(jax.jit, static_argnames=("cfg",))
def gram_slab(A: jnp.ndarray, B: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """Compute the kernel slab ``K(A, B) in R^{m x r}``.

    A: (m, n) full (or feature-sharded) data matrix.
    B: (r, n) the sampled rows ``Omega_k^T A`` (same feature layout as A).
    """
    dots = A @ B.T
    if cfg.name == RBF:
        rs = jnp.sum(A * A, axis=1)
        cs = jnp.sum(B * B, axis=1)
        return apply_epilogue(dots, cfg, rs, cs)
    return apply_epilogue(dots, cfg)


def gram_full(A: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """Full m x m kernel matrix (only for oracles / closed-form solves)."""
    return gram_slab(A, A, cfg)
