"""Classical Dual Coordinate Descent (paper Algorithm 1) for kernel SVM.

Solves the Lagrangian-dual K-SVM problem

    argmin_{alpha}  1/2 sum_ij alpha_i alpha_j y_i y_j K(a_i, a_j) - sum_i alpha_i
                    (+ 1/(4C) ||alpha||^2 for the L2 / squared-hinge variant)
    s.t. 0 <= alpha_i <= C   (L1)   /   0 <= alpha_i   (L2)

one coordinate at a time.  Each iteration needs one column ``u_k = K(Atil,
a_{i_k})`` of the kernel matrix — on a distributed machine that is one
all-reduce per iteration, which is exactly the bottleneck the s-step
variant (``sstep_dcd.py``) removes.

The column is only ever consumed through ``u_k^T alpha`` and ``u_k[i_k]``
(= K(a_i, a_i)), so the default path reads both through a slab-free
``GramOperator`` (DESIGN.md §2); ``gram_fn`` forces the legacy
materialized-column path, kept as the parity oracle.

Prefer the ``repro.api`` facade (``KernelSVM`` with
``SolverOptions(method="classical")``) over calling this entrypoint
directly — it adds tolerance-based stopping, layout dispatch, and
prediction on top of the same round protocol (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels import ExactGramOperator, KernelConfig
from .loop import run_rounds

L1 = "l1"
L2 = "l2"


@dataclasses.dataclass(frozen=True)
class SVMConfig:
    C: float = 1.0
    loss: str = L1            # "l1" (hinge) or "l2" (squared hinge)
    kernel: KernelConfig = dataclasses.field(default_factory=KernelConfig)

    def __post_init__(self):
        if self.loss not in (L1, L2):
            raise ValueError(f"loss must be 'l1' or 'l2', got {self.loss!r}")

    @property
    def nu(self) -> float:
        """Upper clip bound on alpha (paper line 2)."""
        return self.C if self.loss == L1 else jnp.inf

    @property
    def omega(self) -> float:
        """Diagonal shift (paper line 2)."""
        return 0.0 if self.loss == L1 else 1.0 / (2.0 * self.C)


def _nu_omega(cfg: SVMConfig, C=None):
    """(nu, omega) from the config, or re-derived from a traceable ``C``
    override (the fleet solvers' batched cfg leaf — see
    ``make_dcd_round_fn``)."""
    if C is None:
        return cfg.nu, cfg.omega
    if cfg.loss == L1:
        return C, 0.0
    return jnp.inf, 1.0 / (2.0 * C)


def coordinate_schedule(key: jax.Array, H: int, m: int) -> jnp.ndarray:
    """i_k ~ Uniform[m], k = 1..H.  Identical schedule is used by DCD and
    s-step DCD so that the two produce bitwise-comparable iterates."""
    return jax.random.randint(key, (H,), 0, m)


def _dcd_theta(alpha_i, g, eta, nu):
    """One DCD coordinate update (paper lines 8-16). Returns theta."""
    cand = jnp.clip(alpha_i - g, 0.0, nu) - alpha_i
    gtilde = jnp.abs(cand)
    return jnp.where(
        gtilde != 0.0,
        jnp.clip(alpha_i - g / eta, 0.0, nu) - alpha_i,
        0.0,
    )


def make_dcd_round_fn(A: jnp.ndarray, y: jnp.ndarray, cfg: SVMConfig,
                      gram_fn: Optional[Callable] = None,
                      op_factory: Optional[Callable] = None,
                      op=None, C=None, guard: bool = False) -> Callable:
    """``round_fn(alpha, i) -> alpha`` for ``loop.run_rounds``: one
    Algorithm-1 coordinate step.  This closure IS the classical solver;
    ``dcd_ksvm`` and the ``repro.api`` facade both drive it.

    ``op`` injects a prebuilt ``GramOperator`` over the TRAINING
    representation (already row-scaled by ``diag(y)`` — use
    ``operator.scale_rows(y)``); the facade builds it once per fit and
    reuses it for prediction (DESIGN.md §9).

    ``C`` overrides ``cfg.C`` with a TRACEABLE value — the batched cfg
    leaf of the fleet solver (repro.tune): the derived clip bound nu and
    L2 shift omega become traced scalars, so ``jax.vmap`` over
    per-member C's solves a whole C-grid in lockstep (DESIGN.md §10).

    ``guard=True`` switches to the guarded-carry protocol
    (``round_fn((alpha, f), i) -> (alpha, f)`` with ``f = Ktil @ alpha``
    maintained by the residual recurrence ``f += Ktil[:, i] * theta`` —
    one ``apply_at`` of the SAME column the round already evaluates, so
    the per-round kernel work is unchanged; DESIGN.md §12).  ``u^T
    alpha`` then becomes the free gather ``f[i]``, and drift correction
    can splice an exactly recomputed ``f`` back in (residual
    replacement).  Requires the operator path (no ``gram_fn``).
    """
    if sum(x is not None for x in (gram_fn, op_factory, op)) > 1:
        raise ValueError("pass at most one of gram_fn (materialized "
                         "slab), op_factory, or op (prebuilt operator)")
    if guard and gram_fn is not None:
        raise ValueError("guard=True requires the GramOperator path "
                         "(gram_fn= is the legacy materialized oracle)")
    Atil = y[:, None] * A                       # diag(y) @ A
    nu, omega = _nu_omega(cfg, C)
    if op is None and gram_fn is None:
        op = (op_factory or ExactGramOperator)(Atil, cfg.kernel)

    if guard:
        def round_fn(carry, i):
            alpha, f = carry                    # f = Ktil @ alpha, (m,)
            idx = i[None]
            eta = op.cross_block(idx)[0, 0] + omega
            g = f[i] - 1.0 + omega * alpha[i]   # u^T alpha = f[i], free
            theta = _dcd_theta(alpha[i], g, eta, nu)
            return (alpha.at[i].add(theta),
                    f + op.apply_at(idx, theta[None]))

        return round_fn

    def round_fn(alpha, i):
        idx = i[None]
        if gram_fn is not None:                 # materialized m x 1 column
            u = gram_fn(Atil, Atil[idx], cfg.kernel)[:, 0]
            eta = u[i] + omega
            g = u @ alpha - 1.0 + omega * alpha[i]
        else:                                   # slab-free operator path
            G, uTa = op.round_data(idx, alpha)  # (1, 1), (1,)
            eta = G[0, 0] + omega
            g = uTa[0] - 1.0 + omega * alpha[i]
        theta = _dcd_theta(alpha[i], g, eta, nu)
        return alpha.at[i].add(theta)

    return round_fn


# repro: noqa[CHK-STATIC] gram_fn/op_factory are module-level functions
#   (or None) at every call site; passing a fresh closure retraces by
#   design — it is the documented parity-oracle escape hatch.
@partial(jax.jit, static_argnames=("cfg", "record_every", "gram_fn",
                                   "op_factory"))
def dcd_ksvm(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
             schedule: jnp.ndarray, cfg: SVMConfig,
             record_every: int = 0,
             gram_fn: Optional[Callable] = None,
             op_factory: Optional[Callable] = None,
             op=None,
             ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 1 for ``H = len(schedule)`` iterations.

    Returns ``(alpha_H, history)`` where ``history`` stacks ``alpha`` every
    ``record_every`` iterations (or ``None`` when 0).  ``op`` (a pytree —
    it crosses the jit boundary as data) injects a prebuilt, already
    row-scaled training operator; see ``make_dcd_round_fn``.
    """
    round_fn = make_dcd_round_fn(A, y, cfg, gram_fn=gram_fn,
                                 op_factory=op_factory, op=op)
    res = run_rounds(round_fn, alpha0, schedule,
                     record_state=bool(record_every))
    if record_every:
        return res.state, res.state_hist[record_every - 1::record_every]
    return res.state, None
