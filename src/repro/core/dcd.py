"""Classical Dual Coordinate Descent (paper Algorithm 1) for kernel SVM.

Solves the Lagrangian-dual K-SVM problem

    argmin_{alpha}  1/2 sum_ij alpha_i alpha_j y_i y_j K(a_i, a_j) - sum_i alpha_i
                    (+ 1/(4C) ||alpha||^2 for the L2 / squared-hinge variant)
    s.t. 0 <= alpha_i <= C   (L1)   /   0 <= alpha_i   (L2)

one coordinate at a time.  Each iteration needs one column ``u_k = K(Atil,
a_{i_k})`` of the kernel matrix — on a distributed machine that is one
all-reduce per iteration, which is exactly the bottleneck the s-step
variant (``sstep_dcd.py``) removes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels import KernelConfig, gram_slab

L1 = "l1"
L2 = "l2"


@dataclasses.dataclass(frozen=True)
class SVMConfig:
    C: float = 1.0
    loss: str = L1            # "l1" (hinge) or "l2" (squared hinge)
    kernel: KernelConfig = dataclasses.field(default_factory=KernelConfig)

    def __post_init__(self):
        if self.loss not in (L1, L2):
            raise ValueError(f"loss must be 'l1' or 'l2', got {self.loss!r}")

    @property
    def nu(self) -> float:
        """Upper clip bound on alpha (paper line 2)."""
        return self.C if self.loss == L1 else jnp.inf

    @property
    def omega(self) -> float:
        """Diagonal shift (paper line 2)."""
        return 0.0 if self.loss == L1 else 1.0 / (2.0 * self.C)


def coordinate_schedule(key: jax.Array, H: int, m: int) -> jnp.ndarray:
    """i_k ~ Uniform[m], k = 1..H.  Identical schedule is used by DCD and
    s-step DCD so that the two produce bitwise-comparable iterates."""
    return jax.random.randint(key, (H,), 0, m)


def _dcd_update(alpha, i, u, nu, omega):
    """One DCD coordinate update (paper lines 8-16). Returns theta."""
    eta = u[i] + omega
    g = u @ alpha - 1.0 + omega * alpha[i]
    cand = jnp.clip(alpha[i] - g, 0.0, nu) - alpha[i]
    gtilde = jnp.abs(cand)
    theta = jnp.where(
        gtilde != 0.0,
        jnp.clip(alpha[i] - g / eta, 0.0, nu) - alpha[i],
        0.0,
    )
    return theta


@partial(jax.jit, static_argnames=("cfg", "record_every"))
def dcd_ksvm(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
             schedule: jnp.ndarray, cfg: SVMConfig,
             record_every: int = 0) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 1 for ``H = len(schedule)`` iterations.

    Returns ``(alpha_H, history)`` where ``history`` stacks ``alpha`` every
    ``record_every`` iterations (or ``None`` when 0).
    """
    Atil = y[:, None] * A                       # diag(y) @ A
    nu, omega = cfg.nu, cfg.omega
    H = schedule.shape[0]

    def step(alpha, i):
        u = gram_slab(Atil, Atil[i][None, :], cfg.kernel)[:, 0]
        theta = _dcd_update(alpha, i, u, nu, omega)
        alpha = alpha.at[i].add(theta)
        return alpha, (alpha if record_every else 0.0)

    alpha_H, hist = jax.lax.scan(step, alpha0, schedule)
    if record_every:
        hist = hist[record_every - 1::record_every]
        return alpha_H, hist
    return alpha_H, None
