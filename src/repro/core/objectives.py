"""Objectives, duality gap, and closed-form oracles used by the paper's
convergence experiments (Figures 1-2).

K-SVM duality gap:   gap(alpha) = P(alpha) + D(alpha), where D is the dual
*minimization* objective (so the dual value of the max form is -D) and P is
the primal objective evaluated at the primal point induced by alpha.
For a convex problem gap -> 0; the paper plots it to 1e-8.

K-RR: closed-form solution alpha* = ((1/lam) K + m I)^{-1} y and the
relative solution error ||alpha_k - alpha*|| / ||alpha*||.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .bdcd import KRRConfig
from .dcd import L1, SVMConfig
from .kernels import gram_full


def _Qbar(A, y, cfg: SVMConfig):
    """Qbar_ij = y_i y_j K(a_i, a_j) (+ omega I for L2)."""
    K = gram_full(A, cfg.kernel)
    Q = (y[:, None] * y[None, :]) * K
    if cfg.loss != L1:
        Q = Q + cfg.omega * jnp.eye(A.shape[0], dtype=A.dtype)
    return Q


@partial(jax.jit, static_argnames=("cfg",))
def ksvm_dual_objective(A, y, alpha, cfg: SVMConfig):
    """D(alpha) = 1/2 alpha^T Qbar alpha - sum(alpha)   (minimization form;
    the omega*I term inside Qbar carries the L2 1/(4C)||alpha||^2)."""
    Q = _Qbar(A, y, cfg)
    return 0.5 * alpha @ (Q @ alpha) - jnp.sum(alpha)


@partial(jax.jit, static_argnames=("cfg",))
def ksvm_primal_objective(A, y, alpha, cfg: SVMConfig):
    """Primal objective at the KKT primal point w = sum_i alpha_i y_i phi(a_i):
    1/2 ||w||^2 = 1/2 alpha^T Q alpha (Q without the L2 shift) and the
    margins y_i f(a_i) = (Q alpha)_i."""
    K = gram_full(A, cfg.kernel)
    Q = (y[:, None] * y[None, :]) * K
    Qa = Q @ alpha
    margins = jnp.maximum(1.0 - Qa, 0.0)
    if cfg.loss == L1:
        loss = cfg.C * jnp.sum(margins)
    else:
        loss = cfg.C * jnp.sum(margins ** 2)
    return 0.5 * alpha @ Qa + loss


def ksvm_duality_gap(A, y, alpha, cfg: SVMConfig):
    return ksvm_primal_objective(A, y, alpha, cfg) + ksvm_dual_objective(
        A, y, alpha, cfg)


def ksvm_gap_from_Qa(Qa, alpha, C, loss):
    """Primal + dual gap given ``Qa = (yy^T o K) alpha`` — the ONE place
    the gap formula (L1/L2 hinge, omega shift) lives.  ``C`` is
    traceable, so this core is shared by the jitted config-static
    wrappers below AND the fleet stopper (repro.tune.fleet), which vmaps
    it over per-member C's."""
    if loss == L1:
        Qbar_a = Qa
        hinge = C * jnp.sum(jnp.maximum(1.0 - Qa, 0.0))
    else:
        Qbar_a = Qa + (1.0 / (2.0 * C)) * alpha      # omega = 1/(2C)
        hinge = C * jnp.sum(jnp.maximum(1.0 - Qa, 0.0) ** 2)
    dual = 0.5 * alpha @ Qbar_a - jnp.sum(alpha)
    primal = 0.5 * alpha @ Qa + hinge
    return primal + dual


@partial(jax.jit, static_argnames=("cfg",))
def ksvm_duality_gap_lowrank(Phi, y, alpha, cfg: SVMConfig):
    """Duality gap under the factored kernel ``K~ = Phi Phi^T`` without
    ever forming the m x m gram: the shared core ``Qbar alpha`` is the
    O(m l) contraction ``y * (Phi (Phi^T (y alpha)))`` — the low-rank
    facade's tolerance stopper (``ksvm_duality_gap`` on a linear kernel
    over Phi computes the identical value at O(m^2) memory)."""
    ya = y * alpha
    Qa = y * (Phi @ (Phi.T @ ya))           # (yy^T Phi Phi^T) alpha
    return ksvm_gap_from_Qa(Qa, alpha, cfg.C, cfg.loss)


@partial(jax.jit, static_argnames=("cfg",))
def krr_dual_objective(A, y, alpha, cfg: KRRConfig):
    """Paper eq. (2): 1/2 alpha^T ((1/lam) K + m I) alpha - alpha^T y."""
    m = A.shape[0]
    K = gram_full(A, cfg.kernel)
    M = K / cfg.lam + m * jnp.eye(m, dtype=A.dtype)
    return 0.5 * alpha @ (M @ alpha) - alpha @ y


@partial(jax.jit, static_argnames=("cfg",))
def krr_closed_form(A, y, cfg: KRRConfig):
    """alpha* via full kernel-matrix factorization (paper's reference)."""
    m = A.shape[0]
    K = gram_full(A, cfg.kernel)
    M = K / cfg.lam + m * jnp.eye(m, dtype=A.dtype)
    return jnp.linalg.solve(M, y)


def relative_solution_error(alpha, alpha_star):
    return jnp.linalg.norm(alpha - alpha_star) / jnp.linalg.norm(alpha_star)


def krr_rel_residual_value(A, y, alpha, lam, kernel):
    """Traceable-lam core of ``krr_rel_residual`` — shared with the
    fleet stopper (repro.tune.fleet), which vmaps it over per-member
    lambdas.  Computed slab-free: one ``K @ alpha`` kernel matvec, no
    m x m gram."""
    from .kernels import kmv_slab_free
    m = A.shape[0]
    Ka = kmv_slab_free(A, A, alpha, kernel)
    r = y - (Ka / lam + m * alpha)
    return jnp.linalg.norm(r) / jnp.linalg.norm(y)


@partial(jax.jit, static_argnames=("cfg",))
def krr_rel_residual(A, y, alpha, cfg: KRRConfig):
    """Relative residual of the K-RR optimality system,
    ``||y - ((1/lam) K + m I) alpha|| / ||y||`` — the closed-form-free
    convergence metric used by the ``repro.api`` tolerance stopper (the
    paper's rel-error needs alpha*, which costs an m x m factorization).
    """
    return krr_rel_residual_value(A, y, alpha, cfg.lam, cfg.kernel)


@partial(jax.jit, static_argnames=("cfg",))
def ksvm_predict(A_train, y_train, alpha, A_test, cfg: SVMConfig):
    """Decision values f(x) = sum_i alpha_i y_i K(a_i, x).

    LEGACY DENSE ORACLE: materializes the full (q x m) test-kernel slab
    in one GEMM.  Serving goes through ``core/predict.py`` (batched,
    slab-free, SV-compacted — DESIGN.md §9); this stays as the parity
    reference ``benchmarks/fig6_predict.py`` and the tests gate against.
    """
    from .kernels import gram_slab
    Kxt = gram_slab(A_test, A_train, cfg.kernel)     # (mt, m)
    return Kxt @ (alpha * y_train)


@partial(jax.jit, static_argnames=("cfg",))
def krr_predict(A_train, alpha, A_test, cfg: KRRConfig):
    """K-RR predictions.  With M alpha = y, f(x) = (1/lam) K(x, A) alpha.

    LEGACY DENSE ORACLE — see ``ksvm_predict``; serving runs through
    ``core/predict.py`` (DESIGN.md §9).
    """
    from .kernels import gram_slab
    Kxt = gram_slab(A_test, A_train, cfg.kernel)
    return (Kxt @ alpha) / cfg.lam
