"""s-Step Dual Coordinate Descent (paper Algorithm 2) for kernel SVM.

Mathematically equivalent to ``dcd.dcd_ksvm`` (same coordinate schedule =>
same iterates in exact arithmetic), but computes the kernel data for ``s``
future coordinates up front:

    G_k = K(Atil_k, Atil_k) + omega*I in R^{s x s}  -- all cross terms the
                                                       inner recurrence needs
    U_k^T alpha in R^s                              -- one fused KMV

then runs the ``s`` scalar sub-problem solves sequentially with gradient
corrections (paper lines 14-23), touching only O(s^2) data and **no
communication**.

Slab-free by default (DESIGN.md §2): the ``m x s`` slab ``U_k`` is only
ever consumed through ``U_k^T alpha`` and its sampled ``s x s`` cross
block, so the solver reads both through a ``GramOperator`` and the slab
never exists in HBM.  Pass ``gram_fn`` (e.g. ``core.kernels.gram_slab`` or
the Pallas fused gram kernel) to force the legacy materialized-slab path —
kept as the parity oracle and the paper-faithful baseline.

Ragged schedules are fine: ``H % s != 0`` runs a final short round via the
pad-and-mask round protocol (``loop.pad_rounds``); padded slots produce
exactly-zero updates, so the iterates still match classical DCD.

Prefer the ``repro.api`` facade (``KernelSVM`` with
``SolverOptions(method="sstep", s=...)``) over calling this entrypoint
directly — it adds tolerance-based stopping, layout dispatch, and
prediction on top of the same round protocol (DESIGN.md §8).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .dcd import SVMConfig
from .kernels import ExactGramOperator
from .loop import pad_rounds, run_rounds


def sstep_dcd_inner(G0, u_dot_alpha, alpha_at, idx_s, nu, omega, s,
                    valid=None):
    """The redundant local phase shared by the serial and 2D-distributed
    solvers: ``s`` sequential scalar solves with gradient corrections
    (paper Alg. 2 lines 14-23).

    G0: (s, s) sampled cross block, u_dot_alpha: (s,), alpha_at: (s,),
    idx_s: (s,) the round's coordinates, valid: (s,) 1/0 mask for the
    ragged final round (padded slots get theta = 0).  Returns thetas (s,).
    """
    dtype = alpha_at.dtype
    ones = jnp.ones((s,), dtype) if valid is None else valid.astype(dtype)
    # same[t, j] = 1 iff i_{sk+t} == i_{sk+j} (for the omega & rho terms)
    same = (idx_s[:, None] == idx_s[None, :]).astype(dtype)
    eta = jnp.diagonal(G0) + omega               # (s,)

    def inner(j, thetas):
        tmask = (jnp.arange(s) < j).astype(dtype)    # t < j
        prior = thetas * tmask
        rho = alpha_at[j] + prior @ same[:, j]
        g = (u_dot_alpha[j] - 1.0 + omega * alpha_at[j]
             + prior @ G0[:, j]
             + omega * (prior @ same[:, j]))
        cand = jnp.clip(rho - g, 0.0, nu) - rho
        theta = jnp.where(
            jnp.abs(cand) != 0.0,
            jnp.clip(rho - g / eta[j], 0.0, nu) - rho,
            0.0,
        )
        return thetas.at[j].set(theta * ones[j])

    return jax.lax.fori_loop(0, s, inner, jnp.zeros((s,), dtype))


def make_sstep_dcd_round_fn(A: jnp.ndarray, y: jnp.ndarray, cfg: SVMConfig,
                            s: int,
                            gram_fn: Optional[Callable] = None,
                            op_factory: Optional[Callable] = None,
                            op=None, C=None, guard: bool = False,
                            ) -> Callable:
    """``round_fn(alpha, (idx_s, valid)) -> alpha`` for ``loop.run_rounds``:
    one Algorithm-2 outer round (communication phase + s local solves).

    ``op`` injects a prebuilt, already ``diag(y)``-scaled training
    operator (``operator.scale_rows(y)``) — exact or low-rank; the
    facade builds it once per fit (DESIGN.md §9).

    ``C`` overrides ``cfg.C`` with a TRACEABLE value — the batched cfg
    leaf of the fleet solver (repro.tune): vmapping the closure over
    per-member C's solves a whole C-grid in lockstep on ONE shared
    operator (DESIGN.md §10).

    ``guard=True`` switches to the guarded-carry protocol
    (``round_fn((alpha, f), xs) -> (alpha, f)`` with ``f = Ktil @
    alpha`` maintained by the residual recurrence ``f += Ktil[:, idx_s]
    @ thetas`` — the same m x s column block the fused KMV already
    evaluates, so per-round kernel work is unchanged; DESIGN.md §12).
    ``U^T alpha`` becomes the free gather ``f[idx_s]`` and drift
    correction can splice an exactly recomputed ``f`` back in (residual
    replacement, Devarakonda et al. 2016).  Requires the operator path.
    """
    if sum(x is not None for x in (gram_fn, op_factory, op)) > 1:
        raise ValueError("pass at most one of gram_fn (materialized "
                         "slab), op_factory, or op (prebuilt operator)")
    if guard and gram_fn is not None:
        raise ValueError("guard=True requires the GramOperator path "
                         "(gram_fn= is the legacy materialized oracle)")
    from .dcd import _nu_omega
    Atil = y[:, None] * A
    nu, omega = _nu_omega(cfg, C)
    if op is None and gram_fn is None:
        op = (op_factory or ExactGramOperator)(Atil, cfg.kernel)

    if guard:
        def round_fn(carry, xs):
            alpha, f = carry                     # f = Ktil @ alpha, (m,)
            idx_s, valid = xs
            G0 = op.cross_block(idx_s)           # (s, s)
            u_dot_alpha = f[idx_s]               # U^T alpha, free gather
            thetas = sstep_dcd_inner(G0, u_dot_alpha, alpha[idx_s],
                                     idx_s, nu, omega, s, valid)
            return (alpha.at[idx_s].add(thetas),
                    f + op.apply_at(idx_s, thetas))

        return round_fn

    def round_fn(alpha, xs):
        idx_s, valid = xs
        # --- communication phase: one fused round, one (would-be) psum ---
        if gram_fn is not None:                  # materialized m x s slab
            U = gram_fn(Atil, Atil[idx_s], cfg.kernel)
            G0 = U[idx_s, :]                     # V_k^T U_k, (s, s)
            u_dot_alpha = U.T @ alpha            # (s,)
        else:                                    # slab-free operator path
            G0, u_dot_alpha = op.round_data(idx_s, alpha)

        # --- redundant local phase: s sequential scalar solves ----------
        thetas = sstep_dcd_inner(G0, u_dot_alpha, alpha[idx_s], idx_s,
                                 nu, omega, s, valid)
        return alpha.at[idx_s].add(thetas)       # alpha_{sk+s}

    return round_fn


# repro: noqa[CHK-STATIC] gram_fn/op_factory are module-level functions
#   (or None) at every call site; passing a fresh closure retraces by
#   design — it is the documented parity-oracle escape hatch.
@partial(jax.jit, static_argnames=("cfg", "s", "record_rounds", "gram_fn",
                                   "op_factory"))
def sstep_dcd_ksvm(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
                   schedule: jnp.ndarray, cfg: SVMConfig, s: int,
                   record_rounds: bool = False,
                   gram_fn: Optional[Callable] = None,
                   op_factory: Optional[Callable] = None,
                   op=None,
                   ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 2 over ``ceil(H/s)`` rounds (ragged tails allowed).

    ``op_factory(Atil, kernel_cfg)`` overrides the slab-free GramOperator
    (e.g. with the Pallas KMV backend from ``repro.kernels.ops`` or the
    all-reduce operator from ``core.distributed``).  ``gram_fn(Atil, rows,
    kernel_cfg)`` instead selects the materialized-slab path.  ``op``
    (a pytree — crosses the jit boundary as data) injects a prebuilt,
    already row-scaled training operator; see ``make_sstep_dcd_round_fn``.
    """
    round_fn = make_sstep_dcd_round_fn(A, y, cfg, s, gram_fn=gram_fn,
                                       op_factory=op_factory, op=op)
    xs = pad_rounds(schedule, s)
    res = run_rounds(round_fn, alpha0, xs, record_state=record_rounds)
    return res.state, (res.state_hist if record_rounds else None)
