"""s-Step Dual Coordinate Descent (paper Algorithm 2) for kernel SVM.

Mathematically equivalent to ``dcd.dcd_ksvm`` (same coordinate schedule =>
same iterates in exact arithmetic), but computes the kernel slab for ``s``
future coordinates up front:

    U_k = K(Atil, Atil_k) in R^{m x s}       -- ONE gram GEMM + ONE all-reduce
    G_k = V_k^T U_k + omega*I in R^{s x s}   -- all cross terms needed by the
                                                inner recurrence

then runs the ``s`` scalar sub-problem solves sequentially with gradient
corrections (paper lines 14-23), touching only O(s^2) data and **no
communication**.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .dcd import SVMConfig
from .kernels import gram_slab


@partial(jax.jit, static_argnames=("cfg", "s", "record_rounds", "gram_fn"))
def sstep_dcd_ksvm(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
                   schedule: jnp.ndarray, cfg: SVMConfig, s: int,
                   record_rounds: bool = False,
                   gram_fn: Optional[Callable] = None,
                   ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 2.  ``schedule`` has length H and must satisfy H % s == 0.

    ``gram_fn(Atil, rows, kernel_cfg)`` may be overridden (e.g. with the
    Pallas fused gram kernel from ``repro.kernels.ops``); defaults to the
    jnp reference.
    """
    H = schedule.shape[0]
    if H % s != 0:
        raise ValueError(f"H={H} must be divisible by s={s}")
    gram = gram_fn or gram_slab

    Atil = y[:, None] * A
    nu, omega = cfg.nu, cfg.omega
    rounds = schedule.reshape(H // s, s)

    def outer(alpha, idx_s):
        # --- communication phase: one slab, one (would-be) all-reduce ----
        U = gram(Atil, Atil[idx_s], cfg.kernel)          # (m, s)
        G0 = U[idx_s, :]                                 # V_k^T U_k, (s, s)
        eta = jnp.diagonal(G0) + omega                   # (s,)
        u_dot_alpha = U.T @ alpha                        # (s,)
        alpha_at = alpha[idx_s]                          # (s,)
        # same[t, j] = 1 iff i_{sk+t} == i_{sk+j} (for the omega & rho terms)
        same = (idx_s[:, None] == idx_s[None, :]).astype(alpha.dtype)

        # --- redundant local phase: s sequential scalar solves ----------
        def inner(j, thetas):
            mask = (jnp.arange(s) < j).astype(alpha.dtype)   # t < j
            prior = thetas * mask
            rho = alpha_at[j] + prior @ same[:, j]
            g = (u_dot_alpha[j] - 1.0 + omega * alpha_at[j]
                 + prior @ G0[:, j]
                 + omega * (prior @ same[:, j]))
            cand = jnp.clip(rho - g, 0.0, nu) - rho
            theta = jnp.where(
                jnp.abs(cand) != 0.0,
                jnp.clip(rho - g / eta[j], 0.0, nu) - rho,
                0.0,
            )
            return thetas.at[j].set(theta)

        thetas = jax.lax.fori_loop(0, s, inner, jnp.zeros((s,), alpha.dtype))
        alpha = alpha.at[idx_s].add(thetas)              # alpha_{sk+s}
        return alpha, (alpha if record_rounds else 0.0)

    alpha_H, hist = jax.lax.scan(outer, alpha0, rounds)
    return (alpha_H, hist) if record_rounds else (alpha_H, None)
