"""s-Step Dual Coordinate Descent (paper Algorithm 2) for kernel SVM.

Mathematically equivalent to ``dcd.dcd_ksvm`` (same coordinate schedule =>
same iterates in exact arithmetic), but computes the kernel data for ``s``
future coordinates up front:

    G_k = K(Atil_k, Atil_k) + omega*I in R^{s x s}  -- all cross terms the
                                                       inner recurrence needs
    U_k^T alpha in R^s                              -- one fused KMV

then runs the ``s`` scalar sub-problem solves sequentially with gradient
corrections (paper lines 14-23), touching only O(s^2) data and **no
communication**.

Slab-free by default (DESIGN.md §2): the ``m x s`` slab ``U_k`` is only
ever consumed through ``U_k^T alpha`` and its sampled ``s x s`` cross
block, so the solver reads both through a ``GramOperator`` and the slab
never exists in HBM.  Pass ``gram_fn`` (e.g. ``core.kernels.gram_slab`` or
the Pallas fused gram kernel) to force the legacy materialized-slab path —
kept as the parity oracle and the paper-faithful baseline.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .dcd import SVMConfig
from .kernels import GramOperator


@partial(jax.jit, static_argnames=("cfg", "s", "record_rounds", "gram_fn",
                                   "op_factory"))
def sstep_dcd_ksvm(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
                   schedule: jnp.ndarray, cfg: SVMConfig, s: int,
                   record_rounds: bool = False,
                   gram_fn: Optional[Callable] = None,
                   op_factory: Optional[Callable] = None,
                   ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 2.  ``schedule`` has length H and must satisfy H % s == 0.

    ``op_factory(Atil, kernel_cfg)`` overrides the slab-free GramOperator
    (e.g. with the Pallas KMV backend from ``repro.kernels.ops`` or the
    all-reduce operator from ``core.distributed``).  ``gram_fn(Atil, rows,
    kernel_cfg)`` instead selects the materialized-slab path.
    """
    H = schedule.shape[0]
    if H % s != 0:
        raise ValueError(f"H={H} must be divisible by s={s}")
    if gram_fn is not None and op_factory is not None:
        raise ValueError("pass either gram_fn (materialized slab) or "
                         "op_factory (slab-free operator), not both")

    Atil = y[:, None] * A
    nu, omega = cfg.nu, cfg.omega
    rounds = schedule.reshape(H // s, s)
    op = None if gram_fn else (op_factory or GramOperator)(Atil, cfg.kernel)

    def outer(alpha, idx_s):
        # --- communication phase: one fused round, one (would-be) psum ---
        if gram_fn is not None:                  # materialized m x s slab
            U = gram_fn(Atil, Atil[idx_s], cfg.kernel)
            G0 = U[idx_s, :]                     # V_k^T U_k, (s, s)
            u_dot_alpha = U.T @ alpha            # (s,)
        else:                                    # slab-free operator path
            G0, u_dot_alpha = op.round_data(idx_s, alpha)
        eta = jnp.diagonal(G0) + omega           # (s,)
        alpha_at = alpha[idx_s]                  # (s,)
        # same[t, j] = 1 iff i_{sk+t} == i_{sk+j} (for the omega & rho terms)
        same = (idx_s[:, None] == idx_s[None, :]).astype(alpha.dtype)

        # --- redundant local phase: s sequential scalar solves ----------
        def inner(j, thetas):
            mask = (jnp.arange(s) < j).astype(alpha.dtype)   # t < j
            prior = thetas * mask
            rho = alpha_at[j] + prior @ same[:, j]
            g = (u_dot_alpha[j] - 1.0 + omega * alpha_at[j]
                 + prior @ G0[:, j]
                 + omega * (prior @ same[:, j]))
            cand = jnp.clip(rho - g, 0.0, nu) - rho
            theta = jnp.where(
                jnp.abs(cand) != 0.0,
                jnp.clip(rho - g / eta[j], 0.0, nu) - rho,
                0.0,
            )
            return thetas.at[j].set(theta)

        thetas = jax.lax.fori_loop(0, s, inner, jnp.zeros((s,), alpha.dtype))
        alpha = alpha.at[idx_s].add(thetas)              # alpha_{sk+s}
        return alpha, (alpha if record_rounds else 0.0)

    alpha_H, hist = jax.lax.scan(outer, alpha0, rounds)
    return (alpha_H, hist) if record_rounds else (alpha_H, None)
