"""Batched, jit-cached, slab-free prediction (DESIGN.md §9).

The legacy predict paths (``objectives.ksvm_predict`` / ``krr_predict``)
materialize the dense ``(q x m)`` kernel slab ``K(A_test, A_train)``
against the FULL training set in one serial GEMM — exactly the slab
bloat the slab-free solvers eliminated from training, and the first
thing that falls over when a fitted model has to serve heavy query
traffic (m is millions; q arrives in a stream).

This module serves through the same ``GramOperator`` representation
hierarchy the solvers train through:

  * exact operators tile each query block through the slab-free KMV
    contraction (``K(A, Xq)^T w == K(Xq, A) @ w`` — queries ARE the
    sampled rows, so the ``q x m`` slab never exists; the Pallas KMV
    kernel applies when the operator carries a ``matvec_impl``);
  * low-rank operators precompute ``sw = Phi^T w`` ONCE — (l,) words,
    the entire model — and answer each block with an O(l)-per-query
    feature-map matmul;
  * K-SVM models are compacted to their support vectors first
    (``compact_support``): hinge-loss duals are sparse, so the serving
    representation shrinks to the SVs before any query arrives.

Queries are padded to power-of-two blocks (capped at ``batch``), so the
jitted per-block function compiles at most log2(batch) shapes and every
later call — any query count — hits the jit cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import GramOperator


@jax.jit
def _serve_block(op: GramOperator, sw, Xq):
    """One query block through the operator's serving reduction.  ``op``
    is a pytree argument: its arrays are traced (no retrace when the
    representation changes values) and its static config is part of the
    cache key (retrace when the kernel/backend changes)."""
    return op.serve_block(Xq, sw)


def compact_support(op: GramOperator, w, tol: float = 0.0):
    """Drop zero-weight training rows from the serving representation.

    K-SVM duals are sparse (alpha_i = 0 off the margin), so serving only
    the support vectors cuts per-query work by the SV fraction for exact
    operators.  Host-side (data-dependent shape): call once at model
    build, not per query.  Returns ``(compacted_op, compacted_w)``.
    """
    w_host = np.asarray(jax.device_get(w))
    keep = np.flatnonzero(np.abs(w_host) > tol)
    if keep.size == 0:                   # degenerate all-zero model:
        keep = np.array([0])             # serve one row, weight zero
    if keep.size == w_host.shape[0]:
        return op, w
    keep_j = jnp.asarray(keep)
    return op.take(keep_j), w[keep_j]


class BatchedPredictor:
    """``f(Xq) = scale * K(Xq, train) @ w`` served in fixed-size blocks.

    Built once per fitted model (the ``repro.api`` estimators cache one):
    the representation-side precompute (``op.serve_weights`` — identity
    for exact, ``Phi^T w`` for low-rank) happens here, and every
    ``__call__`` only pays the per-block reduction.
    """

    def __init__(self, op: GramOperator, w, *, batch: int = 1024,
                 scale: float = 1.0, compact: bool = False,
                 compact_tol: float = 0.0):
        if not isinstance(batch, int) or batch < 1:
            raise ValueError(f"batch must be a positive int, got {batch!r}")
        if compact:
            op, w = compact_support(op, w, tol=compact_tol)
        self.op = op
        self.batch = batch
        self.scale = scale
        self.sw = op.serve_weights(w)

    def _block_shape(self, q: int) -> int:
        """Pad small requests up to a power-of-two bucket (capped at
        ``batch``): a stream of varying query counts then compiles at
        most log2(batch) block shapes instead of one per distinct q."""
        if q >= self.batch:
            return self.batch
        return min(self.batch, max(8, 1 << (q - 1).bit_length()))

    def __call__(self, A_test: jnp.ndarray) -> jnp.ndarray:
        q = A_test.shape[0]
        if q == 0:                       # drained queue: graceful empty
            return jnp.zeros((0,), self.sw.dtype)
        out, lo = [], 0
        while lo < q:
            qb = self._block_shape(q - lo)   # tail drops to its own
            Xq = A_test[lo:lo + qb]          # (cached) pow-2 bucket
            if Xq.shape[0] != qb:            # pad to the block shape,
                pad = qb - Xq.shape[0]       # slice off below
                Xq = jnp.pad(Xq, ((0, pad), (0, 0)))
            out.append(_serve_block(self.op, self.sw, Xq))
            lo += qb
        f = jnp.concatenate(out)[:q] if len(out) > 1 else out[0][:q]
        return f * self.scale if self.scale != 1.0 else f


def batched_predict(op: GramOperator, w, A_test, *, batch: int = 1024,
                    scale: float = 1.0) -> jnp.ndarray:
    """One-shot convenience wrapper over ``BatchedPredictor``."""
    return BatchedPredictor(op, w, batch=batch, scale=scale)(A_test)
