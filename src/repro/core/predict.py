"""Batched, jit-cached, slab-free prediction (DESIGN.md §9).

The legacy predict paths (``objectives.ksvm_predict`` / ``krr_predict``)
materialize the dense ``(q x m)`` kernel slab ``K(A_test, A_train)``
against the FULL training set in one serial GEMM — exactly the slab
bloat the slab-free solvers eliminated from training, and the first
thing that falls over when a fitted model has to serve heavy query
traffic (m is millions; q arrives in a stream).

This module serves through the same ``GramOperator`` representation
hierarchy the solvers train through:

  * exact operators tile each query block through the slab-free KMV
    contraction (``K(A, Xq)^T w == K(Xq, A) @ w`` — queries ARE the
    sampled rows, so the ``q x m`` slab never exists; the Pallas KMV
    kernel applies when the operator carries a ``matvec_impl``);
  * low-rank operators precompute ``sw = Phi^T w`` ONCE — (l,) words,
    the entire model — and answer each block with an O(l)-per-query
    feature-map matmul;
  * K-SVM models are compacted to their support vectors first
    (``compact_support``): hinge-loss duals are sparse, so the serving
    representation shrinks to the SVs before any query arrives.

Queries are padded to power-of-two blocks (capped at ``batch``), so the
jitted per-block function compiles at most log2(batch) shapes and every
later call — any query count, including batches LARGER than the largest
bucket, which split into full blocks plus a bucketed tail — hits the
jit cache.  ``w`` may also be F stacked models (m, F): the whole fleet
(or a multi-model registry group, ``repro.serve``) is then served
through ONE block call per bucket.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import GramOperator


@jax.jit
def _serve_block(op: GramOperator, sw, Xq):
    """One query block through the operator's serving reduction.  ``op``
    is a pytree argument: its arrays are traced (no retrace when the
    representation changes values) and its static config is part of the
    cache key (retrace when the kernel/backend changes)."""
    return op.serve_block(Xq, sw)


def serve_cache_size() -> int:
    """Number of compiled ``_serve_block`` entries — the recompile
    observable the serving SLO benchmark and the engine tests assert on
    (zero growth after warmup = no recompiles at admission)."""
    return _serve_block._cache_size()


def validate_queries(op: GramOperator, X, name: str = "A_test"):
    """Eager serve-side input validation (mirrors the fit-side
    ``api._check_finite`` satellite of DESIGN.md §12): reject malformed
    query blocks at the public boundary with the offending ARGUMENT
    named, instead of failing inside jit with a shape error attributed
    to an internal contraction.

    Checks: 2-D shape, feature width against ``op.feature_dim``, and
    dtype against ``op.dtype`` (serving never silently casts — an f64
    query stream against an f32 model doubles every block's bandwidth
    and still returns f32-accurate values).  Array inputs keep their
    kind (a host numpy block stays on host — the serving engine
    validates at submit without paying a device round trip per
    request); anything else is converted via ``jnp.asarray``.
    """
    if not (hasattr(X, "ndim") and hasattr(X, "dtype")):
        X = jnp.asarray(X)
    if X.ndim != 2:
        raise ValueError(
            f"{name} must be 2-D (queries x features), got shape "
            f"{X.shape}")
    fd = op.feature_dim
    if fd is None:
        raise ValueError(
            f"{name}: this operator cannot serve new points (low-rank "
            f"factor without a feature map — build it via "
            f"repro.core.nystrom.fit_nystrom or the repro.api facade)")
    if X.shape[1] != fd:
        raise ValueError(
            f"{name} has {X.shape[1]} features but the fitted operator "
            f"expects {fd} — the query block must match the training "
            f"feature width")
    if X.dtype != op.dtype:
        raise ValueError(
            f"{name} has dtype {X.dtype} but the fitted operator is "
            f"{op.dtype} — cast the queries explicitly (serving never "
            f"silently converts)")
    return X


def compact_support(op: GramOperator, w, tol: float = 0.0):
    """Drop zero-weight training rows from the serving representation.

    K-SVM duals are sparse (alpha_i = 0 off the margin), so serving only
    the support vectors cuts per-query work by the SV fraction for exact
    operators.  Host-side (data-dependent shape): call once at model
    build, not per query.  Returns ``(compacted_op, compacted_w)``.

    ``w`` may be stacked models (m, F): a row survives when ANY member
    uses it (the compacted operator must serve the whole stack).  With
    zero support vectors the model is identically zero — one row is
    kept (operators cannot be empty) with its weight forced to EXACT
    zero, so the degenerate model still serves exact zeros even when
    ``tol > 0`` left a sub-threshold residue on the kept row.
    """
    w_host = np.asarray(jax.device_get(w))
    mags = (np.abs(w_host) if w_host.ndim == 1
            else np.max(np.abs(w_host), axis=tuple(range(1, w_host.ndim))))
    keep = np.flatnonzero(mags > tol)
    if keep.size == 0:                   # degenerate all-zero model:
        keep_j = jnp.asarray([0])        # serve one row, weight zero
        return op.take(keep_j), jnp.zeros_like(w[keep_j])
    if keep.size == w_host.shape[0]:
        return op, w
    keep_j = jnp.asarray(keep)
    return op.take(keep_j), w[keep_j]


class BatchedPredictor:
    """``f(Xq) = scale * K(Xq, train) @ w`` served in fixed-size blocks.

    Built once per fitted model (the ``repro.api`` estimators cache one)
    or once per registry GROUP (``repro.serve``: w is the (m, F) stacked
    weights of every model sharing the operator): the representation-side
    precompute (``op.serve_weights`` — identity for exact, ``Phi^T w``
    for low-rank) happens here, and every ``__call__`` only pays the
    per-block reduction.
    """

    def __init__(self, op: GramOperator, w, *, batch: int = 1024,
                 scale: float = 1.0, compact: bool = False,
                 compact_tol: float = 0.0, stream: Optional[int] = None):
        if not isinstance(batch, int) or batch < 1:
            raise ValueError(f"batch must be a positive int, got {batch!r}")
        if stream is not None and (not isinstance(stream, int)
                                   or stream < 1):
            raise ValueError(f"stream must be None or a positive int "
                             f"(query rows per host chunk), got "
                             f"{stream!r}")
        if compact:
            op, w = compact_support(op, w, tol=compact_tol)
        self.op = op
        self.batch = batch
        self.scale = scale
        self.stream = stream
        self.sw = op.serve_weights(w)

    def block_shape(self, q: int) -> int:
        """The power-of-two bucket a q-query request pads to (capped at
        ``batch``): a stream of varying query counts then compiles at
        most log2(batch) block shapes instead of one per distinct q.
        Public so batch assemblers (``serve.engine``) can build
        bucket-shaped host buffers directly and skip the device-side
        pad."""
        if q >= self.batch:
            return self.batch
        return min(self.batch, max(8, 1 << (q - 1).bit_length()))

    def bucket_sizes(self):
        """Every block shape this predictor can issue — the full jit
        working set.  ``warmup`` compiles them all up front so steady
        traffic never recompiles (asserted via ``serve_cache_size``)."""
        sizes, b = [], 8
        while b < self.batch:
            sizes.append(b)
            b <<= 1
        sizes.append(self.batch)
        return sizes

    def warmup(self) -> int:
        """Pre-compile every bucket (zero-filled blocks); returns the
        bucket count.  After this, admission-time calls of ANY query
        count hit the jit cache — the serving engine's no-recompile
        invariant."""
        fd = self.op.feature_dim
        for qb in self.bucket_sizes():
            jax.block_until_ready(_serve_block(
                self.op, self.sw, jnp.zeros((qb, fd), self.op.dtype)))
        return len(self.bucket_sizes())

    def _serve_chunk(self, A_chunk) -> jnp.ndarray:
        """Bucketed block loop over one (device-resident) query chunk —
        the pre-streaming ``__call__`` body, unscaled."""
        q = A_chunk.shape[0]
        out, lo = [], 0
        while lo < q:
            qb = self.block_shape(q - lo)    # tail drops to its own
            Xq = A_chunk[lo:lo + qb]         # (cached) pow-2 bucket
            if Xq.shape[0] != qb:            # pad to the block shape,
                pad = qb - Xq.shape[0]       # slice off below
                Xq = jnp.pad(jnp.asarray(Xq), ((0, pad), (0, 0)))
            out.append(_serve_block(self.op, self.sw, jnp.asarray(Xq)))
            lo += qb
        return jnp.concatenate(out)[:q] if len(out) > 1 else out[0][:q]

    def __call__(self, A_test) -> jnp.ndarray:
        q = A_test.shape[0]
        if q == 0:                       # drained queue: graceful empty
            # shape follows the weights: (0,) for one model, (0, F) for
            # a stacked fleet/registry group
            return jnp.zeros((0,) + self.sw.shape[1:], self.sw.dtype)
        if self.stream is not None and q > self.stream:
            # out-of-core query stream (DESIGN.md §14): A_test may be a
            # host array / memmap far larger than device memory — only
            # ``stream`` query rows are sliced (and transferred) at a
            # time, and each finished chunk's scores are pulled back to
            # host before the next chunk is touched, so the device
            # working set stays one chunk of queries + one chunk of
            # scores regardless of q.
            parts = []
            for lo in range(0, q, self.stream):
                f_c = self._serve_chunk(A_test[lo:lo + self.stream])
                parts.append(np.asarray(jax.device_get(f_c)))
            f = jnp.asarray(np.concatenate(parts))
        else:
            f = self._serve_chunk(A_test)
        return f * self.scale if self.scale != 1.0 else f


def batched_predict(op: GramOperator, w, A_test, *, batch: int = 1024,
                    scale: float = 1.0) -> jnp.ndarray:
    """One-shot convenience wrapper over ``BatchedPredictor``."""
    return BatchedPredictor(op, w, batch=batch, scale=scale)(A_test)
