from .kernels import (KernelConfig, GramOperator, ExactGramOperator,
                      LowRankGramOperator, StreamingGramOperator,
                      gram_slab, gram_full,
                      apply_epilogue, kernel_diag, kmv_apply,
                      kmv_slab_free)
from .loop import (DIVERGED_METRIC, DIVERGED_NONE, DIVERGED_NONFINITE,
                   GuardSpec, LoopResult, NO_TOL, pad_rounds, run_rounds,
                   run_rounds_fleet)
from .dcd import (SVMConfig, dcd_ksvm, coordinate_schedule, L1, L2,
                  make_dcd_round_fn)
from .sstep_dcd import sstep_dcd_ksvm, make_sstep_dcd_round_fn
from .bdcd import KRRConfig, bdcd_krr, block_schedule, make_bdcd_round_fn
from .sstep_bdcd import sstep_bdcd_krr, make_sstep_bdcd_round_fn
from .objectives import (ksvm_duality_gap, ksvm_duality_gap_lowrank,
                         ksvm_dual_objective, ksvm_gap_from_Qa,
                         ksvm_primal_objective, krr_closed_form,
                         krr_dual_objective, krr_rel_residual,
                         krr_rel_residual_value,
                         relative_solution_error, ksvm_predict, krr_predict)
from .nystrom import (NystromMap, choose_landmarks, fit_nystrom,
                      kmeans_landmarks, lowrank_operator,
                      nystrom_kernel_error, nystrom_krr_setup)
from .predict import BatchedPredictor, batched_predict, compact_support
