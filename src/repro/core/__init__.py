from .kernels import (KernelConfig, GramOperator, gram_slab, gram_full,
                      apply_epilogue, kernel_diag, kmv_slab_free)
from .dcd import SVMConfig, dcd_ksvm, coordinate_schedule, L1, L2
from .sstep_dcd import sstep_dcd_ksvm
from .bdcd import KRRConfig, bdcd_krr, block_schedule
from .sstep_bdcd import sstep_bdcd_krr
from .objectives import (ksvm_duality_gap, ksvm_dual_objective,
                         ksvm_primal_objective, krr_closed_form,
                         krr_dual_objective, relative_solution_error,
                         ksvm_predict, krr_predict)
