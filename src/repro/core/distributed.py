"""Distributed DCD/BDCD solvers under ``shard_map`` — the paper's MPI
implementation (Section 5.2) mapped to JAX mesh collectives.

Layouts
-------
1D (paper):   A is partitioned in 1D-column (feature) layout over the
              ``model`` axis — each device holds ``A[:, n/P]``.  The
              per-iteration kernel-slab reduction ``sum_p A_p B_p^T`` is an
              ``MPI_Allreduce`` in the paper and a ``lax.psum`` here.
              alpha, y and all solver state are replicated, exactly as
              each MPI rank "redundantly stores y and alpha" (Thm 1 proof).

2D (beyond paper): additionally shards samples over the ``data`` axis.
              The model-axis psum then reduces only ``m/P_data x sb``
              words per device, cutting the psum bandwidth term of
              Theorem 2 by P_data at the cost of two extra small
              collectives per round (sampled-row gather + fused
              cross-term gather).  See EXPERIMENTS.md §Perf.

Classical vs s-step: the classical solvers communicate every iteration
(H collectives); the s-step solvers communicate once per outer round
(H/s collectives), which is the paper's entire contribution.

Slab-free (EXPERIMENTS.md §Perf): the solvers consume the kernel slab
through a ``GramOperator``, so these paths keep the psum-before-epilogue
ordering required by nonlinear kernels (Thm 1/2 proofs) but drop the
post-epilogue slab round-trip — the epilogue and the ``U^T alpha``
contraction happen immediately on the psum result, the sampled cross
block is sliced out of the SAME psum (no extra payload), and for the
linear kernel the m x sb reduction disappears entirely (only the
(sb, sb+1) contracted quantities are psummed).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .bdcd import KRRConfig
from .dcd import SVMConfig
from .kernels import LINEAR, RBF, KernelConfig, apply_epilogue
from .loop import pad_rounds, run_rounds
from .sstep_bdcd import sstep_bdcd_inner, sstep_bdcd_krr
from .sstep_dcd import sstep_dcd_inner, sstep_dcd_ksvm


def make_allreduce_gram(axis_name: str, row_sqnorms=None):
    """Feature-partitioned MATERIALIZED gram slab (legacy / parity oracle):
    partial GEMM on local columns, one all-reduce (== the paper's
    MPI_Allreduce), then the nonlinear epilogue applied redundantly on
    every rank (as in Thm 1/2 proofs).  The slab-free operator below is
    the default; this path survives as ``slab_free=False``.

    §Perf-paper optimization: for RBF, ``row_sqnorms`` (the psummed
    ||a_i||^2, computed ONCE per solve — they are loop-invariant) removes
    the per-round (m,) norm psum, and the remaining (s*b,) B-norm vector
    is FUSED into the slab all-reduce (concat one extra row), so every
    round issues exactly ONE collective — the paper's ideal schedule.
    """

    def gram(A_loc, B_loc, cfg: KernelConfig):
        dots_part = A_loc @ B_loc.T                       # (m, sb) partial
        if cfg.name != RBF:
            return apply_epilogue(jax.lax.psum(dots_part, axis_name), cfg)
        cs_part = jnp.sum(B_loc * B_loc, axis=1)[None, :]  # (1, sb)
        if row_sqnorms is not None:
            packed = jax.lax.psum(
                jnp.concatenate([dots_part, cs_part], axis=0), axis_name)
            return apply_epilogue(packed[:-1], cfg, row_sqnorms,
                                  packed[-1])
        rs = jax.lax.psum(jnp.sum(A_loc * A_loc, axis=1), axis_name)
        cs = jax.lax.psum(cs_part[0], axis_name)
        return apply_epilogue(jax.lax.psum(dots_part, axis_name), cfg,
                              rs, cs)

    return gram


class AllreduceGramOperator:
    """Slab-free ``GramOperator`` for the paper's 1D-column layout.

    ``round_data`` issues exactly ONE psum per outer round (the paper's
    ideal schedule), after which the slab exists only transiently on-rank:

      linear:    the contraction commutes with the feature reduction, so
                 only ``B (A^T x)`` and ``B B^T`` — (sb, sb+1) words — are
                 psummed; the m x sb slab is NEVER formed, not even
                 pre-epilogue.
      poly/rbf:  the pre-epilogue m x sb dot block must be psummed first
                 (Thm 1/2 ordering); the sampled sb x sb cross-dots are
                 sliced straight out of that psum result (dots[idx] ==
                 the sampled rows' gram, bit-identical), the epilogue
                 runs redundantly on every rank, and ``U^T x`` is
                 contracted immediately — no post-epilogue slab
                 round-trip, no second collective, no extra payload.

    ``row_sqnorms`` (psummed ||a_i||^2, loop-invariant) must be supplied
    for RBF; sampled-column norms are read from it by index instead of a
    separate psum.

    Implements only ``round_data`` — the solvers' entire per-round
    contract; the richer matvec/cross_block/diag surface lives on the
    serial ``GramOperator``.
    """

    def __init__(self, axis_name: str, A_loc, cfg: KernelConfig,
                 row_sqnorms=None):
        if cfg.name == RBF and row_sqnorms is None:
            raise ValueError("RBF AllreduceGramOperator needs the psummed "
                             "row_sqnorms (loop-invariant, compute once)")
        self.axis_name = axis_name
        self.A_loc = A_loc
        self.cfg = cfg
        self.rs = row_sqnorms

    def round_data(self, idx, x):
        ax, cfg = self.axis_name, self.cfg
        A_loc = self.A_loc
        B_loc = A_loc[idx]
        r = idx.shape[0]
        if cfg.name == LINEAR:
            cross_part = B_loc @ B_loc.T                  # (r, r) partial
            mv_part = B_loc @ (A_loc.T @ x)               # (r,)  partial
            packed = jax.lax.psum(
                jnp.concatenate([cross_part, mv_part[:, None]], axis=1), ax)
            return packed[:, :r], packed[:, r]
        dots = jax.lax.psum(A_loc @ B_loc.T, ax)          # (m, r)
        cross = dots[idx]                                 # == psummed B B^T
        if cfg.name == RBF:
            cs = self.rs[idx]
            U = apply_epilogue(dots, cfg, self.rs, cs)    # transient
            G = apply_epilogue(cross, cfg, cs, cs)
        else:
            U = apply_epilogue(dots, cfg)
            G = apply_epilogue(cross, cfg)
        return G, U.T @ x


def _psummed_row_sqnorms(A_loc, cfg: KernelConfig, axis_name: str):
    """Loop-invariant psummed ||a_i||^2 (RBF only; None otherwise)."""
    if cfg.name != RBF:
        return None
    return jax.lax.psum(jnp.sum(A_loc * A_loc, axis=1), axis_name)


# --------------------------------------------------------------------------
# 1D (paper) layout solvers.  The serial solver bodies are reused verbatim:
# only the gram operator changes, which is precisely the paper's claim that
# the s-step schedule is independent of the partitioning.
# --------------------------------------------------------------------------

def dist_sstep_dcd_ksvm(mesh: Mesh, A, y, alpha0, schedule,
                        cfg: SVMConfig, s: int, axis_name: str = "model",
                        slab_free: bool = True, op_factory=None):
    """s-step DCD for K-SVM with A in 1D-column layout over ``axis_name``.

    A may be passed as a global array; it is sharded on features by the
    in_spec.  Returns the replicated final alpha.  ``slab_free=False``
    selects the legacy materialized-slab all-reduce path (parity oracle).

    ``op_factory(Atil_loc, kernel_cfg)`` injects a custom per-rank
    ``GramOperator`` built from the LOCAL (already diag(y)-scaled) column
    shard — the representation seam of DESIGN.md §9.  For the low-rank
    representation no custom factory is needed: pass ``A = Phi`` with a
    linear kernel config and the default operator reduces only the
    contracted ``(sb, sb+1)``-word round quantities (Phi's l columns are
    what gets sharded, not the raw features).
    """
    spec_A = P(None, axis_name)

    @partial(shard_map, mesh=mesh,
             in_specs=(spec_A, P(), P(), P()), out_specs=P(),
             check_vma=False)
    def run(A_loc, y_r, a0_r, sched_r):
        Atil_loc = y_r[:, None] * A_loc
        rs = _psummed_row_sqnorms(Atil_loc, cfg.kernel, axis_name)
        if op_factory is not None:
            kw = {"op_factory": op_factory}
        elif slab_free:
            def default_factory(Atil, kcfg):
                return AllreduceGramOperator(axis_name, Atil, kcfg, rs)
            kw = {"op_factory": default_factory}
        else:
            kw = {"gram_fn": make_allreduce_gram(axis_name, row_sqnorms=rs)}
        # pass A_loc (sstep solver re-applies diag(y), idempotent w/ ones)
        out, _ = sstep_dcd_ksvm(A_loc, y_r, a0_r, sched_r, cfg, s, **kw)
        return out

    return run(A, y, alpha0, schedule)


def dist_dcd_ksvm(mesh: Mesh, A, y, alpha0, schedule,
                  cfg: SVMConfig, axis_name: str = "model",
                  slab_free: bool = True):
    """Classical DCD baseline (communicates every iteration): implemented
    as s-step with s=1, which degenerates to Algorithm 1's schedule —
    one m-word psum per iteration."""
    return dist_sstep_dcd_ksvm(mesh, A, y, alpha0, schedule, cfg, s=1,
                               axis_name=axis_name, slab_free=slab_free)


def dist_sstep_bdcd_krr(mesh: Mesh, A, y, alpha0, schedule,
                        cfg: KRRConfig, s: int, axis_name: str = "model",
                        slab_free: bool = True, op_factory=None):
    """s-step BDCD for K-RR, 1D-column layout.  ``op_factory(A_loc,
    kernel_cfg)`` injects a custom per-rank operator (see
    ``dist_sstep_dcd_ksvm``); low-rank runs pass ``A = Phi`` + linear
    config and keep the default."""
    @partial(shard_map, mesh=mesh,
             in_specs=(P(None, axis_name), P(), P(), P()), out_specs=P(),
             check_vma=False)
    def run(A_loc, y_r, a0_r, sched_r):
        rs = _psummed_row_sqnorms(A_loc, cfg.kernel, axis_name)
        if op_factory is not None:
            kw = {"op_factory": op_factory}
        elif slab_free:
            def default_factory(A_, kcfg):
                return AllreduceGramOperator(axis_name, A_, kcfg, rs)
            kw = {"op_factory": default_factory}
        else:
            kw = {"gram_fn": make_allreduce_gram(axis_name, row_sqnorms=rs)}
        out, _ = sstep_bdcd_krr(A_loc, y_r, a0_r, sched_r, cfg, s, **kw)
        return out

    return run(A, y, alpha0, schedule)


def dist_bdcd_krr(mesh: Mesh, A, y, alpha0, schedule,
                  cfg: KRRConfig, axis_name: str = "model",
                  slab_free: bool = True):
    """Classical BDCD baseline — one (m x b)-word psum per iteration."""
    return dist_sstep_bdcd_krr(mesh, A, y, alpha0, schedule, cfg, s=1,
                               axis_name=axis_name, slab_free=slab_free)


# --------------------------------------------------------------------------
# 2D (samples x features) s-step solvers — beyond-paper optimization.
# Both drive the shared round protocol (core/loop.py) with a shard_map
# round_fn; the redundant inner phases are the SAME functions the serial
# solvers use (sstep_dcd_inner / sstep_bdcd_inner).
# --------------------------------------------------------------------------

def _gather_rows_onehot(flat, row0, m_loc, dtype):
    """(sb, m_loc) one-hot selector of the globally-indexed sampled rows
    owned by this data-rank; a psum of ``onehot @ X_loc`` IS the gather."""
    return (flat[:, None] == (row0 + jnp.arange(m_loc))[None, :]).astype(
        dtype)


class Sharded2dGramOperator:
    """Per-rank slab-free gram operator for the 2D (samples x features)
    layout — the 2D twin of ``AllreduceGramOperator`` in the operator
    hierarchy (DESIGN.md §9).  Both 2D solver bodies consume ONLY
    ``round_parts``, so a different representation (e.g. a row-sharded
    low-rank factor: pass ``A = Phi`` with a linear kernel config, Phi's
    l columns sharded over ``model``) drops in without touching the
    solver math.

    ``round_parts(flat)`` executes collectives (1)+(2) of the 2D round:
    gather the sampled rows over ``data``, then one ``model`` psum
    reducing the row-local dot block with the sb x sb cross-dots riding
    the same collective.  Returns (onehot, Q_loc, Gblk) — the one-hot
    row selector, the epilogued row-local slab tile, and the replicated
    sampled cross block.
    """

    def __init__(self, A_loc, kernel: KernelConfig, *, data_axis: str,
                 model_axis: str, row0, m_loc: int, row_sqnorms=None):
        self.A_loc = A_loc
        self.kernel = kernel
        self.data_axis = data_axis
        self.model_axis = model_axis
        self.row0 = row0
        self.m_loc = m_loc
        self.rs_loc = row_sqnorms

    def round_parts(self, flat):
        A_loc, kernel, m_loc = self.A_loc, self.kernel, self.m_loc
        onehot = _gather_rows_onehot(flat, self.row0, m_loc, A_loc.dtype)
        B_loc = jax.lax.psum(onehot @ A_loc, self.data_axis)  # (sb, n_loc)
        sb = flat.shape[0]
        packed = jax.lax.psum(jnp.concatenate(
            [A_loc @ B_loc.T,                             # (m_loc, sb)
             B_loc @ B_loc.T], axis=0), self.model_axis)
        dots, cross = packed[:m_loc], packed[m_loc:]
        assert cross.shape[0] == sb
        if kernel.name == RBF:
            cs = jnp.diagonal(cross)                      # ||b_j||^2 free
            Q_loc = apply_epilogue(dots, kernel, self.rs_loc, cs)
            Gblk = apply_epilogue(cross, kernel, cs, cs)
        else:
            Q_loc = apply_epilogue(dots, kernel)
            Gblk = apply_epilogue(cross, kernel)
        return onehot, Q_loc, Gblk


def dist_sstep_bdcd_krr_2d(mesh: Mesh, A, y, alpha0, schedule,
                           cfg: KRRConfig, s: int,
                           data_axis: str = "data",
                           model_axis: str = "model",
                           op_factory=None):
    """2D-partitioned s-step BDCD: A[m/Pd, n/Pm] per device, alpha sharded
    over ``data``.  Slab-free: the row-local slab tile is epilogued and
    contracted in one shot; only contracted quantities cross the wires.

    Per outer round the collective schedule is:
      1. psum_data  : gather the s*b sampled rows (s*b x n/Pm words)
      2. psum_model : reduce the row-local dot block PLUS the s*b x s*b
                      cross-dots riding the same collective
                      ((m/Pd + s*b) x s*b words)
      3. psum_data  : fuse {Q^T alpha, alpha at idx, y at idx} into ONE
                      collective (s*b x 3 words — the sb x sb cross block
                      no longer crosses the data axis at all: every rank
                      rebuilds it redundantly from the replicated rows)
    vs. the 1D layout's single psum of (m x s*b).  For m >> s*b*Pd the
    bandwidth term drops by ~Pd while latency grows 3x — a win exactly in
    the paper's bandwidth-bound regime (news20, Fig. 6-7).  RBF row norms
    are loop-invariant and hoisted out of the round loop entirely.

    Ragged H (H % s != 0) runs a masked final short round, exactly as the
    serial solvers do (loop.pad_rounds).  ``op_factory`` overrides the
    per-rank ``Sharded2dGramOperator`` (same constructor signature) —
    the representation seam of DESIGN.md §9.
    """
    m = A.shape[0]
    pd = mesh.shape[data_axis]
    if m % pd != 0:
        raise ValueError(f"m={m} must divide data axis {pd}")
    m_loc = m // pd
    inv_lam = 1.0 / cfg.lam
    b = schedule.shape[1]

    @partial(shard_map, mesh=mesh,
             in_specs=(P(data_axis, model_axis), P(data_axis), P(data_axis),
                       P()),
             out_specs=P(data_axis), check_vma=False)
    def run(A_loc, y_loc, a0_loc, sched):
        my_d = jax.lax.axis_index(data_axis)
        row0 = my_d * m_loc
        # loop-invariant RBF row norms for the locally-owned samples
        rs_loc = _psummed_row_sqnorms(A_loc, cfg.kernel, model_axis)
        op = (op_factory or Sharded2dGramOperator)(
            A_loc, cfg.kernel, data_axis=data_axis, model_axis=model_axis,
            row0=row0, m_loc=m_loc, row_sqnorms=rs_loc)

        def round_fn(alpha_loc, xs):                  # idx: (s, b) global
            idx, valid = xs
            flat = idx.reshape(s * b)
            onehot, Q_loc, Gblk = op.round_parts(flat)
            # (3) contract the slab tile IMMEDIATELY (it never leaves this
            #     scope) and fuse every data-axis cross term into ONE psum.
            packed = jnp.concatenate([
                (Q_loc.T @ alpha_loc)[:, None],        # (sb, 1)
                (onehot @ alpha_loc)[:, None],         # (sb, 1)
                (onehot @ y_loc)[:, None],             # (sb, 1)
            ], axis=1)
            packed = jax.lax.psum(packed, data_axis)
            QTalpha = packed[:, 0]
            alpha_at = packed[:, 1].reshape(s, b)
            y_at = packed[:, 2].reshape(s, b)

            # redundant inner loop — shared with the serial solver
            dalpha = sstep_bdcd_inner(Gblk, QTalpha, alpha_at, y_at, flat,
                                      m, inv_lam, s, b, valid)
            # locally-owned scatter-add of the deferred update
            return alpha_loc + onehot.T @ dalpha.reshape(s * b)

        xs = pad_rounds(sched, s)
        return run_rounds(round_fn, a0_loc, xs).state

    return run(A, y, alpha0, schedule)


def dist_sstep_dcd_ksvm_2d(mesh: Mesh, A, y, alpha0, schedule,
                           cfg: SVMConfig, s: int,
                           data_axis: str = "data",
                           model_axis: str = "model",
                           op_factory=None):
    """2D-partitioned s-step DCD for K-SVM: Atil[m/Pd, n/Pm] per device,
    alpha and y sharded over ``data``.  Same collective schedule as the
    2D BDCD solver (rows gather -> fused model psum -> fused data psum of
    the contracted round quantities), with the scalar-coordinate inner
    recurrence shared with the serial solver (``sstep_dcd_inner``)."""
    m = A.shape[0]
    pd = mesh.shape[data_axis]
    if m % pd != 0:
        raise ValueError(f"m={m} must divide data axis {pd}")
    m_loc = m // pd
    nu, omega = cfg.nu, cfg.omega

    @partial(shard_map, mesh=mesh,
             in_specs=(P(data_axis, model_axis), P(data_axis), P(data_axis),
                       P()),
             out_specs=P(data_axis), check_vma=False)
    def run(A_loc, y_loc, a0_loc, sched):
        my_d = jax.lax.axis_index(data_axis)
        row0 = my_d * m_loc
        Atil_loc = y_loc[:, None] * A_loc
        rs_loc = _psummed_row_sqnorms(Atil_loc, cfg.kernel, model_axis)
        op = (op_factory or Sharded2dGramOperator)(
            Atil_loc, cfg.kernel, data_axis=data_axis,
            model_axis=model_axis, row0=row0, m_loc=m_loc,
            row_sqnorms=rs_loc)

        def round_fn(alpha_loc, xs):                  # idx: (s,) global
            idx, valid = xs
            onehot, U_loc, G0 = op.round_parts(idx)
            packed = jax.lax.psum(jnp.concatenate([
                (U_loc.T @ alpha_loc)[:, None],        # (s, 1)
                (onehot @ alpha_loc)[:, None],         # (s, 1)
            ], axis=1), data_axis)
            u_dot_alpha, alpha_at = packed[:, 0], packed[:, 1]

            thetas = sstep_dcd_inner(G0, u_dot_alpha, alpha_at, idx,
                                     nu, omega, s, valid)
            return alpha_loc + onehot.T @ thetas

        xs = pad_rounds(sched, s)
        return run_rounds(round_fn, a0_loc, xs).state

    return run(A, y, alpha0, schedule)


def shard_dataset_1d(mesh: Mesh, A, axis_name: str = "model"):
    """Place a host array in the paper's 1D-column layout on the mesh."""
    return jax.device_put(A, NamedSharding(mesh, P(None, axis_name)))
