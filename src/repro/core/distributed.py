"""Distributed DCD/BDCD solvers under ``shard_map`` — the paper's MPI
implementation (Section 5.2) mapped to JAX mesh collectives.

Layouts
-------
1D (paper):   A is partitioned in 1D-column (feature) layout over the
              ``model`` axis — each device holds ``A[:, n/P]``.  The
              per-iteration kernel-slab reduction ``sum_p A_p B_p^T`` is an
              ``MPI_Allreduce`` in the paper and a ``lax.psum`` here.
              alpha, y and all solver state are replicated, exactly as
              each MPI rank "redundantly stores y and alpha" (Thm 1 proof).

2D (beyond paper): additionally shards samples over the ``data`` axis.
              The m x sb slab then lives row-sharded (each device reduces
              only ``m/P_data x sb`` words over the model axis), cutting
              the psum bandwidth term of Theorem 2 by P_data at the cost
              of two extra small collectives per round (sampled-row gather
              + cross-term gather).  See EXPERIMENTS.md §Perf.

Classical vs s-step: the classical solvers communicate every iteration
(H collectives); the s-step solvers communicate once per outer round
(H/s collectives), which is the paper's entire contribution.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .bdcd import KRRConfig
from .dcd import SVMConfig
from .kernels import RBF, KernelConfig, apply_epilogue
from .sstep_bdcd import sstep_bdcd_krr
from .sstep_dcd import sstep_dcd_ksvm


def make_allreduce_gram(axis_name: str, row_sqnorms=None):
    """Feature-partitioned gram slab: partial GEMM on local columns, then
    one all-reduce (== the paper's MPI_Allreduce), then the nonlinear
    epilogue applied redundantly on every rank (as in Thm 1/2 proofs).

    §Perf-paper optimization: for RBF, ``row_sqnorms`` (the psummed
    ||a_i||^2, computed ONCE per solve — they are loop-invariant) removes
    the per-round (m,) norm psum, and the remaining (s*b,) B-norm vector
    is FUSED into the slab all-reduce (concat one extra row), so every
    round issues exactly ONE collective — the paper's ideal schedule.
    """

    def gram(A_loc, B_loc, cfg: KernelConfig):
        dots_part = A_loc @ B_loc.T                       # (m, sb) partial
        if cfg.name != RBF:
            return apply_epilogue(jax.lax.psum(dots_part, axis_name), cfg)
        cs_part = jnp.sum(B_loc * B_loc, axis=1)[None, :]  # (1, sb)
        if row_sqnorms is not None:
            packed = jax.lax.psum(
                jnp.concatenate([dots_part, cs_part], axis=0), axis_name)
            return apply_epilogue(packed[:-1], cfg, row_sqnorms,
                                  packed[-1])
        rs = jax.lax.psum(jnp.sum(A_loc * A_loc, axis=1), axis_name)
        cs = jax.lax.psum(cs_part[0], axis_name)
        return apply_epilogue(jax.lax.psum(dots_part, axis_name), cfg,
                              rs, cs)

    return gram


# --------------------------------------------------------------------------
# 1D (paper) layout solvers.  The serial solver bodies are reused verbatim:
# only the gram function changes, which is precisely the paper's claim that
# the s-step schedule is independent of the partitioning.
# --------------------------------------------------------------------------

def dist_sstep_dcd_ksvm(mesh: Mesh, A, y, alpha0, schedule,
                        cfg: SVMConfig, s: int, axis_name: str = "model"):
    """s-step DCD for K-SVM with A in 1D-column layout over ``axis_name``.

    A may be passed as a global array; it is sharded on features by the
    in_spec.  Returns the replicated final alpha.
    """
    spec_A = P(None, axis_name)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(spec_A, P(), P(), P()), out_specs=P(),
             check_vma=False)
    def run(A_loc, y_r, a0_r, sched_r):
        Atil_loc = y_r[:, None] * A_loc
        rs = (jax.lax.psum(jnp.sum(Atil_loc * Atil_loc, axis=1), axis_name)
              if cfg.kernel.name == RBF else None)
        gram = make_allreduce_gram(axis_name, row_sqnorms=rs)
        # pass A_loc (sstep solver re-applies diag(y), idempotent w/ ones)
        out, _ = sstep_dcd_ksvm(A_loc, y_r, a0_r, sched_r, cfg, s,
                                gram_fn=gram)
        return out

    return run(A, y, alpha0, schedule)


def dist_dcd_ksvm(mesh: Mesh, A, y, alpha0, schedule,
                  cfg: SVMConfig, axis_name: str = "model"):
    """Classical DCD baseline (communicates every iteration): implemented
    as s-step with s=1, which degenerates to Algorithm 1's schedule —
    one m-word psum per iteration."""
    return dist_sstep_dcd_ksvm(mesh, A, y, alpha0, schedule, cfg, s=1,
                               axis_name=axis_name)


def dist_sstep_bdcd_krr(mesh: Mesh, A, y, alpha0, schedule,
                        cfg: KRRConfig, s: int, axis_name: str = "model"):
    """s-step BDCD for K-RR, 1D-column layout."""
    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P(None, axis_name), P(), P(), P()), out_specs=P(),
             check_vma=False)
    def run(A_loc, y_r, a0_r, sched_r):
        rs = (jax.lax.psum(jnp.sum(A_loc * A_loc, axis=1), axis_name)
              if cfg.kernel.name == RBF else None)
        gram = make_allreduce_gram(axis_name, row_sqnorms=rs)
        out, _ = sstep_bdcd_krr(A_loc, y_r, a0_r, sched_r, cfg, s,
                                gram_fn=gram)
        return out

    return run(A, y, alpha0, schedule)


def dist_bdcd_krr(mesh: Mesh, A, y, alpha0, schedule,
                  cfg: KRRConfig, axis_name: str = "model"):
    """Classical BDCD baseline — one (m x b)-word psum per iteration."""
    return dist_sstep_bdcd_krr(mesh, A, y, alpha0, schedule, cfg, s=1,
                               axis_name=axis_name)


# --------------------------------------------------------------------------
# 2D (samples x features) s-step BDCD — beyond-paper optimization.
# --------------------------------------------------------------------------

def dist_sstep_bdcd_krr_2d(mesh: Mesh, A, y, alpha0, schedule,
                           cfg: KRRConfig, s: int,
                           data_axis: str = "data",
                           model_axis: str = "model"):
    """2D-partitioned s-step BDCD: A[m/Pd, n/Pm] per device, alpha sharded
    over ``data``.

    Per outer round the collective schedule is:
      1. psum_data  : gather the s*b sampled rows (s*b x n/Pm words)
      2. psum_model : reduce the row-local slab  (m/Pd x s*b words)
      3. psum_data  : fuse {cross-term block Gblk, Q^T alpha, alpha/y at
                      sampled idx} into ONE collective (s*b x (s*b+3))
    vs. the 1D layout's single psum of (m x s*b).  For m >> s*b*Pd the
    bandwidth term drops by ~Pd while latency grows 3x — a win exactly in
    the paper's bandwidth-bound regime (news20, Fig. 6-7).
    """
    m = A.shape[0]
    pd = mesh.shape[data_axis]
    if m % pd != 0:
        raise ValueError(f"m={m} must divide data axis {pd}")
    m_loc = m // pd
    H, b = schedule.shape
    if H % s != 0:
        raise ValueError("H % s != 0")
    inv_lam = 1.0 / cfg.lam
    rounds_shape = (H // s, s, b)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P(data_axis, model_axis), P(data_axis), P(data_axis),
                       P()),
             out_specs=P(data_axis), check_vma=False)
    def run(A_loc, y_loc, a0_loc, sched):
        my_d = jax.lax.axis_index(data_axis)
        row0 = my_d * m_loc
        rounds = sched.reshape(rounds_shape)

        def outer(alpha_loc, idx):                    # idx: (s, b) global
            flat = idx.reshape(s * b)
            # (1) gather sampled rows across the data axis (one-hot matmul
            #     keeps it a psum — no gather collective needed).
            onehot = (flat[:, None] == (row0 + jnp.arange(m_loc))[None, :])
            onehot = onehot.astype(A_loc.dtype)       # (sb, m_loc)
            B_loc = jax.lax.psum(onehot @ A_loc, data_axis)   # (sb, n_loc)
            # (2) row-local slab, reduced over the model axis only.
            dots = jax.lax.psum(A_loc @ B_loc.T, model_axis)  # (m_loc, sb)
            if cfg.kernel.name == RBF:
                rs = jax.lax.psum(jnp.sum(A_loc * A_loc, 1), model_axis)
                cs = jax.lax.psum(jnp.sum(B_loc * B_loc, 1), model_axis)
                Q_loc = apply_epilogue(dots, cfg.kernel, rs, cs)
            else:
                Q_loc = apply_epilogue(dots, cfg.kernel)
            # (3) one fused data-axis psum for every cross term the inner
            #     loop needs: Gblk (sb x sb), Q^T alpha (sb), alpha@idx,
            #     y@idx (sb each).
            packed = jnp.concatenate([
                onehot @ Q_loc,                        # (sb, sb) partial Gblk
                (Q_loc.T @ alpha_loc)[:, None],        # (sb, 1)
                (onehot @ alpha_loc)[:, None],         # (sb, 1)
                (onehot @ y_loc)[:, None],             # (sb, 1)
            ], axis=1)
            packed = jax.lax.psum(packed, data_axis)
            Gblk = packed[:, :s * b]
            QTalpha = packed[:, s * b]
            alpha_at = packed[:, s * b + 1].reshape(s, b)
            y_at = packed[:, s * b + 2].reshape(s, b)

            collide = (flat[:, None] == flat[None, :]).astype(A_loc.dtype)
            collide4 = collide.reshape(s, b, s, b)
            Gblk4 = Gblk.reshape(s, b, s, b)
            eye_b = jnp.eye(b, dtype=A_loc.dtype)

            # redundant inner loop — identical math to sstep_bdcd_krr
            def inner(j, dalpha):
                tmask = (jnp.arange(s) < j).astype(A_loc.dtype)
                prior = dalpha * tmask[:, None]
                vv = jnp.einsum("tq,tqp->p", prior, collide4[:, :, j, :])
                uv = jnp.einsum("tq,tqp->p", prior, Gblk4[:, :, j, :])
                Uj_idx = jax.lax.dynamic_slice_in_dim(
                    Gblk4[:, :, j, :].reshape(s * b, b), j * b, b, axis=0)
                G = inv_lam * Uj_idx + m * eye_b
                rhs = (y_at[j] - m * alpha_at[j] - m * vv
                       - inv_lam * jax.lax.dynamic_slice_in_dim(
                           QTalpha, j * b, b)
                       - inv_lam * uv)
                return dalpha.at[j].set(jnp.linalg.solve(G, rhs))

            dalpha = jax.lax.fori_loop(0, s, inner,
                                       jnp.zeros((s, b), A_loc.dtype))
            # locally-owned scatter-add of the deferred update
            upd = onehot.T @ dalpha.reshape(s * b)      # (m_loc,)
            return alpha_loc + upd, 0.0

        out, _ = jax.lax.scan(outer, a0_loc, rounds)
        return out

    return run(A, y, alpha0, schedule)


def shard_dataset_1d(mesh: Mesh, A, axis_name: str = "model"):
    """Place a host array in the paper's 1D-column layout on the mesh."""
    return jax.device_put(A, NamedSharding(mesh, P(None, axis_name)))
