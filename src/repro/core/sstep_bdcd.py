"""s-Step Block Dual Coordinate Descent (paper Algorithm 4) for K-RR.

One outer round computes the m x (s*b) kernel slab

    Q_k = K(A, Omega_k^T A),   Omega_k = [V_{sk+1} ... V_{sk+s}]

with a single gram GEMM + single all-reduce, then performs ``s`` exact b x b
block solves locally.  The deferred alpha update is repaired with the
correction sums of paper eq. (3):

    dalpha_{sk+j} = G^{-1}( V_j^T y - m V_j^T alpha_sk
                            - m     sum_{t<j} V_j^T V_t dalpha_t
                            - 1/lam U_j^T alpha_sk
                            - 1/lam sum_{t<j} U_j^T V_t dalpha_t )

All correction data lives in the (sb x sb) matrix ``Q_k[idx_flat, :]`` and
the index-collision mask — O((sb)^2) redundant flops, zero communication.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .bdcd import KRRConfig
from .kernels import gram_slab


@partial(jax.jit, static_argnames=("cfg", "s", "record_rounds", "gram_fn"))
def sstep_bdcd_krr(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
                   schedule: jnp.ndarray, cfg: KRRConfig, s: int,
                   record_rounds: bool = False,
                   gram_fn: Optional[Callable] = None,
                   ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 4.  ``schedule`` is the (H, b) block schedule from
    ``bdcd.block_schedule``; H % s == 0 required."""
    H, b = schedule.shape
    if H % s != 0:
        raise ValueError(f"H={H} must be divisible by s={s}")
    gram = gram_fn or gram_slab

    m = A.shape[0]
    inv_lam = 1.0 / cfg.lam
    rounds = schedule.reshape(H // s, s, b)
    eye_b = jnp.eye(b, dtype=A.dtype)

    def outer(alpha, idx):                     # idx: (s, b)
        flat = idx.reshape(s * b)
        # --- communication phase ----------------------------------------
        Q = gram(A, A[flat], cfg.kernel)                  # (m, s*b)
        Gblk = Q[flat, :]                                 # (s*b, s*b)
        QTalpha = Q.T @ alpha                             # (s*b,)
        y_at = y[idx]                                     # (s, b)
        alpha_at = alpha[idx]                             # (s, b)
        # collide[t, q, j, p] = 1 iff idx[t, q] == idx[j, p]
        collide = (flat[:, None] == flat[None, :]).astype(alpha.dtype)
        collide = collide.reshape(s, b, s, b)
        Gblk4 = Gblk.reshape(s, b, s, b)                  # [t, q, j, p]

        # --- redundant local phase: s block solves -----------------------
        def inner(j, dalpha):                             # dalpha: (s, b)
            tmask = (jnp.arange(s) < j).astype(alpha.dtype)
            prior = dalpha * tmask[:, None]               # zero for t >= j
            # m * sum_t V_j^T V_t dalpha_t    -> (b,)
            vv = jnp.einsum("tq,tqp->p", prior, collide[:, :, j, :])
            # 1/lam * sum_t U_j^T V_t dalpha_t = Q[idx_t, jb:jb+b]^T dalpha_t
            uv = jnp.einsum("tq,tqp->p", prior, Gblk4[:, :, j, :])
            Uj_idx = jax.lax.dynamic_slice_in_dim(
                Gblk4[:, :, j, :].reshape(s * b, b), j * b, b, axis=0)
            G = inv_lam * Uj_idx + m * eye_b
            rhs = (y_at[j] - m * alpha_at[j] - m * vv
                   - inv_lam * jax.lax.dynamic_slice_in_dim(QTalpha, j * b, b)
                   - inv_lam * uv)
            sol = jnp.linalg.solve(G, rhs)
            return dalpha.at[j].set(sol)

        dalpha = jax.lax.fori_loop(
            0, s, inner, jnp.zeros((s, b), alpha.dtype))
        alpha = alpha.at[flat].add(dalpha.reshape(s * b))
        return alpha, (alpha if record_rounds else 0.0)

    alpha_H, hist = jax.lax.scan(outer, alpha0, rounds)
    return (alpha_H, hist) if record_rounds else (alpha_H, None)
