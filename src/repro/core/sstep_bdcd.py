"""s-Step Block Dual Coordinate Descent (paper Algorithm 4) for K-RR.

One outer round gathers everything ``s`` exact b x b block solves need:

    Gblk    = K(A_Omega, A_Omega)  in R^{sb x sb}   (sampled cross block)
    Q^T alpha in R^{sb}                             (one fused KMV)

with a single collective, then repairs the deferred alpha update with the
correction sums of paper eq. (3):

    dalpha_{sk+j} = G^{-1}( V_j^T y - m V_j^T alpha_sk
                            - m     sum_{t<j} V_j^T V_t dalpha_t
                            - 1/lam U_j^T alpha_sk
                            - 1/lam sum_{t<j} U_j^T V_t dalpha_t )

All correction data lives in the (sb x sb) ``Gblk`` and the
index-collision mask — O((sb)^2) redundant flops, zero communication.

Slab-free by default (DESIGN.md §2): the ``m x sb`` slab ``Q_k`` is only
consumed through ``Q^T alpha`` and ``Gblk``, both exposed by
``GramOperator`` without materializing ``Q_k``.  ``gram_fn`` forces the
legacy materialized-slab path (parity oracle / paper-faithful baseline).

Ragged schedules are fine: ``H % s != 0`` runs a final short round via the
pad-and-mask round protocol (``loop.pad_rounds``); padded blocks produce
exactly-zero updates, so the iterates still match classical BDCD.

Prefer the ``repro.api`` facade (``KernelRidge`` with
``SolverOptions(method="sstep", s=..., b=...)``) over calling this
entrypoint directly — it adds tolerance-based stopping, layout dispatch,
and prediction on top of the same round protocol (DESIGN.md §8).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .bdcd import KRRConfig
from .kernels import ExactGramOperator
from .loop import pad_rounds, run_rounds


def sstep_bdcd_inner(Gblk, QTalpha, alpha_at, y_at, flat, m, inv_lam,
                     s, b, valid=None):
    """The redundant local phase shared by the serial and 2D-distributed
    solvers: ``s`` sequential b x b solves with eq. (3) corrections.

    Gblk: (sb, sb), QTalpha: (sb,), alpha_at/y_at: (s, b), flat: (sb,),
    valid: (s,) 1/0 mask for the ragged final round (padded blocks get
    dalpha = 0).  Returns dalpha: (s, b).
    """
    dtype = alpha_at.dtype
    ones = jnp.ones((s,), dtype) if valid is None else valid.astype(dtype)
    # collide[t, q, j, p] = 1 iff flat[t*b+q] == flat[j*b+p]
    collide = (flat[:, None] == flat[None, :]).astype(dtype)
    collide4 = collide.reshape(s, b, s, b)
    Gblk4 = Gblk.reshape(s, b, s, b)                  # [t, q, j, p]
    eye_b = jnp.eye(b, dtype=dtype)

    def inner(j, dalpha):                             # dalpha: (s, b)
        tmask = (jnp.arange(s) < j).astype(dtype)
        prior = dalpha * tmask[:, None]               # zero for t >= j
        # m * sum_t V_j^T V_t dalpha_t    -> (b,)
        vv = jnp.einsum("tq,tqp->p", prior, collide4[:, :, j, :])
        # 1/lam * sum_t U_j^T V_t dalpha_t = Q[idx_t, jb:jb+b]^T dalpha_t
        uv = jnp.einsum("tq,tqp->p", prior, Gblk4[:, :, j, :])
        Uj_idx = jax.lax.dynamic_slice_in_dim(
            Gblk4[:, :, j, :].reshape(s * b, b), j * b, b, axis=0)
        G = inv_lam * Uj_idx + m * eye_b
        rhs = (y_at[j] - m * alpha_at[j] - m * vv
               - inv_lam * jax.lax.dynamic_slice_in_dim(QTalpha, j * b, b)
               - inv_lam * uv)
        sol = jnp.linalg.solve(G, rhs)
        return dalpha.at[j].set(sol * ones[j])

    return jax.lax.fori_loop(0, s, inner, jnp.zeros((s, b), dtype))


def make_sstep_bdcd_round_fn(A: jnp.ndarray, y: jnp.ndarray, cfg: KRRConfig,
                             s: int,
                             gram_fn: Optional[Callable] = None,
                             op_factory: Optional[Callable] = None,
                             op=None, lam=None, guard: bool = False,
                             ) -> Callable:
    """``round_fn(alpha, (idx, valid)) -> alpha`` for ``loop.run_rounds``:
    one Algorithm-4 outer round; idx: (s, b), valid: (s,).  ``op``
    injects a prebuilt operator (exact or low-rank) over the training
    representation; the facade builds it once per fit (DESIGN.md §9).

    ``lam`` overrides ``cfg.lam`` with a TRACEABLE value — the batched
    cfg leaf of the fleet solver (repro.tune): vmapping the closure over
    per-member lam solves a whole regularization grid in lockstep on ONE
    shared operator (DESIGN.md §10).

    ``guard=True`` switches to the guarded-carry protocol
    (``round_fn((alpha, f), xs) -> (alpha, f)`` with ``f = K @ alpha``
    maintained by ``f += K[:, flat] @ dalpha`` — the same m x sb block
    the fused KMV already evaluates; ``Q^T alpha`` becomes the free
    gather ``f[flat]``, and drift correction splices an exactly
    recomputed ``f`` back in — residual replacement for the s-step
    recurrence; DESIGN.md §12).  Requires the operator path."""
    if sum(x is not None for x in (gram_fn, op_factory, op)) > 1:
        raise ValueError("pass at most one of gram_fn (materialized "
                         "slab), op_factory, or op (prebuilt operator)")
    if guard and gram_fn is not None:
        raise ValueError("guard=True requires the GramOperator path "
                         "(gram_fn= is the legacy materialized oracle)")
    m = A.shape[0]
    inv_lam = 1.0 / (cfg.lam if lam is None else lam)
    if op is None and gram_fn is None:
        op = (op_factory or ExactGramOperator)(A, cfg.kernel)

    if guard:
        def round_fn(carry, xs):
            alpha, f = carry                   # f = K @ alpha, (m,)
            idx, valid = xs                    # idx: (s, b)
            b = idx.shape[1]
            flat = idx.reshape(s * b)
            Gblk = op.cross_block(flat)        # (sb, sb)
            QTalpha = f[flat]                  # Q^T alpha, free gather
            dalpha = sstep_bdcd_inner(Gblk, QTalpha, alpha[idx], y[idx],
                                      flat, m, inv_lam, s, b, valid)
            d = dalpha.reshape(s * b)
            # duplicate coordinates in ``flat`` accumulate identically
            # in .at[].add and in the K[:, flat] @ d contraction
            return (alpha.at[flat].add(d), f + op.apply_at(flat, d))

        return round_fn

    def round_fn(alpha, xs):
        idx, valid = xs                        # idx: (s, b)
        b = idx.shape[1]
        flat = idx.reshape(s * b)
        # --- communication phase ----------------------------------------
        if gram_fn is not None:                # materialized m x sb slab
            Q = gram_fn(A, A[flat], cfg.kernel)
            Gblk = Q[flat, :]                  # (s*b, s*b)
            QTalpha = Q.T @ alpha              # (s*b,)
        else:                                  # slab-free operator path
            Gblk, QTalpha = op.round_data(flat, alpha)
        y_at = y[idx]                          # (s, b)
        alpha_at = alpha[idx]                  # (s, b)

        # --- redundant local phase: s block solves -----------------------
        dalpha = sstep_bdcd_inner(Gblk, QTalpha, alpha_at, y_at, flat,
                                  m, inv_lam, s, b, valid)
        return alpha.at[flat].add(dalpha.reshape(s * b))

    return round_fn


# repro: noqa[CHK-STATIC] gram_fn/op_factory are module-level functions
#   (or None) at every call site; passing a fresh closure retraces by
#   design — it is the documented parity-oracle escape hatch.
@partial(jax.jit, static_argnames=("cfg", "s", "record_rounds", "gram_fn",
                                   "op_factory"))
def sstep_bdcd_krr(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
                   schedule: jnp.ndarray, cfg: KRRConfig, s: int,
                   record_rounds: bool = False,
                   gram_fn: Optional[Callable] = None,
                   op_factory: Optional[Callable] = None,
                   op=None,
                   ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 4.  ``schedule`` is the (H, b) block schedule from
    ``bdcd.block_schedule``; ragged H (H % s != 0) runs a masked final
    short round.  ``op`` (a pytree — crosses the jit boundary as data)
    injects a prebuilt operator; see ``make_sstep_bdcd_round_fn``."""
    round_fn = make_sstep_bdcd_round_fn(A, y, cfg, s, gram_fn=gram_fn,
                                        op_factory=op_factory, op=op)
    xs = pad_rounds(schedule, s)
    res = run_rounds(round_fn, alpha0, xs, record_state=record_rounds)
    return res.state, (res.state_hist if record_rounds else None)
