"""s-Step Block Dual Coordinate Descent (paper Algorithm 4) for K-RR.

One outer round gathers everything ``s`` exact b x b block solves need:

    Gblk    = K(A_Omega, A_Omega)  in R^{sb x sb}   (sampled cross block)
    Q^T alpha in R^{sb}                             (one fused KMV)

with a single collective, then repairs the deferred alpha update with the
correction sums of paper eq. (3):

    dalpha_{sk+j} = G^{-1}( V_j^T y - m V_j^T alpha_sk
                            - m     sum_{t<j} V_j^T V_t dalpha_t
                            - 1/lam U_j^T alpha_sk
                            - 1/lam sum_{t<j} U_j^T V_t dalpha_t )

All correction data lives in the (sb x sb) ``Gblk`` and the
index-collision mask — O((sb)^2) redundant flops, zero communication.

Slab-free by default (DESIGN.md §2): the ``m x sb`` slab ``Q_k`` is only
consumed through ``Q^T alpha`` and ``Gblk``, both exposed by
``GramOperator`` without materializing ``Q_k``.  ``gram_fn`` forces the
legacy materialized-slab path (parity oracle / paper-faithful baseline).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .bdcd import KRRConfig
from .kernels import GramOperator


def sstep_bdcd_inner(Gblk, QTalpha, alpha_at, y_at, flat, m, inv_lam,
                     s, b):
    """The redundant local phase shared by the serial and 2D-distributed
    solvers: ``s`` sequential b x b solves with eq. (3) corrections.

    Gblk: (sb, sb), QTalpha: (sb,), alpha_at/y_at: (s, b), flat: (sb,).
    Returns dalpha: (s, b).
    """
    dtype = alpha_at.dtype
    # collide[t, q, j, p] = 1 iff flat[t*b+q] == flat[j*b+p]
    collide = (flat[:, None] == flat[None, :]).astype(dtype)
    collide4 = collide.reshape(s, b, s, b)
    Gblk4 = Gblk.reshape(s, b, s, b)                  # [t, q, j, p]
    eye_b = jnp.eye(b, dtype=dtype)

    def inner(j, dalpha):                             # dalpha: (s, b)
        tmask = (jnp.arange(s) < j).astype(dtype)
        prior = dalpha * tmask[:, None]               # zero for t >= j
        # m * sum_t V_j^T V_t dalpha_t    -> (b,)
        vv = jnp.einsum("tq,tqp->p", prior, collide4[:, :, j, :])
        # 1/lam * sum_t U_j^T V_t dalpha_t = Q[idx_t, jb:jb+b]^T dalpha_t
        uv = jnp.einsum("tq,tqp->p", prior, Gblk4[:, :, j, :])
        Uj_idx = jax.lax.dynamic_slice_in_dim(
            Gblk4[:, :, j, :].reshape(s * b, b), j * b, b, axis=0)
        G = inv_lam * Uj_idx + m * eye_b
        rhs = (y_at[j] - m * alpha_at[j] - m * vv
               - inv_lam * jax.lax.dynamic_slice_in_dim(QTalpha, j * b, b)
               - inv_lam * uv)
        sol = jnp.linalg.solve(G, rhs)
        return dalpha.at[j].set(sol)

    return jax.lax.fori_loop(0, s, inner, jnp.zeros((s, b), dtype))


@partial(jax.jit, static_argnames=("cfg", "s", "record_rounds", "gram_fn",
                                   "op_factory"))
def sstep_bdcd_krr(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
                   schedule: jnp.ndarray, cfg: KRRConfig, s: int,
                   record_rounds: bool = False,
                   gram_fn: Optional[Callable] = None,
                   op_factory: Optional[Callable] = None,
                   ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 4.  ``schedule`` is the (H, b) block schedule from
    ``bdcd.block_schedule``; H % s == 0 required."""
    H, b = schedule.shape
    if H % s != 0:
        raise ValueError(f"H={H} must be divisible by s={s}")
    if gram_fn is not None and op_factory is not None:
        raise ValueError("pass either gram_fn (materialized slab) or "
                         "op_factory (slab-free operator), not both")

    m = A.shape[0]
    inv_lam = 1.0 / cfg.lam
    rounds = schedule.reshape(H // s, s, b)
    op = None if gram_fn else (op_factory or GramOperator)(A, cfg.kernel)

    def outer(alpha, idx):                     # idx: (s, b)
        flat = idx.reshape(s * b)
        # --- communication phase ----------------------------------------
        if gram_fn is not None:                # materialized m x sb slab
            Q = gram_fn(A, A[flat], cfg.kernel)
            Gblk = Q[flat, :]                  # (s*b, s*b)
            QTalpha = Q.T @ alpha              # (s*b,)
        else:                                  # slab-free operator path
            Gblk, QTalpha = op.round_data(flat, alpha)
        y_at = y[idx]                          # (s, b)
        alpha_at = alpha[idx]                  # (s, b)

        # --- redundant local phase: s block solves -----------------------
        dalpha = sstep_bdcd_inner(Gblk, QTalpha, alpha_at, y_at, flat,
                                  m, inv_lam, s, b)
        alpha = alpha.at[flat].add(dalpha.reshape(s * b))
        return alpha, (alpha if record_rounds else 0.0)

    alpha_H, hist = jax.lax.scan(outer, alpha0, rounds)
    return (alpha_H, hist) if record_rounds else (alpha_H, None)
