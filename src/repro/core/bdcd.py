"""Block Dual Coordinate Descent (paper Algorithm 3) for kernel ridge
regression.

K-RR dual (paper eq. 2):  the optimality system is
    ((1/lambda) K + m I) alpha = y
BDCD samples a block of ``b`` coordinates per iteration, extracts the b x b
sub-system and solves it exactly:

    G_k = (1/lambda) K(A_k, A_k) + m I      (b x b)
    dalpha = G_k^{-1}(V_k^T y - m V_k^T alpha - (1/lambda) U_k^T alpha)

The ``m x b`` slab ``U_k = K(A, V_k^T A)`` only enters through
``U_k^T alpha`` and its sampled b x b block, so the default path is
slab-free via ``GramOperator`` (DESIGN.md §2); ``gram_fn`` forces the
legacy materialized-slab path (the parity oracle).

Prefer the ``repro.api`` facade (``KernelRidge`` with
``SolverOptions(method="classical", b=...)``) over calling this
entrypoint directly — it adds tolerance-based stopping, layout dispatch,
and prediction on top of the same round protocol (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels import ExactGramOperator, KernelConfig
from .loop import run_rounds


@dataclasses.dataclass(frozen=True)
class KRRConfig:
    lam: float = 1.0          # ridge parameter lambda
    kernel: KernelConfig = dataclasses.field(default_factory=KernelConfig)


def block_schedule(key: jax.Array, H: int, m: int, b: int) -> jnp.ndarray:
    """(H, b) coordinate blocks, each sampled uniformly WITHOUT replacement
    (paper Alg. 3 line 4). Shared by BDCD and s-step BDCD."""
    keys = jax.random.split(key, H)

    def one(k):
        return jax.random.choice(k, m, (b,), replace=False)

    return jax.vmap(one)(keys)


def make_bdcd_round_fn(A: jnp.ndarray, y: jnp.ndarray, cfg: KRRConfig,
                       gram_fn: Optional[Callable] = None,
                       op_factory: Optional[Callable] = None,
                       op=None, lam=None, guard: bool = False) -> Callable:
    """``round_fn(alpha, idx) -> alpha`` for ``loop.run_rounds``: one
    Algorithm-3 exact b x b block solve.  ``op`` injects a prebuilt
    ``GramOperator`` (exact or low-rank) over the training
    representation; the facade builds it once per fit (DESIGN.md §9).

    ``lam`` overrides ``cfg.lam`` with a TRACEABLE value — the batched
    cfg leaf of the fleet solver (repro.tune): ``jax.vmap`` over
    per-member scalars turns one closure into F lockstep problems
    sharing the operator (DESIGN.md §10).

    ``guard=True`` switches to the guarded-carry protocol
    (``round_fn((alpha, f), idx) -> (alpha, f)`` with ``f = K @ alpha``
    maintained by ``f += K[:, idx] @ dalpha`` — the same m x b block
    the round already evaluates; ``U^T alpha`` becomes the free gather
    ``f[idx]``, and drift correction splices an exactly recomputed
    ``f`` back in; DESIGN.md §12).  Requires the operator path."""
    if sum(x is not None for x in (gram_fn, op_factory, op)) > 1:
        raise ValueError("pass at most one of gram_fn (materialized "
                         "slab), op_factory, or op (prebuilt operator)")
    if guard and gram_fn is not None:
        raise ValueError("guard=True requires the GramOperator path "
                         "(gram_fn= is the legacy materialized oracle)")
    m = A.shape[0]
    inv_lam = 1.0 / (cfg.lam if lam is None else lam)
    if op is None and gram_fn is None:
        op = (op_factory or ExactGramOperator)(A, cfg.kernel)

    if guard:
        def round_fn(carry, idx):             # idx: (b,)
            alpha, f = carry                  # f = K @ alpha, (m,)
            b = idx.shape[0]
            Gblk = op.cross_block(idx)        # (b, b)
            uTa = f[idx]                      # U^T alpha, free gather
            G = inv_lam * Gblk + m * jnp.eye(b, dtype=A.dtype)
            rhs = y[idx] - m * alpha[idx] - inv_lam * uTa
            dalpha = jnp.linalg.solve(G, rhs)
            return (alpha.at[idx].add(dalpha),
                    f + op.apply_at(idx, dalpha))

        return round_fn

    def round_fn(alpha, idx):                 # idx: (b,)
        b = idx.shape[0]
        if gram_fn is not None:               # materialized m x b slab
            U = gram_fn(A, A[idx], cfg.kernel)
            Gblk = U[idx, :]
            uTa = U.T @ alpha
        else:                                 # slab-free operator path
            Gblk, uTa = op.round_data(idx, alpha)
        G = inv_lam * Gblk + m * jnp.eye(b, dtype=A.dtype)
        rhs = y[idx] - m * alpha[idx] - inv_lam * uTa
        dalpha = jnp.linalg.solve(G, rhs)
        return alpha.at[idx].add(dalpha)

    return round_fn


# repro: noqa[CHK-STATIC] gram_fn/op_factory are module-level functions
#   (or None) at every call site; passing a fresh closure retraces by
#   design — it is the documented parity-oracle escape hatch.
@partial(jax.jit, static_argnames=("cfg", "record_every", "gram_fn",
                                   "op_factory"))
def bdcd_krr(A: jnp.ndarray, y: jnp.ndarray, alpha0: jnp.ndarray,
             schedule: jnp.ndarray, cfg: KRRConfig,
             record_every: int = 0,
             gram_fn: Optional[Callable] = None,
             op_factory: Optional[Callable] = None,
             op=None,
             ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run Algorithm 3 for H = schedule.shape[0] iterations.  ``op``
    injects a prebuilt operator (pytree, crosses jit as data)."""
    round_fn = make_bdcd_round_fn(A, y, cfg, gram_fn=gram_fn,
                                  op_factory=op_factory, op=op)
    res = run_rounds(round_fn, alpha0, schedule,
                     record_state=bool(record_every))
    if record_every:
        return res.state, res.state_hist[record_every - 1::record_every]
    return res.state, None
