"""The jit-safe half of the guarded-solve layer (DESIGN.md §12).

Everything here is consumed by ``core.loop._run_rounds_guarded`` through
a ``GuardSpec``: the health predicate runs after EVERY round on the new
carry (an unhealthy update is discarded and the loop freezes on the last
good state), and the correction closure performs residual replacement —
recompute ``f = K @ alpha`` exactly through the ``GramOperator`` (one
extra KMV, never a stored gram) and splice it back into the carry,
recording the observed relative drift.

The escalation ladder is the HOST-side policy the facade walks when a
guarded run reports divergence: halve s (s-step -> shallower s-step ->
classical at s=1) then retry in f64 accumulation.  Every rung solves the
SAME problem — the s-step decomposition is mathematically equivalent at
every s — so falling back resumes from the last good state instead of
restarting.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


class DivergenceError(RuntimeError):
    """A guarded solve diverged and the escalation ladder was exhausted
    (or fallback was disabled).  Carries the structured ``events`` the
    run observed before giving up."""

    def __init__(self, message: str, events: tuple = ()):
        super().__init__(message)
        self.events = events


def finite_health(state) -> jnp.ndarray:
    """Scalar bool: every leaf of the carry is finite.  O(carry) reads,
    no reductions beyond ``all`` — cheap enough to run every round."""
    leaves = jax.tree_util.tree_leaves(state)
    return functools.reduce(
        jnp.logical_and, [jnp.all(jnp.isfinite(leaf)) for leaf in leaves])


def init_residual(op, alpha0: jnp.ndarray) -> jnp.ndarray:
    """f_0 = K @ alpha_0 through the operator.  Cold starts (alpha_0 ==
    0, the overwhelmingly common case) skip the matvec entirely — this
    runs host-side before the jitted chunk, so the data-dependent branch
    is free."""
    import numpy as np
    if not np.any(np.asarray(jax.device_get(alpha0))):
        return jnp.zeros_like(alpha0)
    return op.full_matvec(alpha0)


def make_correct_fn(op):
    """``correct_fn(state) -> (state', drift)`` for ``GuardSpec``:
    residual replacement.  ``drift`` is the relative error of the
    recurrence-maintained residual vs. the exact recompute — the
    quantity the paper's stability experiments track."""

    def correct_fn(state):
        alpha, f = state
        f_exact = op.full_matvec(alpha)
        drift = (jnp.linalg.norm(f - f_exact)
                 / (jnp.linalg.norm(f_exact) + 1e-30))
        return (alpha, f_exact), drift

    return correct_fn


# Escalation-ladder rungs, in the order the facade tries them.
LADDER_HALVE_S = "halve_s"
LADDER_CLASSICAL = "classical"
LADDER_F64 = "f64"


def next_fallback(s: int, method: str, x64: bool
                  ) -> Tuple[str, int, str, bool]:
    """One rung down the ladder from the current (s, method, x64) state.

    Returns ``(action, s', method', x64')``; raises ``DivergenceError``
    when the ladder is exhausted (already classical AND f64).  Halving
    is repeated until s == 1 — each step is a strictly more conservative
    round decomposition of the SAME iterate sequence — then the method
    itself drops to classical, then accumulation widens to f64.
    """
    if method == "sstep" and s > 1:
        s2 = max(1, s // 2)
        return (f"{LADDER_HALVE_S}:{s}->{s2}", s2, method, x64)
    if method == "sstep":
        return (LADDER_CLASSICAL, 1, "classical", x64)
    if not x64:
        return (LADDER_F64, s, method, True)
    raise DivergenceError(
        "escalation ladder exhausted: classical method in f64 "
        "accumulation still diverges — the problem data or "
        "regularization is pathological")
