"""Mid-solve checkpoint/resume for guarded fits (DESIGN.md §12).

Thin, typed layer over the generic ``train/checkpoint.py`` machinery
(atomic step directories, one .npy per pytree leaf, async writes):

  * ``save_solve_state``/``load_solve_state`` snapshot the guarded
    carry ``(alpha, f)`` plus the host bookkeeping needed to continue —
    iterations consumed, the CURRENT ladder position (s/method may have
    fallen back mid-run), and a solve fingerprint.
  * The fingerprint pins everything the deterministic replay depends on
    (problem, shapes, config, schedule seed); ``fit(resume_from=...)``
    refuses to resume a checkpoint from a different solve — resuming
    under a different schedule or config would silently compute garbage.
  * ``save_fit``/``load_fit`` round-trip a completed ``FitResult``
    (arrays as leaves, host scalars/options as JSON meta) together with
    its ``GramOperator`` — exact or Nystrom; operators are registered
    pytrees, so the generic leaf machinery handles them once the
    template supplies the static aux data.
  * ``operator_meta``/``operator_template`` make that template
    SELF-DESCRIBING: the static half of an operator (representation
    kind, kernel config, block size) serializes to a JSON dict, and the
    dict rebuilds a structurally-identical template on a cold host —
    no live operator needed to load.  This is what the serving artifact
    layer (``repro.serve.artifacts``, DESIGN.md §13) persists models
    through, and ``load_fit`` uses it as the fallback when the caller
    passes no ``op_template``.

Checkpoints are cut at outer-round boundaries, so a resumed solve
replays the SAME round decomposition from the snapshot round — the
continuation is bit-identical to the uninterrupted run modulo the
restart round (acceptance: the resumed solve reaches the same
tolerance-stop solution).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import (CheckpointManager, available_steps,
                                    load_checkpoint, save_checkpoint)

SOLVE_STATE_KEYS = ("alpha", "f")


# ---------------------------------------------------------------------------
# Operator (de)serialization: the static half as JSON, the array half as
# ordinary checkpoint leaves.  ``matvec_impl`` (a host callable, pure
# acceleration — never semantics) is deliberately NOT persisted: a
# restored operator serves through the portable jnp path, and callers
# that want the Pallas KMV back re-attach it explicitly.
# ---------------------------------------------------------------------------

def operator_meta(op) -> dict:
    """The static (non-leaf) half of a ``GramOperator`` as a JSON-native
    dict — enough for ``operator_template`` to rebuild a structurally
    identical pytree template on a host that never saw the original."""
    import dataclasses as _dc

    from repro.core.kernels import ExactGramOperator, LowRankGramOperator

    if isinstance(op, ExactGramOperator):
        return {"kind": "exact", "kernel": _dc.asdict(op.cfg),
                "block": int(op.block)}
    if isinstance(op, LowRankGramOperator):
        meta = {"kind": "lowrank", "has_fmap": op.fmap is not None}
        if op.fmap is not None:
            meta["kernel"] = _dc.asdict(op.fmap.kernel)
        return meta
    raise TypeError(
        f"cannot serialize operator of type {type(op).__name__}: only "
        f"the Exact/LowRank serving representations persist (sharded "
        f"operators are rebuilt per rank from their shards)")


def operator_template(meta: dict):
    """Inverse of ``operator_meta``: a template operator whose treedef +
    static aux match the saved one (leaf slots hold the placeholder 0 —
    the checkpoint loader only reads the STRUCTURE)."""
    from repro.core.kernels import (ExactGramOperator, KernelConfig,
                                    LowRankGramOperator)
    from repro.core.nystrom import NystromMap

    kind = meta.get("kind")
    if kind == "exact":
        return ExactGramOperator(A=0, cfg=KernelConfig(**meta["kernel"]),
                                 matvec_impl=None,
                                 block=int(meta.get("block", 2048)))
    if kind == "lowrank":
        fmap = None
        if meta.get("has_fmap"):
            fmap = NystromMap(landmarks=0, transform=0,
                              kernel=KernelConfig(**meta["kernel"]))
        return LowRankGramOperator(Phi=0, fmap=fmap)
    raise ValueError(f"unknown operator kind {kind!r} in checkpoint "
                     f"meta — cannot rebuild a template")


def solve_fingerprint(problem: str, m: int, dtype, cfg, opts) -> dict:
    """Everything a valid resume must match: the schedule replay is
    deterministic in (seed, max_iters, m, b), and the iterate sequence
    additionally depends on the problem config.  The CURRENT ladder
    position (s/method) is deliberately NOT here — it is resume STATE
    (stored alongside), not identity."""
    return {
        "problem": problem,
        "m": int(m),
        "dtype": str(dtype),
        "cfg": repr(cfg),
        "b": int(opts.b if problem == "krr" else 1),
        "seed": int(opts.seed),
        "max_iters": int(opts.max_iters),
        "layout": opts.layout,
    }


def save_solve_state(manager: CheckpointManager, iters_done: int,
                     alpha, f, *, s_cur: int, method_cur: str,
                     fingerprint: dict) -> None:
    """Async snapshot at an outer-round boundary (``iters_done`` inner
    iterations consumed).  ``f`` may be None (distributed layouts carry
    only alpha; the residual is recomputed on resume)."""
    tree = {"alpha": alpha}
    if f is not None:
        tree["f"] = f
    manager.save_async(iters_done, tree,
                       extra={"iters_done": int(iters_done),
                              "s_cur": int(s_cur),
                              "method_cur": method_cur,
                              "has_f": f is not None,
                              "fingerprint": fingerprint})


def load_solve_state(directory: str, *,
                     expect_fingerprint: Optional[dict] = None
                     ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], dict]:
    """Latest snapshot in ``directory`` -> ``(alpha, f, extra)``.

    Raises ``FileNotFoundError`` when empty and ``ValueError`` on a
    fingerprint mismatch (naming every differing field)."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(
            f"resume_from={directory!r}: no checkpoints found")
    tree, meta = load_checkpoint(directory, step=steps[-1])
    extra = meta["extra"]
    if expect_fingerprint is not None:
        saved = extra.get("fingerprint", {})
        bad = {k: (saved.get(k), v) for k, v in expect_fingerprint.items()
               if saved.get(k) != v}
        if bad:
            detail = ", ".join(f"{k}: checkpoint={s!r} vs fit={v!r}"
                               for k, (s, v) in sorted(bad.items()))
            raise ValueError(
                f"resume_from={directory!r} belongs to a different "
                f"solve — mismatched fingerprint fields: {detail}")
    # leaves come back path-sorted by the template-free loader: the
    # meta paths name them
    by_path = dict(zip(meta["paths"], tree))
    alpha = jnp.asarray(by_path["alpha"])
    f = jnp.asarray(by_path["f"]) if extra.get("has_f") else None
    return alpha, f, extra


def save_fit(directory: str, result, op=None, step: int = 0) -> str:
    """Persist a completed ``FitResult`` (+ optionally its operator).

    Arrays travel as checkpoint leaves; host scalars, the resolved
    ``SolverOptions`` and the comm model go to JSON meta.  The guard's
    ``SolveHealth`` ledger round-trips too (drift as an array leaf,
    events/scalars as meta) — a restored fit keeps its provenance.
    ``plan`` (a live tuning session) and ``telemetry`` (an open
    recording handle) are session objects and are NOT persisted."""
    arrays = {"alpha": result.alpha, "schedule": result.schedule}
    if result.history is not None:
        arrays["history"] = np.asarray(result.history)
    health = getattr(result, "health", None)
    if health is not None:
        arrays["health_drift"] = (np.zeros(0) if health.drift is None
                                  else np.asarray(health.drift))
    tree = {"arrays": arrays}
    if op is not None:
        tree["op"] = op
    meta = {
        "metric": result.metric,
        "converged": bool(result.converged),
        "rounds_run": int(result.rounds_run),
        "iters_run": int(result.iters_run),
        "wall_time_s": float(result.wall_time_s),
        # comm is the modeled_fit_cost dict: numeric terms plus config
        # echoes like approx (possibly None) — all JSON-native already
        "comm": {k: (float(v) if isinstance(v, float) else v)
                 for k, v in result.comm.items()},
        # a live Mesh is a device handle and a live Telemetry an open
        # log, not state — resumable options rebuild/re-enable on the
        # restoring host
        "options": {**dataclasses.asdict(result.options), "mesh": None,
                    "telemetry": None},
        "representation": result.representation,
        "has_history": result.history is not None,
        "has_op": op is not None,
        "has_health": health is not None,
    }
    if health is not None:
        meta["health"] = {
            "guarded": bool(health.guarded),
            "recompute_every": int(health.recompute_every),
            "corrections": int(health.corrections),
            "checkpoints": int(health.checkpoints),
            "resumed_from": health.resumed_from,
            "events": [dataclasses.asdict(e) for e in health.events],
        }
    if op is not None:
        meta["op_meta"] = operator_meta(op)
    return save_checkpoint(directory, step, tree, extra={"fit": meta})


def load_fit(directory: str, op_template: Any = None, step: int = 0):
    """Inverse of ``save_fit`` -> ``(FitResult, op)``.

    ``op_template`` must be an operator with the same STRUCTURE as the
    saved one (pytree aux data — configs, static ints — lives in the
    treedef, not on disk); pass the live operator or a zeros-like
    clone — or pass None and the template is rebuilt from the saved
    ``operator_meta`` (checkpoints written before the meta existed
    still require an explicit template).  ``op`` is None when the fit
    was saved without one."""
    from repro.api import FitResult, SolverOptions

    steps = available_steps(directory)
    if step not in steps:
        raise FileNotFoundError(
            f"no step {step} in {directory!r} (have {steps})")
    _, meta = load_checkpoint(directory, step=step)
    fit = meta["extra"]["fit"]
    # 0 is a LEAF placeholder (None would be an empty pytree node and
    # drop the slot from the template structure)
    arrays = {"alpha": 0, "schedule": 0}
    if fit["has_history"]:
        arrays["history"] = 0
    if fit.get("has_health"):
        arrays["health_drift"] = 0
    template = {"arrays": arrays}
    if fit["has_op"]:
        if op_template is None and "op_meta" in fit:
            op_template = operator_template(fit["op_meta"])
        if op_template is None:
            raise ValueError("checkpoint contains an operator but no "
                             "op_meta (pre-serve format); pass "
                             "op_template= with the matching structure")
        template["op"] = op_template
    tree, _ = load_checkpoint(directory, step=step, template=template)
    arrs = tree["arrays"]
    health = None
    if fit.get("has_health"):
        from repro.resilience.health import HealthEvent, SolveHealth
        h = fit["health"]
        health = SolveHealth(
            guarded=h["guarded"],
            recompute_every=h["recompute_every"],
            drift=np.asarray(arrs["health_drift"]),
            corrections=h["corrections"],
            events=tuple(HealthEvent(**e) for e in h["events"]),
            checkpoints=h["checkpoints"],
            resumed_from=h["resumed_from"])
    result = FitResult(
        alpha=jnp.asarray(arrs["alpha"]),
        schedule=jnp.asarray(arrs["schedule"]),
        history=(np.asarray(arrs["history"]) if fit["has_history"]
                 else None),
        metric=fit["metric"], converged=fit["converged"],
        rounds_run=fit["rounds_run"], iters_run=fit["iters_run"],
        wall_time_s=fit["wall_time_s"], comm=fit["comm"],
        options=SolverOptions(**fit["options"]),
        representation=fit["representation"], health=health)
    return result, tree.get("op")
