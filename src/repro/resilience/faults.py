"""Deterministic fault injection for guarded-solve tests (DESIGN.md §12).

Recovery paths that are never exercised are recovery theater: this
harness lets tier-1 tests inject the exact failures the guard exists
for, at deterministic points, and assert end-to-end recovery:

  * ``FaultPlan(nan_at_iter=...)`` — the facade executor arms the
    jit-safe fault lane of its guarded chunk: at the round containing
    the given inner iteration, ``value`` (NaN/Inf) is added to the
    chosen carry leaf.  The fault fires ONCE (the executor consumes it
    after the divergence is observed), so the escalation ladder descends
    exactly one rung per injected fault.
  * ``FaultPlan(kill_at_iter=...)`` — the executor raises
    ``SimulatedKill`` at the first checkpoint boundary at/after the
    given iteration (after the snapshot is durable), simulating
    preemption; the test then re-fits with ``resume_from=``.
  * ``poisoned_1d_factory`` — an ``op_factory`` for the 1d solvers that
    scales ONE rank's local column shard before the all-reduce, so that
    shard's psum contribution is corrupted (NaN scale) or perturbed
    (finite scale) consistently across every round of a chunk.

Faults are armed with the ``inject`` context manager; production code
never consults this module unless a plan is active.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

FAULT_TARGETS = ("f", "alpha")


class SimulatedKill(RuntimeError):
    """Raised by the executor to simulate preemption mid-solve.  The
    checkpoint written just before the raise is durable — catch this and
    re-fit with ``resume_from=`` to exercise the recovery path."""

    def __init__(self, message: str, checkpoint_dir: str):
        super().__init__(message)
        self.checkpoint_dir = checkpoint_dir


@dataclasses.dataclass
class FaultPlan:
    """One deterministic fault scenario.

    nan_at_iter: global inner-iteration index; the fault fires in the
                 round containing it.  None = no carry fault.
    value:       what is added to the target leaf (NaN default, or Inf).
    target:      which guarded-carry leaf to poison: "f" (the residual
                 recurrence — the s-step failure mode) or "alpha".
    kill_at_iter: simulate preemption at the first checkpoint boundary
                 at/after this iteration.  None = no kill.
    """

    nan_at_iter: Optional[int] = None
    value: float = float("nan")
    target: str = "f"
    kill_at_iter: Optional[int] = None
    # one-shot bookkeeping (set by the executor)
    carry_fired: bool = False
    kill_fired: bool = False

    def __post_init__(self):
        if self.target not in FAULT_TARGETS:
            raise ValueError(f"target must be one of {FAULT_TARGETS}, "
                             f"got {self.target!r}")

    def carry_fault_round(self, pos: int, seg_iters: int, s: int) -> int:
        """Round index WITHIN the segment [pos, pos + seg_iters) where
        the carry fault should fire, or -1 (none/already fired)."""
        if self.nan_at_iter is None or self.carry_fired:
            return -1
        if not pos <= self.nan_at_iter < pos + seg_iters:
            return -1
        return (self.nan_at_iter - pos) // s

    def should_kill(self, pos: int) -> bool:
        """Whether the executor should simulate preemption at the
        checkpoint boundary after ``pos`` consumed iterations."""
        return (self.kill_at_iter is not None and not self.kill_fired
                and pos >= self.kill_at_iter)


_ACTIVE: Optional[FaultPlan] = None


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Arm ``plan`` for every guarded fit inside the block."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


def poisoned_1d_factory(axis_name: str = "model", rank: int = 0,
                        scale: float = float("nan")):
    """``op_factory(A_loc, kcfg)`` for the 1d solvers that corrupts ONE
    rank's shard before the round all-reduce: its psum contribution is
    scaled by ``scale`` (NaN poisons the collective; a large finite
    scale perturbs it).  Linear kernels only — the RBF operator needs
    the psummed row norms, which this factory deliberately does not
    recompute from poisoned data."""
    from repro.core.distributed import AllreduceGramOperator

    def factory(A_loc, kcfg):
        if kcfg.name != "linear":
            raise ValueError("poisoned_1d_factory supports linear "
                             f"kernels only, got {kcfg.name!r}")
        r = jax.lax.axis_index(axis_name)
        fac = jnp.where(r == rank, jnp.asarray(scale, A_loc.dtype),
                        jnp.ones((), A_loc.dtype))
        return AllreduceGramOperator(axis_name, A_loc * fac, kcfg, None)

    return factory
