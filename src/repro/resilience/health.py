"""Structured health records for guarded solves (DESIGN.md §12).

``SolveHealth`` is the host-side ledger ``fit`` attaches to
``FitResult.health`` when ``SolverOptions.guard`` is on: the observed
residual drift at every correction, every divergence/fallback event the
escalation ladder walked, and the checkpoint/resume bookkeeping.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

# What the guard observed (HealthEvent.kind).
KIND_NONFINITE = "nonfinite"       # NaN/Inf appeared in the carry
KIND_METRIC = "metric"             # gap/residual blow-up or non-finite
KIND_RESUME = "resume"             # solve restored from a checkpoint


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One guard observation and the action taken on it.

    kind:    "nonfinite" | "metric" | "resume".
    round_idx: 0-based OUTER round (within the whole solve) of the first
             unhealthy round — the update of that round was DISCARDED;
             the solve resumed from the carry before it.
    iter_idx: the matching inner-iteration offset into the schedule.
    action:  what the executor did: "halve_s:16->8" | "classical" |
             "f64" | "resume" | "raise".
    detail:  free-form context (metric value, checkpoint path, ...).
    """

    kind: str
    round_idx: int
    iter_idx: int
    action: str
    detail: str = ""


# repro: noqa[CHK-PYTREE] host-side health ledger — built by the facade
#   executor AFTER every jit boundary has been crossed (drift arrays are
#   device_get numpy); it is never passed into a traced function.
@dataclasses.dataclass
class SolveHealth:
    """Everything the guarded executor observed across one ``fit``.

    guarded:          the guard was on (False => a plain solve).
    recompute_every:  resolved drift-correction cadence in outer rounds
                      (0 = correction off).
    drift:            (n_corrections,) observed relative drift at each
                      residual replacement, concatenated across
                      segments/fallbacks in execution order.
    corrections:      == len(drift).
    events:           every HealthEvent in execution order.
    checkpoints:      snapshots written by THIS fit.
    resumed_from:     checkpoint path the solve restored from, or None.
    """

    guarded: bool = False
    recompute_every: int = 0
    drift: Optional[np.ndarray] = None
    corrections: int = 0
    events: Tuple[HealthEvent, ...] = ()
    checkpoints: int = 0
    resumed_from: Optional[str] = None

    @property
    def max_drift(self) -> float:
        """Largest observed relative residual drift (0.0 when no
        correction ever ran)."""
        if self.drift is None or len(self.drift) == 0:
            return 0.0
        return float(np.max(self.drift))

    @property
    def fallbacks(self) -> Tuple[HealthEvent, ...]:
        """The subset of events where the escalation ladder fired."""
        return tuple(e for e in self.events
                     if e.kind in (KIND_NONFINITE, KIND_METRIC))
