"""Guarded solves: drift correction, divergence detection with
auto-fallback, mid-solve checkpoint/resume, and fault injection
(DESIGN.md §12).

The paper's s-step solvers are "the same solution in exact arithmetic",
but in finite precision the guarded protocol's residual ``f = K @
alpha`` is maintained by a long recurrence of fused updates — the
classic s-step/CA failure mode that residual replacement counters
(Devarakonda et al. 2016).  This package supplies the pieces the shared
round protocol (``core/loop.run_rounds(guard=...)``) and the facade
executor (``repro.api``) thread through every solver family:

  guard.py       jit-safe health predicate, residual init / exact
                 recompute (drift correction), the escalation ladder
  health.py      structured HealthEvent / SolveHealth records
                 (``FitResult.health``)
  checkpoint.py  mid-solve snapshot/resume over train/checkpoint.py
  faults.py      deterministic fault injection for tests: NaN/Inf into
                 carries, one shard's psum contribution, kill/restart
"""
from .guard import (DivergenceError, finite_health, init_residual,
                    make_correct_fn, next_fallback, LADDER_HALVE_S,
                    LADDER_CLASSICAL, LADDER_F64)
from .health import HealthEvent, SolveHealth
from .checkpoint import (SOLVE_STATE_KEYS, load_solve_state,
                         save_solve_state, solve_fingerprint)
from .faults import (FaultPlan, SimulatedKill, active_plan, inject,
                     poisoned_1d_factory)
