"""Model artifacts: persist a fitted estimator, restore it cold
(DESIGN.md §13, layer 1 of ``repro.serve``).

A *servable model* is everything the prediction path needs and nothing
the solve needed: the serving ``GramOperator`` (exact features + kernel
config, or the Nystrom factor + feature map), the dual weights, the
problem config (C/lam/loss), the RESOLVED ``SolverOptions`` the fit ran
with, and — so a deployed model can absorb fresh labeled traffic via
``ModelRegistry.refit`` — the raw training data and targets.

On-disk format reuses the checkpoint machinery end to end
(``train/checkpoint.py`` atomic step directories; one .npy per pytree
leaf; ``resilience/checkpoint.operator_meta`` for the operator's static
half), under a VERSIONED manifest:

    <dir>/step_00000000/
        meta.json      {"serve_manifest": {"version": 1, "problem": ...,
                        "cfg": ..., "options": ..., "op_meta": ...,
                        "fingerprint": ...}}
        leaf_*.npy     alpha, y, op leaves, [A_raw for low-rank]

``load_model`` refuses manifests from a NEWER format version (forward
compatibility is a lie; failing loudly beats serving garbage) and
verifies the fit fingerprint round-trips, so a registry can dedup
device state across models restored on different days (content hashes
match when the training set matches — see ``registry.operator_key``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp

from repro.core.dcd import SVMConfig
from repro.core.bdcd import KRRConfig
from repro.core.kernels import ExactGramOperator, KernelConfig
from repro.resilience.checkpoint import operator_meta, operator_template
from repro.train.checkpoint import (available_steps, load_checkpoint,
                                    save_checkpoint)

MANIFEST_VERSION = 1
PROBLEMS = ("ksvm", "krr")


# repro: noqa[CHK-PYTREE] host-side model record — the registry/engine
#   feed its op/weights INTO jitted block calls as separate pytree args;
#   the record itself never crosses a jit boundary.
@dataclasses.dataclass
class ServableModel:
    """A fitted estimator reduced to its serving + refit essentials.

    ``problem`` is "ksvm" or "krr"; ``alpha`` the raw dual solution;
    ``y`` the training targets/labels (refit needs them; K-SVM serving
    folds them into the weights); ``op`` the UNSCALED serving operator
    the facade kept on ``op_``; ``A_raw`` the raw training features —
    identical to ``op.A`` for exact representations (not duplicated in
    storage), carried separately for low-rank ones (refit has to rebuild
    the feature map over the grown training set).
    """

    problem: str
    cfg: Union[SVMConfig, KRRConfig]
    options: object                      # resolved SolverOptions
    alpha: jnp.ndarray
    y: jnp.ndarray
    op: object                           # GramOperator
    A_raw: Optional[jnp.ndarray] = None
    fingerprint: Optional[dict] = None

    def __post_init__(self):
        if self.problem not in PROBLEMS:
            raise ValueError(f"problem must be one of {PROBLEMS}, got "
                             f"{self.problem!r}")

    # -- serving surface ------------------------------------------------

    @property
    def serve_w(self) -> jnp.ndarray:
        """The weight vector ``K(Xq, train) @ w`` serves, with every
        per-model scalar FOLDED IN (serving is linear in w): K-SVM
        decision values use ``alpha * y``; K-RR predictions ``alpha /
        lam``.  Registry groups stack these columns directly — one
        block call serves every model in the group with no per-model
        epilogue."""
        if self.problem == "ksvm":
            return self.alpha * self.y
        return self.alpha / self.cfg.lam

    @property
    def features(self) -> jnp.ndarray:
        """Raw training features (refit's base): ``op.A`` for exact
        operators, the separately-carried ``A_raw`` for low-rank."""
        if isinstance(self.op, ExactGramOperator):
            return self.op.A
        if self.A_raw is None:
            raise ValueError(
                "low-rank model carries no raw training features "
                "(A_raw=None) — it can serve but not refit")
        return self.A_raw

    @classmethod
    def from_estimator(cls, est) -> "ServableModel":
        """Capture a fitted ``repro.api`` estimator (``KernelSVM`` /
        ``KernelRidge``)."""
        from repro.api import KernelRidge, KernelSVM
        from repro.resilience.checkpoint import solve_fingerprint

        if isinstance(est, KernelSVM):
            problem = "ksvm"
        elif isinstance(est, KernelRidge):
            problem = "krr"
        else:
            raise TypeError(f"expected a fitted KernelSVM/KernelRidge, "
                            f"got {type(est).__name__}")
        if not hasattr(est, "op_"):
            raise ValueError("estimator is not fitted (no op_) — call "
                             "fit() before registering/saving")
        y = est.y_
        opts = est.result_.options
        A_raw = est.A_ if not isinstance(est.op_, ExactGramOperator) \
            else None
        fp = solve_fingerprint(problem, est.A_.shape[0], est.A_.dtype,
                               est.cfg, opts)
        return cls(problem=problem, cfg=est.cfg, options=opts,
                   alpha=est.alpha_, y=y, op=est.op_, A_raw=A_raw,
                   fingerprint=fp)


def save_model(directory: str, model, *, step: int = 0) -> str:
    """Persist a ``ServableModel`` (or a fitted estimator, captured via
    ``ServableModel.from_estimator``) under a versioned manifest.
    Returns the checkpoint path."""
    from repro.api import KernelRidge, KernelSVM

    if isinstance(model, (KernelSVM, KernelRidge)):
        model = ServableModel.from_estimator(model)
    tree = {"alpha": model.alpha, "y": model.y, "op": model.op}
    if model.A_raw is not None:
        tree["A_raw"] = model.A_raw
    manifest = {
        "version": MANIFEST_VERSION,
        "problem": model.problem,
        "cfg": _cfg_meta(model.cfg),
        "options": {**dataclasses.asdict(model.options), "mesh": None,
                    "telemetry": None},
        "op_meta": operator_meta(model.op),
        "has_A_raw": model.A_raw is not None,
        "fingerprint": model.fingerprint,
    }
    return save_checkpoint(directory, step, tree,
                           extra={"serve_manifest": manifest})


def load_model(directory: str, *, step: Optional[int] = None
               ) -> ServableModel:
    """Restore a ``ServableModel`` from ``save_model`` output.  The
    operator template is rebuilt from the manifest's ``op_meta`` — no
    live object needed; a manifest written by a NEWER format version is
    refused with the versions named."""
    from repro.api import SolverOptions

    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no model artifact in {directory!r}")
    step = steps[-1] if step is None else step
    _, meta = load_checkpoint(directory, step=step)
    manifest = meta["extra"].get("serve_manifest")
    if manifest is None:
        raise ValueError(
            f"{directory!r} holds a checkpoint but not a serve model "
            f"artifact (no serve_manifest) — was it written by "
            f"save_fit/save_solve_state instead of save_model?")
    if manifest["version"] > MANIFEST_VERSION:
        raise ValueError(
            f"model artifact {directory!r} has manifest version "
            f"{manifest['version']} but this build reads <= "
            f"{MANIFEST_VERSION} — upgrade repro before serving it")
    template = {"alpha": 0, "y": 0,
                "op": operator_template(manifest["op_meta"])}
    if manifest["has_A_raw"]:
        template["A_raw"] = 0
    tree, _ = load_checkpoint(directory, step=step, template=template)
    return ServableModel(
        problem=manifest["problem"],
        cfg=_cfg_from_meta(manifest["problem"], manifest["cfg"]),
        options=SolverOptions(**manifest["options"]),
        alpha=jnp.asarray(tree["alpha"]),
        y=jnp.asarray(tree["y"]),
        op=tree["op"],
        A_raw=(jnp.asarray(tree["A_raw"]) if manifest["has_A_raw"]
               else None),
        fingerprint=manifest["fingerprint"])


def _cfg_meta(cfg) -> dict:
    meta = dataclasses.asdict(cfg)           # kernel nests as a dict
    return meta


def _cfg_from_meta(problem: str, meta: dict):
    kernel = KernelConfig(**meta.pop("kernel"))
    if problem == "ksvm":
        return SVMConfig(kernel=kernel, **meta)
    return KRRConfig(kernel=kernel, **meta)
