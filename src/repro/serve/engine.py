"""Continuous-batching serving engine (DESIGN.md §13, layer 3 of
``repro.serve``).

Query traffic does not arrive in tidy power-of-two blocks: requests for
different models trickle in one at a time, some with latency deadlines,
sometimes faster than the device can serve.  This engine turns that
stream into the fixed-shape work the jit cache already holds — the
serving twin of the training fleet's slot-matrix scheduler
(``train/serving.py``: admit into fixed slots, step, retire):

  * ``submit`` validates EAGERLY (feature width, dtype, 1-D/2-D shape —
    the offending argument named; a malformed request never reaches a
    batch another request is riding in), then enqueues a ``Ticket``.
    The queue is BOUNDED: beyond ``max_queue`` waiting tickets new
    arrivals are SHED at submit time — the caller learns immediately
    (ticket.status == "shed") instead of waiting on a queue that cannot
    drain; accepted traffic keeps its latency.
  * ``step`` is one drain cycle: expired tickets retire first (deadline
    passed while queued — serving them would waste a slot on an answer
    nobody is waiting for), then each registry group admits up to
    ``slots`` queued rows, concatenates them into ONE query block, and
    serves every member model's column in a single
    ``BatchedPredictor`` call — the block pads to the pre-warmed
    power-of-two buckets, so admission NEVER compiles (asserted via
    ``serve_cache_size`` growth == 0 after ``warmup``).
  * mixed-model traffic batches per GROUP, not per model: requests for
    F models sharing one operator ride the same block, each ticket
    slicing its model's column out of the (q, F) result.

Time is injected (``clock=``): production uses ``time.monotonic``; the
SLO benchmark (fig9) drives a virtual clock advanced by measured step
durations, so modeled-vs-measured latency comparisons do not inherit
host scheduling jitter.  Registry mutations (refit's atomic swap)
are picked up at step boundaries via the generation counter —
in-flight blocks finish on the weights they were formed with.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predict import validate_queries
from .registry import ModelRegistry

PENDING = "pending"
DONE = "done"
EXPIRED = "expired"
SHED = "shed"


# repro: noqa[CHK-PYTREE] host-side request record — the engine gathers
#   ticket rows into plain query blocks before any jit boundary; the
#   ticket itself never crosses one.
@dataclasses.dataclass
class Ticket:
    """One submitted request: ``rows`` queries against one model.

    ``X`` is kept as a HOST array: the engine assembles each group's
    batch in a host buffer sized to the jit bucket and ships ONE
    transfer per block — per-ticket device concatenation would compile
    a fresh XLA concat for every distinct ticket count.

    ``status`` walks pending -> done (``result`` holds the (rows,)
    values) | expired (deadline passed while queued) | shed (bounded
    queue was full at submit).  Times are in the engine clock's units.
    """

    id: int
    name: str
    X: np.ndarray                       # (rows, n) query block, host
    t_submit: float
    deadline: Optional[float] = None    # absolute clock time, or None
    status: str = PENDING
    result: Optional[jnp.ndarray] = None
    t_done: Optional[float] = None

    @property
    def rows(self) -> int:
        return self.X.shape[0]

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-done latency (None until served)."""
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit


class ServingEngine:
    """Bounded-queue continuous batcher over a ``ModelRegistry``.

    ``slots`` is the per-group admission width of one step — at most
    that many queued rows form each group's block, so it must not
    exceed the registry's ``predict_batch`` (the largest warmed
    bucket); the constructor clamps and the invariant holds by
    construction.  ``max_queue`` bounds WAITING tickets across all
    models; ``clock`` supplies time (injectable for virtual-time
    benchmarking).

    ``telemetry`` (repro.obs, DESIGN.md §15) hangs serving metrics off
    the shared registry: queue depth (gauge), ticket dispositions
    (counter, labelled by status), batch occupancy (histogram of
    admitted-rows/slots per block) and submit-to-done latency
    (histogram); ``step`` additionally records one phase="serve" host
    span.  A None/disabled handle costs nothing on the hot path.
    """

    def __init__(self, registry: ModelRegistry, *, slots: int = 256,
                 max_queue: int = 1024,
                 clock: Callable[[], float] = time.monotonic,
                 telemetry=None):
        if not isinstance(slots, int) or slots < 1:
            raise ValueError(f"slots must be a positive int, got {slots!r}")
        if not isinstance(max_queue, int) or max_queue < 1:
            raise ValueError(
                f"max_queue must be a positive int, got {max_queue!r}")
        self.registry = registry
        self.slots = min(slots, registry.predict_batch)
        self.max_queue = max_queue
        self.clock = clock
        self._queue: List[Ticket] = []
        self._next_id = 0
        self._generation = registry.generation
        self.stats: Dict[str, int] = {
            "submitted": 0, "served": 0, "shed": 0, "expired": 0,
            "steps": 0, "blocks": 0}
        self._latencies: List[float] = []
        self._tel = (telemetry if telemetry is not None
                     and telemetry.enabled else None)
        if self._tel is not None:
            reg = self._tel.metrics
            self._m_depth = reg.gauge(
                "repro_serve_queue_depth", "tickets waiting in the "
                "bounded queue")
            self._m_tickets = reg.counter(
                "repro_serve_tickets_total", "ticket dispositions, "
                "labelled by terminal status")
            self._m_occupancy = reg.histogram(
                "repro_serve_batch_occupancy",
                "admitted rows / slots per served block",
                buckets=(0.125, 0.25, 0.5, 0.75, 0.9, 1.0))
            self._m_latency = reg.histogram(
                "repro_serve_ticket_latency_seconds",
                "submit-to-done latency (engine clock units)",
                buckets=(1e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5,
                         1.0, 5.0))
            # label keys resolved once; submit/done fire per ticket
            self._t_submitted = self._m_tickets.labels(
                status="submitted")
            self._t_shed = self._m_tickets.labels(status=SHED)
            self._t_expired = self._m_tickets.labels(status=EXPIRED)
            self._t_done = self._m_tickets.labels(status=DONE)
            self._g_depth = self._m_depth.labels()

    # -- admission ------------------------------------------------------

    def submit(self, name: str, X, *,
               deadline_s: Optional[float] = None) -> Ticket:
        """Enqueue queries for ``name``.  ``X`` is one query row (n,) or
        a block (rows, n); validation is EAGER — feature-dim/dtype
        mismatches raise ``ValueError`` naming ``X`` here, at the public
        boundary, never inside a mixed batch.  Returns the ticket
        (status "shed" when the bounded queue was full)."""
        model = self.registry._model(name)   # KeyError on unknown name
        # host copy FIRST: validation then runs entirely on host (no
        # per-submit device round trip churning the dispatch queue)
        X = np.asarray(X)
        if X.ndim == 1:
            X = X[None, :]
        X = validate_queries(model.op, X, name="X")
        now = self.clock()
        ticket = Ticket(id=self._next_id, name=name, X=X, t_submit=now,
                        deadline=(None if deadline_s is None
                                  else now + deadline_s))
        self._next_id += 1
        self.stats["submitted"] += 1
        if self._tel is not None:
            self._t_submitted.inc()
        if len(self._queue) >= self.max_queue:
            ticket.status = SHED
            self.stats["shed"] += 1
            if self._tel is not None:
                self._t_shed.inc()
            return ticket
        self._queue.append(ticket)
        if self._tel is not None:
            self._g_depth.set(len(self._queue))
        return ticket

    @property
    def pending(self) -> int:
        return len(self._queue)

    def warmup(self) -> int:
        """Pre-compile every group's bucket set (delegates to the
        registry).  After this, ``step`` never compiles — the
        no-recompile invariant ``serve_cache_size`` asserts."""
        return self.registry.warmup()

    # -- drain ----------------------------------------------------------

    def step(self) -> int:
        """One drain cycle; returns the number of rows served.

        Retire-expired -> admit-per-group -> serve-one-block-per-group
        -> scatter results.  Registry generation is sampled ONCE at the
        top: a refit swap that lands mid-step is picked up next step
        (tickets already admitted finish on the group snapshot they
        were batched against — never a mix)."""
        if self._tel is None:
            return self._step()
        with self._tel.span("engine_step", "serve",
                            pending=len(self._queue)):
            served = self._step()
        self._g_depth.set(len(self._queue))
        return served

    def _step(self) -> int:
        self.stats["steps"] += 1
        if self._generation != self.registry.generation:
            self._generation = self.registry.generation
        now = self.clock()
        survivors: List[Ticket] = []
        for t in self._queue:
            if t.deadline is not None and now > t.deadline:
                t.status = EXPIRED
                self.stats["expired"] += 1
                if self._tel is not None:
                    self._t_expired.inc()
            else:
                survivors.append(t)
        self._queue = survivors

        # admit: FIFO per group, up to ``slots`` rows each
        by_group: Dict[int, List[Ticket]] = {}
        admitted_rows: Dict[int, int] = {}
        admitted: List[Ticket] = []
        for t in self._queue:
            group = self.registry.group(t.name)
            gid = id(group)
            used = admitted_rows.get(gid, 0)
            if used + t.rows > self.slots:
                continue                 # next step; FIFO within group
            by_group.setdefault(gid, []).append(t)
            admitted_rows[gid] = used + t.rows
            admitted.append(t)
        if not admitted:
            return 0
        admitted_ids = {t.id for t in admitted}
        self._queue = [t for t in self._queue if t.id not in admitted_ids]

        served = 0
        for gid, tickets in by_group.items():
            group = self.registry.group(tickets[0].name)
            # host-side batch assembly, ALREADY padded to the jit
            # bucket: one zeros buffer, one H2D transfer, one block
            # call — no device-side concat/pad, so no hidden per-size
            # compiles beyond the warmed bucket set
            q = sum(t.rows for t in tickets)
            qb = group.predictor.block_shape(q)
            buf = np.zeros((qb, group.op.feature_dim),
                           dtype=np.dtype(group.op.dtype))
            lo = 0
            for t in tickets:
                buf[lo:lo + t.rows] = t.X
                lo += t.rows
            out = group.serve(jnp.asarray(buf))  # (qb, F): every model
            # ONE transfer back, then host-view scatter: per-ticket jnp
            # slicing would pay a device dispatch per ticket
            out_host = np.asarray(jax.device_get(out))[:q]
            t_done = self.clock()
            lo = 0
            for t in tickets:
                col = group.col[t.name]
                t.result = out_host[lo:lo + t.rows, col]
                lo += t.rows
                t.status = DONE
                t.t_done = t_done
                self._latencies.append(t.latency)
                served += t.rows
                if self._tel is not None:
                    self._t_done.inc()
                    self._m_latency.observe(t.latency)
            self.stats["served"] += len(tickets)
            self.stats["blocks"] += 1
            if self._tel is not None:
                self._m_occupancy.observe(q / self.slots)
        return served

    def run_until_idle(self, *, max_steps: int = 10_000) -> int:
        """Drain the queue completely; returns total rows served."""
        total = 0
        for _ in range(max_steps):
            if not self._queue:
                return total
            total += self.step()
        raise RuntimeError(
            f"queue failed to drain within {max_steps} steps "
            f"({len(self._queue)} tickets still pending)")

    # -- observability --------------------------------------------------

    def latency_quantiles(self, qs=(0.5, 0.99)) -> Dict[str, float]:
        """Observed submit-to-done latency quantiles (engine clock
        units) over every served ticket — the measured side of the
        fig9 modeled-vs-measured comparison."""
        if not self._latencies:
            return {f"p{int(q * 100)}": float("nan") for q in qs}
        lat = np.asarray(self._latencies, np.float64)
        return {f"p{int(q * 100)}": float(np.quantile(lat, q))
                for q in qs}
