"""Model serving subsystem (DESIGN.md §13): persistable model
artifacts, a multi-model registry deduplicating device-resident state,
a continuous-batching engine with deadlines and bounded-queue load
shedding, and online refit with atomic weight swap.

    from repro.serve import ModelRegistry, ServingEngine, save_model

    est.fit(A, y); est.save("artifacts/churn")      # layer 1
    reg = ModelRegistry()
    reg.load("churn", "artifacts/churn")            # layers 1+2
    engine = ServingEngine(reg, slots=256)          # layer 3
    engine.warmup()
    t = engine.submit("churn", Xq, deadline_s=0.1)
    engine.step(); print(t.result)
    reg.refit("churn", X_new, y_new)                # layer 4
"""
from .artifacts import (MANIFEST_VERSION, ServableModel, load_model,
                        save_model)
from .engine import DONE, EXPIRED, PENDING, SHED, ServingEngine, Ticket
from .registry import ModelRegistry, ServeGroup, operator_key

__all__ = [
    "MANIFEST_VERSION", "ServableModel", "load_model", "save_model",
    "ModelRegistry", "ServeGroup", "operator_key",
    "ServingEngine", "Ticket", "PENDING", "DONE", "EXPIRED", "SHED",
]
