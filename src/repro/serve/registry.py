"""Multi-model registry with shared device-resident state (DESIGN.md
§13, layer 2 of ``repro.serve``).

Production kernel-method deployments serve MANY models against the same
data: a regularization grid's survivors, per-segment classifiers on one
embedding table, an A/B pair.  Loading each model's operator separately
duplicates the dominant memory — the (m, n) training features (exact)
or the (m, l) factor (Nystrom) — once per model.  This registry applies
the fleet trick (DESIGN.md §10) at serving time:

  * models whose operators carry the SAME data (content-hashed:
    ``operator_key``) join one *group* holding a single device-resident
    ``GramOperator``;
  * a group's weights stack into ONE (m, F) matrix (each column a
    model's ``serve_w`` — per-model scalars like 1/lam folded in, since
    serving is linear in w), served through one
    ``serve_weights``/``serve_block`` call per query block — F models
    for one KMV sweep;
  * ``refit(name, X_new, y_new)`` absorbs fresh labeled traffic through
    the facade's existing ``warm_start=`` path (old alpha zero-padded
    over the new rows; one representation build) and ATOMICALLY swaps
    the new model in: group state is rebuilt fully before the name is
    repointed, and a generation counter tells long-lived engines to
    refresh their snapshots — in-flight batches finish on the old
    weights, the next batch sees the new ones, nothing ever sees a mix.

The registry is the model-management layer only; request batching,
deadlines and load shedding live in ``serve.engine.ServingEngine``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predict import BatchedPredictor, validate_queries
from .artifacts import ServableModel, load_model, save_model


def operator_key(op) -> str:
    """Content identity of an operator's device state: sha1 over the
    data leaves' bytes plus the static treedef repr.  Two models fitted
    (or restored from artifacts written months apart) against one X and
    one kernel config hash identically — the dedup key that lets the
    registry keep ONE device-resident copy.  Host transfer happens once
    per registration, never on the serving path."""
    leaves, treedef = jax.tree_util.tree_flatten(op)
    h = hashlib.sha1(repr(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


class ServeGroup:
    """One shared operator + the stacked weights of every member model.

    ``W`` is (m, F) with ``col[name]`` naming each model's column; the
    ``BatchedPredictor`` over (op, W) precomputes ``serve_weights`` once
    for the whole group and answers any query block with (q, F) values
    in one reduction.  Groups are rebuilt WHOLE on membership change
    (registration order preserved) — cheap host work, and the old
    predictor stays valid for any batch already formed."""

    def __init__(self, op, *, predict_batch: int = 1024):
        self.op = op
        self.names: List[str] = []
        self.col: Dict[str, int] = {}
        self.W: Optional[jnp.ndarray] = None
        self.predictor: Optional[BatchedPredictor] = None
        self.predict_batch = predict_batch

    @property
    def size(self) -> int:
        return len(self.names)

    def rebuild(self, models: Dict[str, ServableModel]) -> None:
        self.col = {n: j for j, n in enumerate(self.names)}
        self.W = jnp.stack([models[n].serve_w for n in self.names],
                           axis=1)
        self.predictor = BatchedPredictor(self.op, self.W,
                                          batch=self.predict_batch)

    def serve(self, Xq) -> jnp.ndarray:
        """(q, F) decision values/predictions for every member."""
        return self.predictor(Xq)

    def warmup(self) -> int:
        return self.predictor.warmup()


class ModelRegistry:
    """Layer-2 of ``repro.serve``: named models, deduped device state.

    ``register`` accepts a fitted estimator or a ``ServableModel``;
    ``load``/``save`` go through the artifact layer; ``predict`` serves
    one model's queries through its group's stacked predictor (the same
    path the engine batches into); ``refit`` grows a model's training
    set in place.  ``generation`` increments on every mutation that
    changes what serving would return — engines snapshot group state
    and refresh when it moves.
    """

    def __init__(self, *, predict_batch: int = 1024):
        self.models: Dict[str, ServableModel] = {}
        self._groups: Dict[str, ServeGroup] = {}
        self._group_of: Dict[str, str] = {}
        self.predict_batch = predict_batch
        self.generation = 0

    # -- membership -----------------------------------------------------

    def register(self, name: str, model) -> ServableModel:
        """Add (or replace) a named model, joining the group holding its
        operator's data if one exists."""
        from repro.api import KernelRidge, KernelSVM

        if isinstance(model, (KernelSVM, KernelRidge)):
            model = ServableModel.from_estimator(model)
        if not isinstance(model, ServableModel):
            raise TypeError(f"register expects a fitted estimator or a "
                            f"ServableModel, got {type(model).__name__}")
        if name in self.models:
            self.unregister(name)
        key = operator_key(model.op)
        group = self._groups.get(key)
        if group is None:
            group = ServeGroup(model.op,
                               predict_batch=self.predict_batch)
            self._groups[key] = group
        else:
            # share the group's device-resident operator: the new
            # model's (identical-content) copy is dropped on the floor
            model = dataclasses.replace(model, op=group.op)
        self.models[name] = model
        group.names.append(name)
        self._group_of[name] = key
        group.rebuild(self.models)
        self.generation += 1
        return model

    def unregister(self, name: str) -> None:
        key = self._group_of.pop(name)
        group = self._groups[key]
        group.names.remove(name)
        del self.models[name]
        if group.names:
            group.rebuild(self.models)
        else:
            del self._groups[key]
        self.generation += 1

    def save(self, name: str, directory: str) -> str:
        return save_model(directory, self._model(name))

    def load(self, name: str, directory: str) -> ServableModel:
        return self.register(name, load_model(directory))

    # -- introspection --------------------------------------------------

    def _model(self, name: str) -> ServableModel:
        if name not in self.models:
            raise KeyError(f"no model {name!r} registered (have "
                           f"{sorted(self.models)})")
        return self.models[name]

    def group(self, name: str) -> ServeGroup:
        return self._groups[self._group_of[self._check_name(name)]]

    def _check_name(self, name: str) -> str:
        self._model(name)
        return name

    @property
    def n_groups(self) -> int:
        return len(self._groups)

    def groups(self) -> List[ServeGroup]:
        return list(self._groups.values())

    def warmup(self) -> int:
        """Pre-compile every group's predictor buckets; returns total
        bucket count.  After this, steady traffic through ``predict`` /
        the engine never recompiles."""
        return sum(g.warmup() for g in self._groups.values())

    # -- serving --------------------------------------------------------

    def predict(self, name: str, Xq) -> jnp.ndarray:
        """One model's values for a query block — served through the
        GROUP predictor (all F columns computed, one selected), so this
        path and the engine's batched path execute the identical
        compiled computation."""
        model = self._model(name)
        Xq = validate_queries(model.op, Xq, name="Xq")
        group = self.group(name)
        out = group.serve(Xq)
        return out[:, group.col[name]]

    # -- online refit ---------------------------------------------------

    def refit(self, name: str, X_new, y_new, *, options=None):
        """Absorb fresh labeled traffic into a deployed model: fit on
        ``concat(X_old, X_new)`` warm-started from the current alpha
        (zero-padded over the new rows — the facade's existing
        ``warm_start=`` path, one representation build), then atomically
        swap the served weights.  Returns the new fit's ``FitResult``.

        The refitted model's operator covers a DIFFERENT training set,
        so it leaves its old group (siblings keep the old shared
        operator) and joins/forms the group matching the grown data.
        Convergence: run with a tolerance (``options`` overrides the
        stored ones) and the warm start is equivalent to a cold fit on
        the combined data within the stopping tolerance — asserted by
        the serve test suite and the fig9 gate.
        """
        from repro.api import KernelRidge, KernelSVM

        model = self._model(name)
        X_new = jnp.asarray(X_new)
        y_new = jnp.asarray(y_new)
        validate_queries(model.op, X_new, name="X_new")
        if y_new.shape[0] != X_new.shape[0]:
            raise ValueError(
                f"y_new has {y_new.shape[0]} rows but X_new has "
                f"{X_new.shape[0]} — refit needs one label per row")
        A_old = model.features
        A = jnp.concatenate([A_old, X_new], axis=0)
        y = jnp.concatenate([model.y, y_new], axis=0)
        a0 = jnp.concatenate(
            [model.alpha, jnp.zeros(X_new.shape[0], model.alpha.dtype)])
        opts = options if options is not None else model.options
        if model.problem == "ksvm":
            est = KernelSVM(C=model.cfg.C, loss=model.cfg.loss,
                            kernel=model.cfg.kernel, options=opts,
                            predict_batch=self.predict_batch)
        else:
            est = KernelRidge(lam=model.cfg.lam, kernel=model.cfg.kernel,
                              options=opts,
                              predict_batch=self.predict_batch)
        result = est.fit(A, y, warm_start=a0)
        # atomic swap: the new group state is fully built by register()
        # before the name points at it; generation bumps exactly once
        # per visible change, so an engine refreshes at a step boundary
        # and never serves a half-updated group
        self.register(name, est)
        return result
