"""HLO-text analysis helpers (import-safe: no jax device-state effects).

collective_bytes: sum operand bytes of every collective op in an HLO
module — the §Roofline collective term.  While-loop bodies appear once in
the text; the dry-run corrects for layer-scan trip counts with its
two-point unrolled probes (see dryrun.extrapolated_costs).
"""
from __future__ import annotations

import re

# result-shape form: `%x = f32[a,b]{...} all-reduce(...)` (modern HLO
# prints operands as bare refs), with an operand-shape fallback for the
# older inline form.
COLLECTIVE_LINE_RE = re.compile(
    r"= ([^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|f64|s64|pred|c64)"
                      r"\[([0-9,]*)\]")

DTYPE_BYTES = {"f64": 8, "s64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    nbytes = 0
    for sm in SHAPE_RE.finditer(text):
        dt, dims = sm.group(1), sm.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * DTYPE_BYTES[dt]
    return nbytes


def collective_bytes(hlo_text: str) -> dict:
    per_kind = {}
    for m in COLLECTIVE_LINE_RE.finditer(hlo_text):
        kind = m.group(2)
        # prefer operand shapes (inline form); fall back to result shape
        nbytes = _shape_bytes(m.group(3)) or _shape_bytes(m.group(1))
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
    return per_kind


def count_collectives(hlo_text: str) -> dict:
    """Number of collective OPS per kind (latency-term proxy: the paper's
    'messages' count)."""
    out = {}
    for m in COLLECTIVE_LINE_RE.finditer(hlo_text):
        out[m.group(2)] = out.get(m.group(2), 0) + 1
    return out
