"""Production mesh definitions.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets the host-device-count env var
before any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests and
    examples on CPU."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link (~4 links usable / chip)
    "ici_links": 4,
    "hbm_bytes": 16e9,
}
