"""ShapeDtypeStruct stand-ins for every model input of every
(architecture x shape) cell — weak-type-correct, shardable, and never
allocating device memory.  The shardings are attached directly to the
ShapeDtypeStructs so a plain ``jax.jit(step).lower(**specs)`` carries the
full distribution plan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import (ModelConfig, ShapeConfig, abstract_params,
                          init_decode_state, tree_pspecs)
from repro.models.config import ATTN, DENSE, MOE
from repro.models.sharding import MeshRules
from repro.optim.adamw import adamw_init


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(rules: MeshRules, tree, pspecs):
    return jax.tree.map(
        lambda leaf, spec: _sds(leaf.shape, leaf.dtype, rules.named(spec)),
        tree, pspecs)


def param_specs(cfg: ModelConfig, rules: MeshRules):
    """Abstract params with FSDP+TP shardings attached."""
    aparams = abstract_params(cfg)
    return _with_shardings(rules, aparams, tree_pspecs(rules, aparams))


def opt_specs(cfg: ModelConfig, rules: MeshRules):
    """AdamW moments mirror the param shardings (ZeRO-style)."""
    aparams = abstract_params(cfg)
    aopt = jax.eval_shape(adamw_init, aparams)
    pspecs = tree_pspecs(rules, aparams)
    return {
        "m": _with_shardings(rules, aopt["m"], pspecs),
        "v": _with_shardings(rules, aopt["v"], pspecs),
        "step": _sds((), jnp.int32, rules.named(P())),
    }


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules):
    """Training / prefill batch: tokens+labels (B, S) int32, plus the
    frontend-stub inputs ([vlm]: 3-stream M-RoPE positions; [audio]:
    precomputed frame embeddings)."""
    B, S = shape.global_batch, shape.seq_len
    bsh = rules.named(rules.fit((B, S), [rules.batch_axes, None]))
    batch = {"tokens": _sds((B, S), jnp.int32, bsh)}
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32, bsh)
    if cfg.mrope:
        psh = rules.named(rules.fit((3, B, S),
                                    [None, rules.batch_axes, None]))
        batch["positions"] = _sds((3, B, S), jnp.int32, psh)
    if cfg.encoder_layers:
        esh = rules.named(rules.fit(
            (B, cfg.encoder_seq, cfg.d_model),
            [rules.batch_axes, None, None]))
        batch["audio_embed"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                    jnp.float32, esh)
    return batch


def _cache_pspec(rules: MeshRules, cfg: ModelConfig, path: str, leaf):
    """Decode-state sharding rules (all caches carry a leading stacked
    period axis L).

    Attention KV (L,B,S,kv,hd): batch over (pod,data) when divisible and
    kv-heads over model when divisible; with B=1 (long_500k) the cache
    length S is sharded instead (sequence-parallel cache).
    MLA c-cache (L,B,S,r): S over model.  Mamba states: channels/heads
    over model.
    """
    shape = leaf.shape
    bax, tp, F = rules.batch_axes, rules.tp, rules.fsdp
    if path.endswith("pos"):
        return P()
    b_ok = shape[1] % max(rules.axis_size(bax), 1) == 0 if len(shape) > 1 \
        else False
    b = bax if b_ok else None

    # which block kind does this cache belong to?
    kind = None
    parts = path.split("/")
    if parts[0] == "caches" and len(parts) > 1:
        kind = cfg.pattern[int(parts[1])]
    elif parts[0] in ("shared_cache", "cross_kv"):
        kind = ATTN

    if kind in (DENSE, MOE, ATTN):
        if cfg.attn_type == "mla" and parts[0] == "caches":
            # (L, B, S, r) compressed cache
            return rules.fit(shape, [None, b, tp, None])
        kv_ok = shape[3] % rules.axis_size(tp) == 0
        if b_ok:
            return rules.fit(shape, [None, b, None if kv_ok else tp,
                                     tp if kv_ok else None, None])
        return rules.fit(shape, [None, None, F,
                                 tp if kv_ok else None, None])
    # mamba states
    if len(shape) == 5:                        # mamba2 h (L,B,nh,hd,n)
        return rules.fit(shape, [None, b, tp, None, None])
    if "0" == parts[-1] or shape[-1] > cfg.ssm_state:
        # conv state (L,B,K-1,C): channels last
        return rules.fit(shape, [None, b, None, tp])
    return rules.fit(shape, [None, b, tp, None])  # mamba1 h (L,B,di,n)


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig,
                       rules: MeshRules):
    B, S = shape.global_batch, shape.seq_len
    astate = jax.eval_shape(
        lambda: init_decode_state(cfg, B, S,
                                  with_encoder=bool(cfg.encoder_layers)))

    def walk(path_keys, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path_keys)
        spec = _cache_pspec(rules, cfg, path, leaf)
        return _sds(leaf.shape, leaf.dtype, rules.named(spec))

    return jax.tree_util.tree_map_with_path(walk, astate)


def decode_token_specs(shape: ShapeConfig, rules: MeshRules):
    B = shape.global_batch
    sh = rules.named(rules.fit((B, 1), [rules.batch_axes, None]))
    return _sds((B, 1), jnp.int32, sh)
