"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (CPU in this container; the same driver
works on a TPU slice by growing the mesh).  Features exercised:
deterministic data pipeline, AdamW, microbatching, s-step deferred
gradient sync (--defer-s), async checkpointing + preemption-safe resume,
loss logging.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import init_params
from repro.models.sharding import MeshRules
from repro.optim import AdamWConfig, adamw_init
from repro.train import CheckpointManager, make_train_step
from repro.train.train_step import TrainConfig, make_defer_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--defer-s", type=int, default=0,
                    help=">0: use the s-step deferred-allreduce train step")
    ap.add_argument("--mesh", default="1x1",
                    help="data x model mesh, e.g. 2x4")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = dataclasses.replace(cfg, remat="none")
    acfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps)
    tcfg = TrainConfig(microbatches=args.microbatches,
                       defer_s=max(args.defer_s, 1))

    d, m = (int(x) for x in args.mesh.split("x"))
    rules = None
    if d * m > 1:
        mesh = jax.make_mesh((d, m), ("data", "model"))
        rules = MeshRules(mesh)

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)
    params = init_params(jax.random.key(args.seed), cfg)
    opt = adamw_init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()} defer_s={args.defer_s}")

    if args.defer_s > 0:
        assert rules is not None, "--defer-s needs a multi-device mesh"
        step_fn = make_defer_train_step(cfg, acfg, tcfg, rules)
    else:
        step_fn = make_train_step(cfg, acfg, tcfg, rules)

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep_last=2,
                                save_every=args.ckpt_every)
        restored, meta = mgr.restore_latest(
            template={"params": params, "opt": opt})
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start = meta["step"]
            print(f"resumed from step {start}")

    t0 = time.time()
    losses = []
    for s in range(start, args.steps):
        batch = pipe.batch(s)
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if (s + 1) % args.log_every == 0:
            dt = (time.time() - t0) / max(s + 1 - start, 1)
            print(f"step {s+1} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step",
                  flush=True)
        if mgr and mgr.should_save(s + 1):
            mgr.save_async(s + 1, {"params": params, "opt": opt})
    if mgr:
        mgr.save_async(args.steps, {"params": params, "opt": opt})
        mgr.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
