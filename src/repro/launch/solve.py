"""End-to-end kernel-method driver (the paper's workload), on the
``repro.api`` facade.

    PYTHONPATH=src python -m repro.launch.solve --problem ksvm \
        --dataset duke --s 32 --H 2048
    PYTHONPATH=src python -m repro.launch.solve --problem krr \
        --dataset abalone --b 64 --s 16 --H 1024 --tol 1e-4

Solves K-SVM (DCD / s-step DCD) or K-RR (BDCD / s-step BDCD) on a
synthetic dataset matching the paper's Table 2 scales, reports duality
gap / relative residual, accuracy, classical-vs-s-step agreement, and
the modeled communication cost of each run (``FitResult.comm``).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.api import KernelRidge, KernelSVM, SolverOptions
from repro.core import (KernelConfig, krr_closed_form, ksvm_duality_gap,
                        relative_solution_error)
from repro.data import synthetic


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=("ksvm", "krr"), default="ksvm")
    ap.add_argument("--dataset", default="duke",
                    choices=list(synthetic.PAPER_DATASETS))
    ap.add_argument("--kernel", default="rbf",
                    choices=("linear", "polynomial", "rbf"))
    ap.add_argument("--loss", default="l1", choices=("l1", "l2"))
    ap.add_argument("--C", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--H", type=int, default=1024)
    ap.add_argument("--s", type=int, default=32)
    ap.add_argument("--b", type=int, default=1)
    ap.add_argument("--layout", default="serial",
                    choices=("serial", "1d", "2d"))
    ap.add_argument("--tol", type=float, default=0.0,
                    help="early-stop tolerance (0 = run the full budget)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    kern = KernelConfig(args.kernel, degree=3, coef0=0.0, sigma=1.0)
    A, y = synthetic.load(args.dataset, jax.random.key(args.seed))
    m = A.shape[0]
    print(f"{args.problem} on {args.dataset}: m={m} n={A.shape[1]} "
          f"kernel={args.kernel} H={args.H} s={args.s} "
          f"layout={args.layout} tol={args.tol}")

    def opts(method, s=1):
        return SolverOptions(method=method, s=s, b=max(args.b, 1),
                             layout=args.layout, tol=args.tol,
                             max_iters=args.H, seed=args.seed + 1)

    if args.problem == "ksvm":
        ref = KernelSVM(C=args.C, loss=args.loss, kernel=kern,
                        options=opts("classical"))
        r_ref = ref.fit(A, y)
        est = KernelSVM(C=args.C, loss=args.loss, kernel=kern,
                        options=opts("sstep", args.s))
        r_s = est.fit(A, y)
        gap = float(ksvm_duality_gap(A, y, r_s.alpha, est.cfg))
        acc = float(jnp.mean(est.predict(A) == y))
        print(f"DCD {r_ref.wall_time_s:.2f}s | s-step "
              f"{r_s.wall_time_s:.2f}s "
              f"({r_ref.wall_time_s / r_s.wall_time_s:.2f}x on this host)")
        print(f"duality gap {gap:.3e} | train acc {acc:.3f} | "
              f"max|a_s - a_dcd| = "
              f"{float(jnp.max(jnp.abs(r_s.alpha - r_ref.alpha))):.3e}")
    else:
        reg_ref = KernelRidge(lam=args.lam, kernel=kern,
                              options=opts("classical"))
        r_ref = reg_ref.fit(A, y)
        reg = KernelRidge(lam=args.lam, kernel=kern,
                          options=opts("sstep", args.s))
        r_s = reg.fit(A, y)
        astar = krr_closed_form(A, y, reg.cfg)
        print(f"BDCD {r_ref.wall_time_s:.2f}s | s-step "
              f"{r_s.wall_time_s:.2f}s "
              f"({r_ref.wall_time_s / r_s.wall_time_s:.2f}x on this host)")
        print(f"rel err vs closed form: bdcd="
              f"{float(relative_solution_error(r_ref.alpha, astar)):.3e} "
              f"sstep={float(relative_solution_error(r_s.alpha, astar)):.3e}")

    for name, r in (("classical", r_ref), ("sstep", r_s)):
        stop = (f"converged@{r.iters_run}" if r.converged
                else f"budget({r.iters_run})")
        print(f"{name:9s}: {stop} rounds={r.rounds_run} "
              f"modeled comm {r.comm['words']:.3e} words / "
              f"{r.comm['msgs']:.1f} msgs / {r.comm['time']*1e3:.2f} ms "
              f"(P={r.comm['P']})")


if __name__ == "__main__":
    main()
