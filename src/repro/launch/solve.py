"""End-to-end kernel-method driver (the paper's workload).

    PYTHONPATH=src python -m repro.launch.solve --problem ksvm \
        --dataset duke --s 32 --H 2048
    PYTHONPATH=src python -m repro.launch.solve --problem krr \
        --dataset abalone --b 64 --s 16 --H 1024

Solves K-SVM (DCD / s-step DCD) or K-RR (BDCD / s-step BDCD) on a
synthetic dataset matching the paper's Table 2 scales, reports duality
gap / relative error, accuracy, and classical-vs-s-step agreement.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (KernelConfig, KRRConfig, SVMConfig, bdcd_krr,
                        block_schedule, coordinate_schedule, dcd_ksvm,
                        krr_closed_form, ksvm_duality_gap, ksvm_predict,
                        relative_solution_error, sstep_bdcd_krr,
                        sstep_dcd_ksvm)
from repro.data import synthetic


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=("ksvm", "krr"), default="ksvm")
    ap.add_argument("--dataset", default="duke",
                    choices=list(synthetic.PAPER_DATASETS))
    ap.add_argument("--kernel", default="rbf",
                    choices=("linear", "polynomial", "rbf"))
    ap.add_argument("--loss", default="l1", choices=("l1", "l2"))
    ap.add_argument("--C", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--H", type=int, default=1024)
    ap.add_argument("--s", type=int, default=32)
    ap.add_argument("--b", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    kern = KernelConfig(args.kernel, degree=3, coef0=0.0, sigma=1.0)
    A, y = synthetic.load(args.dataset, jax.random.key(args.seed))
    m = A.shape[0]
    print(f"{args.problem} on {args.dataset}: m={m} n={A.shape[1]} "
          f"kernel={args.kernel} H={args.H} s={args.s}")
    a0 = jnp.zeros(m)

    if args.problem == "ksvm":
        cfg = SVMConfig(C=args.C, loss=args.loss, kernel=kern)
        sched = coordinate_schedule(jax.random.key(args.seed + 1),
                                    args.H, m)
        t0 = time.time()
        a_ref, _ = dcd_ksvm(A, y, a0, sched, cfg)
        jax.block_until_ready(a_ref)
        t_ref = time.time() - t0
        t0 = time.time()
        a_s, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=args.s)
        jax.block_until_ready(a_s)
        t_s = time.time() - t0
        gap = float(ksvm_duality_gap(A, y, a_s, cfg))
        acc = float(jnp.mean(jnp.sign(
            ksvm_predict(A, y, a_s, A, cfg)) == y))
        print(f"DCD {t_ref:.2f}s | s-step {t_s:.2f}s "
              f"({t_ref/t_s:.2f}x on this host)")
        print(f"duality gap {gap:.3e} | train acc {acc:.3f} | "
              f"max|a_s - a_dcd| = "
              f"{float(jnp.max(jnp.abs(a_s - a_ref))):.3e}")
    else:
        cfg = KRRConfig(lam=args.lam, kernel=kern)
        b = max(args.b, 1)
        sched = block_schedule(jax.random.key(args.seed + 1), args.H, m, b)
        astar = krr_closed_form(A, y, cfg)
        t0 = time.time()
        a_ref, _ = bdcd_krr(A, y, a0, sched, cfg)
        jax.block_until_ready(a_ref)
        t_ref = time.time() - t0
        t0 = time.time()
        a_s, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=args.s)
        jax.block_until_ready(a_s)
        t_s = time.time() - t0
        print(f"BDCD {t_ref:.2f}s | s-step {t_s:.2f}s "
              f"({t_ref/t_s:.2f}x on this host)")
        print(f"rel err vs closed form: bdcd="
              f"{float(relative_solution_error(a_ref, astar)):.3e} "
              f"sstep={float(relative_solution_error(a_s, astar)):.3e}")


if __name__ == "__main__":
    main()
