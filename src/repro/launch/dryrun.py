"""Multi-pod dry-run: prove that every (architecture x input-shape x mesh)
cell lowers AND compiles under the production sharding plan, and extract
the roofline terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax
# locks the device count on first init, so this MUST precede every other
# import (including repro.*, which imports jax).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402

from repro.launch.hlo_analysis import collective_bytes  # noqa: E402
from repro.configs import ARCHS, get_config           # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.launch import specs as S                   # noqa: E402
from repro.models import ModelConfig, forward, loss_fn  # noqa: E402
from repro.models.config import SHAPES                # noqa: E402
from repro.models.sharding import MeshRules           # noqa: E402
from repro.optim import AdamWConfig                   # noqa: E402
from repro.train.train_step import TrainConfig, make_train_step  # noqa: E402
from repro.models import decode_step                  # noqa: E402

def build_step(cfg: ModelConfig, shape_name: str, rules: MeshRules,
               microbatches: int = 1, unroll: bool = False):
    """Return (fn, example_args) for the cell's step function."""
    shape = SHAPES[shape_name]
    pspecs = S.param_specs(cfg, rules)
    if shape.kind == "train":
        tcfg = TrainConfig(microbatches=microbatches)
        acfg = AdamWConfig()
        step = make_train_step(cfg, acfg, tcfg, rules, unroll=unroll)
        args = (pspecs, S.opt_specs(cfg, rules),
                S.batch_specs(cfg, shape, rules))
        return step, args
    if shape.kind == "prefill":
        def prefill(params, batch):
            return forward(params, cfg, batch["tokens"],
                           positions=batch.get("positions"),
                           audio_embed=batch.get("audio_embed"),
                           rules=rules, unroll=unroll)
        return jax.jit(prefill), (pspecs, S.batch_specs(cfg, shape, rules))
    # decode
    def serve(params, state, tokens):
        return decode_step(params, cfg, state, tokens, rules=rules,
                           unroll=unroll)
    return jax.jit(serve, donate_argnums=(1,)), (
        pspecs, S.decode_state_specs(cfg, SHAPES[shape_name], rules),
        S.decode_token_specs(SHAPES[shape_name], rules))


def _probe_cfg(cfg: ModelConfig, k: int):
    """Depth-k-periods unrolled clone for the two-point cost probes."""
    import dataclasses
    repl = {"n_layers": k * len(cfg.pattern)}
    if cfg.encoder_layers:
        repl["encoder_layers"] = k
    return dataclasses.replace(cfg, **repl)


def _compile_costs(cfg, shape_name, rules, microbatches, unroll):
    step, args = build_step(cfg, shape_name, rules, microbatches, unroll)
    lowered = step.lower(*args)
    compiled = lowered.compile()
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll,
        "coll_total": float(sum(coll.values())),
    }, compiled


def extrapolated_costs(cfg: ModelConfig, shape_name: str, rules: MeshRules,
                       microbatches: int = 1):
    """XLA's cost_analysis counts a while (layer-scan) body ONCE, so the
    full-config numbers miss (n_periods - 1) layers.  Lower UNROLLED probe
    configs at depth 1 and 2 periods: cost(k) = a + b*k, then extrapolate
    to the real depth.  (Verified: cost_analysis is per-device and exactly
    misses scan trip counts — see tests/test_dryrun_probes.py.)"""
    c1, _ = _compile_costs(_probe_cfg(cfg, 1), shape_name, rules,
                           microbatches, unroll=True)
    c2, _ = _compile_costs(_probe_cfg(cfg, 2), shape_name, rules,
                           microbatches, unroll=True)
    K = cfg.n_periods
    out = {}
    for key in ("flops", "bytes", "coll_total"):
        b = c2[key] - c1[key]
        out[key] = c1[key] + b * (K - 1)
    coll = {}
    for kind in set(c1["coll"]) | set(c2["coll"]):
        b = c2["coll"].get(kind, 0) - c1["coll"].get(kind, 0)
        coll[kind] = c1["coll"].get(kind, 0) + b * (K - 1)
    out["coll"] = coll
    out["per_period"] = {k: c2[k] - c1[k]
                         for k in ("flops", "bytes", "coll_total")}
    return out


def cell_supported(cfg: ModelConfig, shape_name: str):
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 512k-KV decode is "
                       "quadratic-history; skipped per assignment")
    return True, ""


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 1, extra=None):
    cfg = get_config(arch)
    if extra:
        import dataclasses
        cfg = dataclasses.replace(cfg, **extra)
    ok, why = cell_supported(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = MeshRules(mesh)
    n_chips = mesh.devices.size

    # 1) full-config lower + compile: THE pass/fail proof for the cell,
    #    plus memory_analysis of the real program.
    t0 = time.time()
    step, args = build_step(cfg, shape_name, rules, microbatches)
    lowered = step.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()

    # 2) two-point unrolled probes -> trip-count-corrected per-DEVICE costs
    t3 = time.time()
    costs = extrapolated_costs(cfg, shape_name, rules, microbatches)
    t4 = time.time()

    flops = costs["flops"]                 # per-device, all layers
    bytes_accessed = costs["bytes"]
    coll_total = costs["coll_total"]

    # model flops: 6*N*D for train (fwd+bwd), 2*N_active*D for inference
    shape = SHAPES[shape_name]
    n_tok = (shape.global_batch * shape.seq_len
             if shape.kind in ("train", "prefill") else shape.global_batch)
    n_act = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_act * n_tok

    result.update({
        "status": "ok",
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "probe_s": round(t4 - t3, 1),
        "n_chips": int(n_chips),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes": costs["coll"],
        "collective_bytes_total": coll_total,
        "t_compute": flops / HW["peak_flops_bf16"],
        "t_memory": bytes_accessed / HW["hbm_bw"],
        "t_collective": coll_total / (HW["ici_bw"] * HW["ici_links"]),
        "params": cfg.param_count(),
        "active_params": n_act,
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flop_ratio": (model_flops / n_chips) / max(flops, 1.0),
    })
    terms = {k: result[k] for k in ("t_compute", "t_memory",
                                    "t_collective")}
    result["bottleneck"] = max(terms, key=terms.get)
    result["roofline_fraction"] = result["t_compute"] / max(
        sum(terms.values()), 1e-30)
    if ma is not None:
        try:
            result["memory_analysis"] = {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "generated_code_bytes": ma.generated_code_size_in_bytes,
            }
        except Exception:
            result["memory_analysis"] = str(ma)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    results = []
    for arch, shape in cells:
        print(f"=== dry-run {arch} x {shape} "
              f"({'2x16x16' if args.multi_pod else '16x16'}) ===",
              flush=True)
        try:
            r = run_cell(arch, shape, args.multi_pod, args.microbatches)
        except Exception as e:  # a failure here is a bug in our sharding
            r = {"arch": arch, "shape": shape, "status": "FAILED",
                 "error": f"{type(e).__name__}: {e}"}
        results.append(r)
        print(json.dumps(r, indent=1, default=str), flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    n_bad = sum(1 for r in results if r["status"] == "FAILED")
    print(f"\n{len(results)} cells: "
          f"{sum(1 for r in results if r['status'] == 'ok')} ok, "
          f"{sum(1 for r in results if r['status'] == 'skipped')} skipped, "
          f"{n_bad} FAILED")
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
