"""Jaxpr-level collective counting: number of collective EXECUTIONS per
step, with scan trip counts multiplied through (unlike HLO text, where a
while body appears once).  This is the paper's 'messages' (latency) term
for an arbitrary jax program — used to verify the s-step schedules
structurally."""
from __future__ import annotations

import jax

COLLECTIVE_PRIMS = {"psum", "all_gather", "reduce_scatter", "all_to_all",
                    "ppermute", "psum_invariant", "pmax", "pmin"}


def count_collective_executions(jaxpr, _mult: int = 1) -> int:
    """jaxpr: a ClosedJaxpr (e.g. jax.make_jaxpr(f)(*args))."""
    core_jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    total = 0
    for eqn in core_jaxpr.eqns:
        name = eqn.primitive.name
        mult = _mult
        if name == "scan":
            mult *= int(eqn.params.get("length", 1))
        if name in COLLECTIVE_PRIMS:
            total += _mult
            continue
        # recurse into sub-jaxprs (scan/while/cond/pjit/shard_map/remat...)
        for sub in _sub_jaxprs(eqn):
            total += count_collective_executions(sub, mult)
    return total


def _sub_jaxprs(eqn):
    out = []
    for k, v in eqn.params.items():
        if k in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
            out.append(v)
        elif k == "branches":
            out.extend(v)
    return out
