"""Jaxpr-level collective counting: number of collective EXECUTIONS per
step, with scan trip counts multiplied through (unlike HLO text, where a
while body appears once).  This is the paper's 'messages' (latency) term
for an arbitrary jax program — used to verify the s-step schedules
structurally.

``collective_census`` is the assertion-grade variant consumed by
``repro.analysis.comm_check`` (DESIGN.md §11): it returns one row per
collective *site* — primitive name, the mesh axis names it reduces
over, and how many times the site executes (scan trip counts
multiplied through) — so the static comm auditor can check both the
execution count against ``perf_model``'s modeled message terms and the
axis names against the ``shard_map`` mesh.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

# Collective primitives by jaxpr name.  Beyond the core set, this covers
# the manual-sharding / vma variants (``psum_invariant``,
# ``all_gather_invariant``, ``pbroadcast``) and the async start/done
# split forms some lowering paths emit, so a schedule that smuggles a
# collective through any spelling is still counted.
COLLECTIVE_PRIMS = frozenset({
    "psum", "all_gather", "reduce_scatter", "all_to_all", "ppermute",
    "psum_invariant", "pmax", "pmin",
    "pbroadcast", "all_gather_invariant", "psum2",
    "all_gather_start", "all_gather_done",
    "all_reduce_start", "all_reduce_done",
    "reduce_scatter_start", "reduce_scatter_done",
    "collective_permute_start", "collective_permute_done",
})


class CollectiveUse(NamedTuple):
    """One collective site in a jaxpr: ``prim`` (primitive name),
    ``axes`` (mesh axis NAMES it communicates over; positional/int axes
    are dropped), ``executions`` (how many times the site runs per call,
    scan trip counts multiplied through)."""

    prim: str
    axes: Tuple[str, ...]
    executions: int


def _axis_names(params) -> Tuple[str, ...]:
    """Mesh axis names from a collective eqn's params (``axes`` for psum
    and friends, ``axis_name`` for gather/permute-style primitives);
    either may be a bare name or a tuple, and psum axes may include
    POSITIONAL (int) entries — only named axes talk to the network."""
    ax = params.get("axes", params.get("axis_name", ()))
    if not isinstance(ax, (tuple, list)):
        ax = (ax,)
    return tuple(a for a in ax if isinstance(a, str))


def collective_census(jaxpr, _mult: int = 1) -> Tuple[CollectiveUse, ...]:
    """Every collective site in ``jaxpr`` (a ClosedJaxpr, e.g.
    ``jax.make_jaxpr(f)(*args)``) with its per-call execution count.

    Scan trip counts multiply through (a psum inside a length-R
    ``lax.scan`` executes R times); while-loop bodies count ONCE (their
    trip count is data-dependent — the census is a static lower bound,
    exact for the scan-based round loops the solvers actually use).
    """
    core_jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    rows = []
    for eqn in core_jaxpr.eqns:
        name = eqn.primitive.name
        mult = _mult
        if name == "scan":
            mult *= int(eqn.params.get("length", 1))
        if name in COLLECTIVE_PRIMS:
            rows.append(CollectiveUse(name, _axis_names(eqn.params), _mult))
            continue
        # recurse into sub-jaxprs (scan/while/cond/pjit/shard_map/remat...)
        for sub in _sub_jaxprs(eqn):
            rows.extend(collective_census(sub, mult))
    return tuple(rows)


def count_collective_executions(jaxpr, _mult: int = 1) -> int:
    """Total collective executions in a ClosedJaxpr (census summed)."""
    return sum(u.executions for u in collective_census(jaxpr, _mult))


def _sub_jaxprs(eqn):
    out = []
    for k, v in eqn.params.items():
        if k in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
            out.append(v)
        elif k == "branches":
            out.extend(v)
    return out
