"""Unified solver facade: estimators over the paper's algorithm family.

The paper's value proposition is *same solution, tunable communication*:
classical vs s-step, block size b, and partition layout are tuning knobs
over ONE algorithm family.  This module is the single public seam that
reflects that (DESIGN.md §8):

    from repro.api import KernelSVM, KernelRidge, SolverOptions

    clf = KernelSVM(C=1.0, kernel="rbf",
                    options=SolverOptions(method="sstep", s=32,
                                          tol=1e-6, max_iters=2048))
    result = clf.fit(A, y)          # FitResult: alpha, history, comm model
    labels = clf.predict(A_test)

Dispatch covers {classical, sstep} x {serial, 1d, 2d}: the serial path
drives the shared round protocol (``core/loop.run_rounds``) directly —
one ``lax.scan`` when no tolerance/recording is requested (bit-compatible
with the legacy entrypoints), one ``lax.while_loop`` with a metric check
every ``check_every`` rounds otherwise.  The 1d/2d paths reuse the
``shard_map`` solvers in ``core/distributed``; their tolerance stopping
runs the same schedule in ``check_every``-round chunks with the metric
evaluated between chunks (round boundaries are identical because chunks
are whole multiples of s).

Convergence metrics: K-SVM stops on the duality gap
(``objectives.ksvm_duality_gap``); K-RR stops on the relative residual of
the optimality system (``objectives.krr_rel_residual``) — the paper's
rel-error needs the closed-form alpha*, which costs an m x m
factorization the facade refuses to hide inside ``fit``.

Representations (DESIGN.md §9): ``SolverOptions(approx="nystrom",
landmarks=l)`` swaps the exact kernel for a rank-l Nystrom feature map —
built ONCE per fit, consumed by the same solvers through a
``LowRankGramOperator`` (every reduction O(l)-wide; convergence metrics
evaluate under the SAME approximate kernel, so tolerance stopping stays
meaningful), and reused at predict time.  K-SVM caveat: the exact path
keeps the paper implementation's ``K(diag(y) A)`` training gram while
the low-rank path uses the textbook ``diag(y) K~ diag(y)`` (feature
scaling does not commute with nonlinear epilogues), so exact-vs-approx
K-SVM solutions are directly comparable only for linear kernels — for
K-RR (no y-scaling) the l -> m limit recovers the exact solution for
every kernel (see ``LowRankGramOperator.scale_rows``).  Prediction always runs through
the batched slab-free subsystem (``core/predict.py``): the dense
``(q x m)`` test-kernel slab of the legacy ``objectives.*_predict``
oracles never materializes.

Sweeps (DESIGN.md §10): ``fit`` takes a ``warm_start=`` alpha and
``fit_path`` solves a warm-started regularization ladder; whole grids
solve as ONE vmapped fleet via ``repro.tune.solve_fleet`` (k-fold
search: ``repro.tune.cross_validate``).  Knobs left at ``"auto"``
(``SolverOptions(s="auto", b="auto", layout="auto", approx="auto")``)
resolve through the perf-model autotuner before the solve; the chosen
``TunedPlan`` lands on ``FitResult.plan``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import enable_x64, make_mesh_auto
from repro.core import (DIVERGED_NONFINITE, GuardSpec, KernelConfig,
                        KRRConfig, SVMConfig, NO_TOL,
                        ExactGramOperator, StreamingGramOperator,
                        bdcd_krr, block_schedule, coordinate_schedule,
                        dcd_ksvm, gram_slab, krr_rel_residual,
                        ksvm_duality_gap, ksvm_duality_gap_lowrank,
                        make_bdcd_round_fn, make_dcd_round_fn,
                        make_sstep_bdcd_round_fn, make_sstep_dcd_round_fn,
                        pad_rounds, run_rounds, sstep_bdcd_krr,
                        sstep_dcd_ksvm)
from repro.core import distributed
from repro.core.nystrom import (LANDMARK_METHODS, fit_nystrom,
                                lowrank_operator)
from repro.core.perf_model import choose_recompute_every, modeled_fit_cost
from repro.core.predict import BatchedPredictor, validate_queries
from repro.resilience.guard import (DivergenceError, finite_health,
                                    init_residual, make_correct_fn,
                                    next_fallback)
from repro.resilience.health import (HealthEvent, KIND_METRIC,
                                     KIND_NONFINITE, KIND_RESUME,
                                     SolveHealth)
from repro.resilience.checkpoint import (load_solve_state,
                                         save_solve_state,
                                         solve_fingerprint)
from repro.resilience.faults import SimulatedKill, active_plan
from repro.obs.spans import Telemetry

METHODS = ("classical", "sstep")
LAYOUTS = ("serial", "1d", "2d")
APPROX = (None, "nystrom")
AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class SolverOptions:
    """How to run the solve — every knob of the paper's algorithm family.

    method:      "classical" (communicate every iteration) or "sstep"
                 (one communication round per s iterations, same iterates).
    s:           s-step depth (ignored for method="classical"), or
                 "auto" — resolved per problem by the perf-model-driven
                 autotuner (repro.tune.autotune, DESIGN.md §10) before
                 the solve; the chosen plan lands on ``FitResult.plan``.
    b:           block size (K-RR only; K-SVM is scalar-coordinate),
                 or "auto" (autotuned jointly with s).
    layout:      "serial", "1d" (paper's feature-partitioned shard_map
                 layout), "2d" (samples x features, beyond paper), or
                 "auto" (autotuned over the visible device count).
    mesh:        jax Mesh for 1d/2d; auto-built over the host's devices
                 when None ("model"-major for 1d, "data"-major for 2d).
    slab_free:   consume kernel slabs through the GramOperator (default);
                 False forces the materialized-slab parity-oracle path
                 (serial and 1d only).
    tol:         stop once the convergence metric (duality gap for K-SVM,
                 relative residual for K-RR) falls to tol; 0 disables
                 early stopping.
    check_every: metric cadence, in outer rounds.
    max_iters:   total inner-iteration budget H.  H % s != 0 is fine —
                 the final short round is handled by pad-and-mask.
    record:      keep the metric history even when tol == 0.
    seed:        PRNG seed for the coordinate/block schedule (and, folded,
                 for the landmark draw when approx is on).
    approx:      kernel representation: None (exact), "nystrom" —
                 rank-``landmarks`` feature map built once per fit, then
                 every per-round reduction runs O(landmarks)-wide through
                 a ``LowRankGramOperator`` (DESIGN.md §9) and prediction
                 serves through the same map — or "auto" (the autotuner
                 picks the cheaper modeled representation).
    landmarks:   Nystrom rank l (clipped to m at fit time).
    landmark_method: "uniform" row sampling or "kmeans" centroids.
    probe:       autotune refinement: when > 0 and any knob is "auto",
                 the top modeled candidates are additionally MEASURED
                 for ``probe`` outer rounds each and the fastest wins
                 (0 = trust the Hockney model alone).
    guard:       guarded solve (DESIGN.md §12): the round loop carries
                 the residual ``f = K @ alpha`` (same per-round kernel
                 work — the recurrence reuses the block each round
                 already evaluates), health-checks every round, corrects
                 residual drift, and on divergence auto-falls back along
                 the escalation ladder (halve s -> classical -> f64)
                 from the last good state.  ``FitResult.health`` records
                 everything observed.  Requires slab_free.
    recompute_every: drift-correction cadence in OUTER rounds — every
                 that many rounds ``f`` is recomputed exactly through
                 the operator (one extra KMV, residual replacement).
                 "auto" resolves via the perf model to the largest
                 cadence within the 10% overhead budget; 0 disables
                 correction (serial layouts only — the distributed
                 bodies recompute their round quantities from alpha
                 every round and carry no drifting residual).
    checkpoint_every: mid-solve snapshot cadence in OUTER rounds (0 =
                 off); requires ``checkpoint_dir`` and ``guard``.
                 ``fit(resume_from=checkpoint_dir)`` restores a killed
                 solve and continues it — bit-identical modulo the
                 restart round.
    checkpoint_dir: where snapshots go (atomic step directories via
                 train/checkpoint.py).
    fallback:    walk the escalation ladder on divergence (default); if
                 False a divergence raises ``DivergenceError``
                 immediately, surfacing the structured events instead.
    stream:      out-of-core representation (DESIGN.md §14): a positive
                 int streams the data through the double-buffered KMV
                 pipeline in row chunks of that size (the
                 ``StreamingGramOperator`` — no reduction ever holds X
                 or an m-tall slab in its working set); ``"auto"`` (or
                 True) lets the autotuner resolve the chunk size from
                 the streaming pipeline cost model
                 (``perf_model.choose_chunk_rows``); None/False (the
                 default) keeps the resident operator.  Exact
                 representation and serial layout only (the distributed
                 layouts shard instead of stream; low-rank factors are
                 already O(m*l)-small).
    telemetry:   observability (repro.obs, DESIGN.md §15): a
                 ``repro.obs.Telemetry`` handle — or True for a fresh
                 one — records host spans around every fit phase plus
                 traced marks at the round protocol's sync points, and
                 lands on ``FitResult.telemetry`` for the audit
                 (``repro.obs.audit_fit``) and the trace exporter.
                 None (default) or a DISABLED handle compiles the
                 exact pre-telemetry round fn — zero added ops.
    """

    method: str = "sstep"
    s: Union[int, str] = 16
    b: Union[int, str] = 1
    layout: str = "serial"
    mesh: Optional[object] = None
    slab_free: bool = True
    tol: float = 0.0
    check_every: int = 8
    max_iters: int = 1024
    record: bool = False
    seed: int = 0
    approx: Optional[str] = None
    landmarks: int = 256
    landmark_method: str = "uniform"
    probe: int = 0
    guard: bool = False
    recompute_every: Union[int, str] = AUTO
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    fallback: bool = True
    stream: Union[None, bool, int, str] = None
    telemetry: Union[None, bool, Telemetry] = None

    def __post_init__(self):
        # normalize the telemetry knob (True == fresh handle, False ==
        # off) and validate it eagerly like every other option
        if self.telemetry is True:
            object.__setattr__(self, "telemetry", Telemetry())
        elif self.telemetry is False:
            object.__setattr__(self, "telemetry", None)
        if self.telemetry is not None and \
                not isinstance(self.telemetry, Telemetry):
            raise ValueError(f"telemetry must be None, a bool, or a "
                             f"repro.obs.Telemetry, got "
                             f"{self.telemetry!r}")
        # normalize the stream knob first (True == "auto", False == off)
        if self.stream is True:
            object.__setattr__(self, "stream", AUTO)
        elif self.stream is False:
            object.__setattr__(self, "stream", None)
        if self.stream is not None and self.stream != AUTO and (
                not isinstance(self.stream, int) or self.stream < 1):
            raise ValueError(f"stream must be None, a positive int "
                             f"chunk size, or {AUTO!r}, got "
                             f"{self.stream!r}")
        if self.stream is not None:
            if not self.slab_free:
                raise ValueError("stream= requires slab_free=True: the "
                                 "streamed representation only exists "
                                 "behind the GramOperator interface")
            if self.layout not in ("serial", AUTO):
                raise ValueError(f"stream= requires the serial layout "
                                 f"(the distributed layouts shard the "
                                 f"data instead of streaming it), got "
                                 f"layout={self.layout!r}")
            if self.approx not in (None, AUTO):
                raise ValueError("stream= requires the exact "
                                 "representation (a low-rank factor is "
                                 "already O(m*l)-small — stream and "
                                 "approx are mutually exclusive)")
        if self.method not in METHODS:
            raise ValueError(
                f"method must be one of {METHODS}, got {self.method!r}")
        if self.layout not in LAYOUTS + (AUTO,):
            raise ValueError(f"layout must be one of "
                             f"{LAYOUTS + (AUTO,)}, got {self.layout!r}")
        for name in ("s", "b"):
            v = getattr(self, name)
            if v != AUTO and (not isinstance(v, int) or v < 1):
                raise ValueError(f"{name} must be a positive int or "
                                 f"{AUTO!r}, got {v!r}")
        for name in ("max_iters", "check_every", "landmarks"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        if not isinstance(self.probe, int) or self.probe < 0:
            raise ValueError(f"probe must be an int >= 0, "
                             f"got {self.probe!r}")
        if not self.tol >= 0.0:
            raise ValueError(f"tol must be >= 0, got {self.tol!r}")
        if not self.slab_free and self.layout == "2d":
            raise ValueError("the 2d layout is slab-free by construction; "
                             "slab_free=False is only meaningful for the "
                             "serial and 1d layouts")
        if self.approx not in APPROX + (AUTO,):
            raise ValueError(f"approx must be one of {APPROX + (AUTO,)}, "
                             f"got {self.approx!r}")
        if self.landmark_method not in LANDMARK_METHODS:
            raise ValueError(f"landmark_method must be one of "
                             f"{LANDMARK_METHODS}, got "
                             f"{self.landmark_method!r}")
        if self.recompute_every != AUTO and (
                not isinstance(self.recompute_every, int)
                or self.recompute_every < 0):
            raise ValueError(f"recompute_every must be an int >= 0 or "
                             f"{AUTO!r}, got {self.recompute_every!r}")
        if not isinstance(self.checkpoint_every, int) \
                or self.checkpoint_every < 0:
            raise ValueError(f"checkpoint_every must be an int >= 0, "
                             f"got {self.checkpoint_every!r}")
        if self.guard and not self.slab_free:
            raise ValueError("guard=True requires slab_free=True: the "
                             "guarded round protocol reads the kernel "
                             "through the GramOperator (the "
                             "materialized-slab oracle has no residual "
                             "recurrence to guard)")
        if self.checkpoint_every > 0 and self.checkpoint_dir is None:
            raise ValueError("checkpoint_every > 0 requires "
                             "checkpoint_dir=")
        if self.checkpoint_every > 0 and not self.guard:
            raise ValueError("checkpoint_every > 0 requires guard=True "
                             "(snapshots are cut at the guarded "
                             "executor's segment boundaries)")

    @property
    def needs_autotune(self) -> bool:
        """Any knob left at "auto" — ``fit`` resolves them through
        ``repro.tune.autotune`` before solving (DESIGN.md §10)."""
        return AUTO in (self.s, self.b, self.layout, self.approx,
                        self.stream)

    @property
    def s_eff(self) -> int:
        """Inner iterations per communication round (1 for classical)."""
        if self.method != "sstep":
            return 1
        if self.s == AUTO:
            raise ValueError('s="auto" is unresolved — fit() resolves it '
                             'via repro.tune.autotune.resolve_options '
                             'before solving')
        return self.s


# repro: noqa[CHK-PYTREE] host-side result record — fit() returns it to
#   the caller after every jit boundary has been crossed; it is never
#   passed back into a traced function.
@dataclasses.dataclass
class FitResult:
    """Everything ``fit`` observed: the solution, the convergence
    trajectory, and the modeled communication cost of the run."""

    alpha: jnp.ndarray
    schedule: jnp.ndarray          # the iterations actually executed —
                                   # truncated to iters_run on early stop,
                                   # so replaying it through a legacy
                                   # entrypoint reproduces alpha
    history: Optional[np.ndarray]  # metric at each check point (or None)
    metric: str                    # "duality_gap" | "rel_residual"
    converged: bool
    rounds_run: int
    iters_run: int
    wall_time_s: float
    comm: dict                     # Hockney model: flops/words/msgs/time
    options: SolverOptions         # the RESOLVED options the solve ran
                                   # with (auto knobs already concrete)
    representation: str = "exact"  # "exact" | "nystrom(l=...)"
    plan: Optional[object] = None  # tune.TunedPlan when any knob was
                                   # "auto" (modeled frontier + choice)
    health: Optional[SolveHealth] = None
                                   # guarded runs: drift observations,
                                   # divergence/fallback events,
                                   # checkpoint/resume ledger
                                   # (DESIGN.md §12)
    telemetry: Optional[Telemetry] = None
                                   # the recording handle when the fit
                                   # ran with SolverOptions(telemetry=)
                                   # — spans/marks/metrics for
                                   # repro.obs.audit_fit and the trace
                                   # exporter (DESIGN.md §15)

    def metric_history(self) -> Optional[np.ndarray]:
        """The evaluated convergence trajectory — the canonical accessor
        (mirrors ``LoopResult.metric_history``): every recorded metric
        value in evaluation order, ``None`` when the run recorded none
        (``tol == 0`` and ``record=False``)."""
        return self.history


def _check_predict_batch(batch) -> int:
    """Eager validation, mirroring SolverOptions' integer knobs."""
    if not isinstance(batch, int) or batch < 1:
        raise ValueError(
            f"predict_batch must be a positive int, got {batch!r}")
    return batch


def _check_finite(value, name: str):
    """Eager input validation: reject non-finite data at the facade
    boundary with the offending argument NAMED, instead of letting a
    single NaN silently poison the whole solve through the round
    recurrences (the failure mode the runtime guard exists for —
    corrupt INPUT deserves an immediate, attributable error)."""
    value = jnp.asarray(value)
    if not jnp.issubdtype(value.dtype, jnp.floating):
        return value
    if not bool(jnp.all(jnp.isfinite(value))):
        bad = int(jnp.sum(~jnp.isfinite(value)))
        raise ValueError(
            f"{name} contains {bad} non-finite (nan/inf) value"
            f"{'s' if bad != 1 else ''} — clean or impute the data "
            f"before fitting")
    return value


def _check_positive(value: float, name: str) -> float:
    if not value > 0:
        raise ValueError(f"{name} must be > 0, got {value!r}")
    return value


def _active_tel(opts: SolverOptions) -> Optional[Telemetry]:
    """The ENABLED telemetry handle of a fit, or None — a disabled
    handle maps to None so every traced path compiles mark-free."""
    t = opts.telemetry
    return t if (t is not None and t.enabled) else None


def _tspan(tel: Optional[Telemetry], name: str, phase: str, **args):
    """``tel.span(...)`` or a no-op context when telemetry is off."""
    if tel is None:
        return contextlib.nullcontext()
    return tel.span(name, phase, **args)


def _as_kernel(kernel: Union[str, KernelConfig, None]) -> KernelConfig:
    if kernel is None:
        return KernelConfig()
    if isinstance(kernel, str):
        return KernelConfig(kernel)
    return kernel


def _resolve_mesh(opts: SolverOptions):
    """User mesh (validated for the layout's axis names) or an auto mesh
    over every visible device."""
    ndev = len(jax.devices())
    if opts.mesh is None:
        shape = (1, ndev) if opts.layout == "1d" else (ndev, 1)
        return make_mesh_auto(shape, ("data", "model"))
    need = ("model",) if opts.layout == "1d" else ("data", "model")
    missing = [ax for ax in need if ax not in opts.mesh.axis_names]
    if missing:
        raise ValueError(f"mesh lacks axes {missing} required by the "
                         f"{opts.layout!r} layout (has "
                         f"{opts.mesh.axis_names})")
    return opts.mesh


@partial(jax.jit, static_argnames=("cfg", "s", "check_every", "slab_free",
                                   "lowrank", "marks"))
def _ksvm_serial_tol(A, y, a0, schedule, tol, *, cfg: SVMConfig, s: int,
                     check_every: int, slab_free: bool, op=None,
                     lowrank: bool = False, marks: bool = False):
    gram = None if slab_free else gram_slab
    op = None if gram is not None else op
    if s == 1:
        rf, xs = make_dcd_round_fn(A, y, cfg, gram_fn=gram, op=op), schedule
    else:
        rf = make_sstep_dcd_round_fn(A, y, cfg, s, gram_fn=gram, op=op)
        xs = pad_rounds(schedule, s)
    # low-rank runs (A is Phi) check the gap through the O(m l) factored
    # form — the generic oracle would build the m x m gram of Phi
    metric = (ksvm_duality_gap_lowrank if lowrank else ksvm_duality_gap)
    return run_rounds(rf, a0, xs, tol=tol, check_every=check_every,
                      metric_fn=lambda a: metric(A, y, a, cfg),
                      marks=marks)


@partial(jax.jit, static_argnames=("cfg", "s", "check_every", "slab_free",
                                   "marks"))
def _krr_serial_tol(A, y, a0, schedule, tol, *, cfg: KRRConfig, s: int,
                    check_every: int, slab_free: bool, op=None,
                    marks: bool = False):
    gram = None if slab_free else gram_slab
    op = None if gram is not None else op
    if s == 1:
        rf, xs = make_bdcd_round_fn(A, y, cfg, gram_fn=gram, op=op), schedule
    else:
        rf = make_sstep_bdcd_round_fn(A, y, cfg, s, gram_fn=gram, op=op)
        xs = pad_rounds(schedule, s)
    return run_rounds(rf, a0, xs, tol=tol, check_every=check_every,
                      metric_fn=lambda a: krr_rel_residual(A, y, a, cfg),
                      marks=marks)


@partial(jax.jit, static_argnames=("problem", "cfg", "s", "check_every",
                                   "correct_every", "lowrank",
                                   "want_metric", "fault_target",
                                   "marks"))
def _guarded_serial_chunk(A, y, a0, f0, schedule, tol, fault_round,
                          fault_value, *, problem, cfg, s: int,
                          check_every: int, correct_every: int,
                          lowrank: bool, want_metric: bool,
                          fault_target: Optional[str] = None, op=None,
                          marks: bool = False):
    """One guarded segment (DESIGN.md §12): the guarded round fns over
    the ``(alpha, f)`` carry, driven by the guarded while-loop with
    per-round health checks and periodic residual replacement.  The
    fault lane (static ``fault_target``) is the test harness's hook: at
    round ``fault_round`` it adds ``fault_value`` to the chosen carry
    leaf AFTER the round update — the jit-safe analogue of a hardware
    flip, compiled only when a fault plan is armed."""
    if problem == "ksvm":
        if s == 1:
            base, xs = make_dcd_round_fn(A, y, cfg, op=op,
                                         guard=True), schedule
        else:
            base = make_sstep_dcd_round_fn(A, y, cfg, s, op=op,
                                           guard=True)
            xs = pad_rounds(schedule, s)
        gap = ksvm_duality_gap_lowrank if lowrank else ksvm_duality_gap
        metric = lambda c: gap(A, y, c[0], cfg)
    else:
        if s == 1:
            base, xs = make_bdcd_round_fn(A, y, cfg, op=op,
                                          guard=True), schedule
        else:
            base = make_sstep_bdcd_round_fn(A, y, cfg, s, op=op,
                                            guard=True)
            xs = pad_rounds(schedule, s)
        metric = lambda c: krr_rel_residual(A, y, c[0], cfg)

    rf = base
    if fault_target is not None:
        R = schedule.shape[0] if s == 1 else -(-schedule.shape[0] // s)
        hits = jnp.arange(R) == fault_round

        def rf(carry, xz):
            x, hit = xz
            alpha, f = base(carry, x)
            bad = jnp.where(hit, jnp.asarray(fault_value, alpha.dtype),
                            jnp.zeros((), alpha.dtype))
            if fault_target == "alpha":
                return alpha + bad, f
            return alpha, f + bad

        xs = (xs, hits)

    spec = GuardSpec(
        health_fn=finite_health,
        correct_fn=make_correct_fn(op) if correct_every >= 1 else None,
        correct_every=correct_every)
    return run_rounds(rf, (a0, f0), xs, tol=tol, check_every=check_every,
                      metric_fn=metric if want_metric else None,
                      guard=spec, marks=marks)


def _cast_floating(tree, dtype):
    """Cast every floating leaf (operators are registered pytrees, so
    their static config rides along untouched)."""
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x,
        tree)


def _run_guarded_serial(problem, A_s, y, a0, schedule, cfg_s,
                        opts: SolverOptions, train_op, *, fingerprint,
                        resume=None):
    """The host half of the guarded serial solve: run
    ``_guarded_serial_chunk`` in checkpoint-bounded segments, harvest
    drift/metric observations, and on divergence walk the escalation
    ladder (halve s -> classical -> f64 accumulation) from the last
    good state.  Returns ``(alpha, history, converged, rounds_run,
    iters_run, health)``."""
    from repro.train.checkpoint import CheckpointManager

    H = schedule.shape[0]
    want_metric = opts.tol > 0.0 or opts.record
    tol = opts.tol if opts.tol > 0.0 else NO_TOL
    lowrank = problem == "ksvm" and bool(opts.approx)
    base_dtype = A_s.dtype
    tel = _active_tel(opts)

    s_cur, method_cur = opts.s_eff, opts.method
    x64 = False
    pos, rounds_done, converged = 0, 0, False
    alpha = a0
    f = None
    events, drifts, hists = [], [], []
    checkpoints, resumed_from = 0, None

    if resume is not None:
        alpha = jnp.asarray(resume["alpha"], base_dtype)
        f = (jnp.asarray(resume["f"], base_dtype)
             if resume.get("f") is not None else None)
        pos = resume["iters_done"]
        s_cur, method_cur = resume["s_cur"], resume["method_cur"]
        resumed_from = resume["path"]
        events.append(HealthEvent(
            kind=KIND_RESUME, round_idx=rounds_done, iter_idx=pos,
            action="resume", detail=resumed_from))

    plan = active_plan()
    mgr = None
    if opts.checkpoint_every > 0:
        mgr = CheckpointManager(opts.checkpoint_dir, save_every=1)

    A_cur, y_cur, op_cur = A_s, y, train_op
    if f is None:
        f = init_residual(op_cur, alpha)

    while pos < H and not converged:
        if opts.checkpoint_every > 0:
            seg = min(opts.checkpoint_every * s_cur, H - pos)
        else:
            seg = H - pos
        sched_seg = schedule[pos:pos + seg]
        fault_round = (plan.carry_fault_round(pos, seg, s_cur)
                       if plan is not None else -1)
        fault_target = plan.target if fault_round >= 0 else None
        fault_value = plan.value if plan is not None else float("nan")

        ctx = enable_x64() if x64 else contextlib.nullcontext()
        with ctx, _tspan(tel, "guarded_segment", "solve", iter_start=pos,
                         iters=int(seg), s=s_cur):
            res = _guarded_serial_chunk(
                A_cur, y_cur, alpha, f, sched_seg,
                jnp.asarray(tol, A_cur.dtype), fault_round, fault_value,
                problem=problem, cfg=cfg_s, s=s_cur,
                check_every=opts.check_every,
                correct_every=opts.recompute_every,
                lowrank=lowrank, want_metric=want_metric,
                fault_target=fault_target, op=op_cur,
                marks=tel is not None)
            # the segment boundary is already a sync point (the host
            # branches on diverged_round next); syncing INSIDE the span
            # keeps the measured interval honest
            div = int(res.diverged_round)
        dh = res.drift_history()
        if dh is not None and len(dh):
            drifts.append(np.asarray(dh, np.float64))
            if tel is not None:
                tel.metrics.counter(
                    "repro_guard_corrections_total",
                    "residual drift corrections applied").inc(len(dh))
        mh = res.metric_history()
        if mh is not None and len(mh):
            hists.append(np.asarray(mh, np.float64))

        if div >= 0:
            # the unhealthy round's update was DISCARDED in-loop; the
            # carry is the last good state — consume the good prefix
            alpha, f = res.state
            good = div
            consumed = min(good * s_cur, seg)
            pos += consumed
            rounds_done += good
            kind = (KIND_NONFINITE
                    if int(res.diverged_kind) == DIVERGED_NONFINITE
                    else KIND_METRIC)
            if fault_round >= 0 and div >= fault_round:
                plan.carry_fired = True      # one-shot: don't re-fire
            if not opts.fallback:
                raise DivergenceError(
                    f"guarded solve diverged ({kind}) at round "
                    f"{rounds_done} (iteration {pos}) and fallback is "
                    f"disabled", events=tuple(events))
            try:
                action, s_cur, method_cur, x64_new = next_fallback(
                    s_cur, method_cur, x64)
            except DivergenceError as e:
                raise DivergenceError(str(e),
                                      events=tuple(events)) from None
            events.append(HealthEvent(
                kind=kind, round_idx=rounds_done, iter_idx=pos,
                action=action,
                detail=f"resuming from last good state at iter {pos}"))
            if tel is not None:
                tel.metrics.counter(
                    "repro_guard_fallbacks_total",
                    "escalation-ladder steps taken").inc(
                        action=action, kind=kind)
                tel.mark("fallback", phase="guard")
            if x64_new and not x64:
                x64 = True
                with enable_x64():
                    A_cur = A_cur.astype(jnp.float64)
                    y_cur = y_cur.astype(jnp.float64)
                    op_cur = _cast_floating(op_cur, jnp.float64)
                    alpha = alpha.astype(jnp.float64)
            # after ANY event the recurrence restarts from an exact
            # residual (the fault may have corrupted f alone)
            with (enable_x64() if x64 else contextlib.nullcontext()):
                f = op_cur.full_matvec(alpha)
            continue

        alpha, f = res.state
        seg_rounds = int(res.rounds_run)
        rounds_done += seg_rounds
        if bool(res.converged):
            converged = True
            pos += min(seg_rounds * s_cur, seg)
        else:
            pos += seg
        if mgr is not None and not converged and pos < H:
            save_solve_state(mgr, pos,
                             jnp.asarray(alpha, base_dtype),
                             jnp.asarray(f, base_dtype),
                             s_cur=s_cur, method_cur=method_cur,
                             fingerprint=fingerprint)
            checkpoints += 1
            if plan is not None and plan.should_kill(pos):
                plan.kill_fired = True
                mgr.wait()               # the snapshot is durable
                raise SimulatedKill(
                    f"simulated preemption at iteration {pos}",
                    opts.checkpoint_dir)
    if mgr is not None:
        mgr.wait()

    if x64:
        with enable_x64():
            alpha = alpha.astype(base_dtype)

    history = (np.concatenate(hists) if hists
               else (np.zeros(0) if want_metric else None))
    health = SolveHealth(
        guarded=True, recompute_every=opts.recompute_every,
        drift=(np.concatenate(drifts) if drifts else np.zeros(0)),
        corrections=sum(len(d) for d in drifts),
        events=tuple(events), checkpoints=checkpoints,
        resumed_from=resumed_from)
    return alpha, history, converged, rounds_done, pos, health


def _run_guarded_dist(problem, A_s, y, a0, schedule, cfg_s,
                      opts: SolverOptions, mesh, metric_host, *,
                      fingerprint, resume=None):
    """Guarded executor for the 1d/2d layouts.  The distributed bodies
    recompute their round quantities from alpha every round (one psum —
    audited by repro.analysis.comm_check), so there is NO drifting
    residual to correct and NO extra in-loop collective the guard could
    add; the guard runs at chunk boundaries on the host instead:
    non-finite/blown-up alpha detection, the same escalation ladder
    (from the chunk-start state), and checkpoint/resume.  Returns
    ``(alpha, history, converged, rounds_run, iters_run, health)``."""
    from repro.train.checkpoint import CheckpointManager
    from repro.resilience.faults import poisoned_1d_factory

    H = schedule.shape[0]
    want_metric = opts.tol > 0.0 or opts.record
    base_dtype = A_s.dtype
    tel = _active_tel(opts)
    blowup = 1e4

    s_cur, method_cur = opts.s_eff, opts.method
    x64 = False
    pos, rounds_done, converged = 0, 0, False
    alpha = a0
    events, hist = [], []
    checkpoints, resumed_from = 0, None
    best = float("inf")

    if resume is not None:
        alpha = jnp.asarray(resume["alpha"], base_dtype)
        pos = resume["iters_done"]
        s_cur, method_cur = resume["s_cur"], resume["method_cur"]
        resumed_from = resume["path"]
        events.append(HealthEvent(
            kind=KIND_RESUME, round_idx=rounds_done, iter_idx=pos,
            action="resume", detail=resumed_from))

    plan = active_plan()
    mgr = None
    if opts.checkpoint_every > 0:
        mgr = CheckpointManager(opts.checkpoint_dir, save_every=1)
    A_cur, y_cur = A_s, y

    while pos < H and not converged:
        chunk = opts.check_every * s_cur
        if opts.checkpoint_every > 0:
            chunk = min(chunk, opts.checkpoint_every * s_cur)
        seg = min(chunk, H - pos)
        sched_seg = schedule[pos:pos + seg]
        # 1d fault harness: a poisoned op_factory corrupts one rank's
        # psum contribution for the whole chunk containing the target
        # iteration (consumed once, like the serial fault lane)
        op_factory = None
        if (plan is not None and opts.layout == "1d"
                and plan.carry_fault_round(pos, seg, s_cur) >= 0):
            op_factory = poisoned_1d_factory(scale=plan.value)
        ctx = enable_x64() if x64 else contextlib.nullcontext()
        with ctx, _tspan(tel, "guarded_chunk", "solve", iter_start=pos,
                         iters=int(seg), s=s_cur, layout=opts.layout):
            alpha_new = _dist_chunk(A_cur, y_cur, alpha, sched_seg,
                                    problem=problem, layout=opts.layout,
                                    mesh=mesh, cfg=cfg_s, s=s_cur,
                                    slab_free=opts.slab_free,
                                    op_factory=op_factory)
            # the finiteness probe is the chunk's existing sync point;
            # syncing inside the span keeps the interval honest
            healthy = bool(jnp.all(jnp.isfinite(alpha_new)))
        val = None
        kind = KIND_NONFINITE
        if healthy and want_metric:
            val = metric_host(alpha_new)
            if not np.isfinite(val) or (np.isfinite(best)
                                        and val > blowup * best):
                healthy, kind = False, KIND_METRIC

        if not healthy:
            # last good state = the chunk-start alpha (the distributed
            # body is one jit region; mid-chunk rounds are not
            # recoverable — chunks are the guard granularity here)
            if op_factory is not None:
                plan.carry_fired = True
            if not opts.fallback:
                raise DivergenceError(
                    f"guarded {opts.layout} solve diverged ({kind}) in "
                    f"the chunk at iteration {pos} and fallback is "
                    f"disabled", events=tuple(events))
            try:
                action, s_cur, method_cur, x64_new = next_fallback(
                    s_cur, method_cur, x64)
            except DivergenceError as e:
                raise DivergenceError(str(e),
                                      events=tuple(events)) from None
            events.append(HealthEvent(
                kind=kind, round_idx=rounds_done, iter_idx=pos,
                action=action,
                detail=f"re-running chunk from iteration {pos}"))
            if tel is not None:
                tel.metrics.counter(
                    "repro_guard_fallbacks_total",
                    "escalation-ladder steps taken").inc(
                        action=action, kind=kind)
                tel.mark("fallback", phase="guard")
            if x64_new and not x64:
                x64 = True
                with enable_x64():
                    A_cur = A_cur.astype(jnp.float64)
                    y_cur = y_cur.astype(jnp.float64)
                    alpha = alpha.astype(jnp.float64)
            continue

        alpha = alpha_new
        pos += seg
        rounds_done += -(-seg // s_cur)
        if val is not None:
            hist.append(val)
            best = min(best, val)
            if opts.tol > 0.0 and val <= opts.tol:
                converged = True
        if mgr is not None and not converged and pos < H:
            save_solve_state(mgr, pos, jnp.asarray(alpha, base_dtype),
                             None, s_cur=s_cur, method_cur=method_cur,
                             fingerprint=fingerprint)
            checkpoints += 1
            if plan is not None and plan.should_kill(pos):
                plan.kill_fired = True
                mgr.wait()
                raise SimulatedKill(
                    f"simulated preemption at iteration {pos}",
                    opts.checkpoint_dir)
    if mgr is not None:
        mgr.wait()

    if x64:
        with enable_x64():
            alpha = alpha.astype(base_dtype)
    history = np.asarray(hist) if want_metric else None
    health = SolveHealth(
        guarded=True, recompute_every=0, drift=np.zeros(0),
        corrections=0, events=tuple(events), checkpoints=checkpoints,
        resumed_from=resumed_from)
    return alpha, history, converged, rounds_done, pos, health


def _serial_fast(problem, A, y, a0, schedule, cfg, s, slab_free, op=None):
    """tol == 0, no recording: the legacy jitted entrypoints verbatim
    (driven by the facade-built operator when slab-free)."""
    gram = None if slab_free else gram_slab
    op = None if gram is not None else op
    if problem == "ksvm":
        if s == 1:
            return dcd_ksvm(A, y, a0, schedule, cfg, gram_fn=gram,
                            op=op)[0]
        return sstep_dcd_ksvm(A, y, a0, schedule, cfg, s, gram_fn=gram,
                              op=op)[0]
    if s == 1:
        return bdcd_krr(A, y, a0, schedule, cfg, gram_fn=gram, op=op)[0]
    return sstep_bdcd_krr(A, y, a0, schedule, cfg, s, gram_fn=gram,
                          op=op)[0]


@partial(jax.jit, static_argnames=("problem", "layout", "mesh", "cfg",
                                   "s", "slab_free", "op_factory"))
def _dist_chunk(A, y, a0, schedule, *, problem, layout, mesh, cfg, s,
                slab_free, op_factory=None):
    """Jit-cached wrapper around the shard_map solvers: the chunked
    tolerance loop re-enters here once per chunk, and every chunk of the
    same length hits the cache instead of re-tracing the shard_map body
    (at most two shapes compile per fit: the chunk and the ragged tail).
    ``op_factory`` (static) overrides the per-rank operator build — the
    fault-injection hook for guarded distributed runs."""
    return _dist_call(problem, layout, mesh, A, y, a0, schedule, cfg, s,
                      slab_free, op_factory)


def _dist_call(problem, layout, mesh, A, y, a0, schedule, cfg, s,
               slab_free, op_factory=None):
    if problem == "ksvm":
        if layout == "1d":
            return distributed.dist_sstep_dcd_ksvm(
                mesh, A, y, a0, schedule, cfg, s=s, slab_free=slab_free,
                op_factory=op_factory)
        return distributed.dist_sstep_dcd_ksvm_2d(
            mesh, A, y, a0, schedule, cfg, s=s, op_factory=op_factory)
    if layout == "1d":
        return distributed.dist_sstep_bdcd_krr(
            mesh, A, y, a0, schedule, cfg, s=s, slab_free=slab_free,
            op_factory=op_factory)
    return distributed.dist_sstep_bdcd_krr_2d(
        mesh, A, y, a0, schedule, cfg, s=s, op_factory=op_factory)


def _build_representation(A, cfg, opts: SolverOptions):
    """The once-per-fit representation build (DESIGN.md §9): returns
    ``(op, A_solve)`` where ``op`` is the raw-data ``GramOperator`` the
    estimator keeps for prediction and ``A_solve`` is the data the
    solvers run on — ``A`` for exact, ``Phi`` for Nystrom (the same
    solvers then perform O(landmarks)-wide reductions; the s-step
    schedule is untouched).  Pair with ``_solve_cfg`` for the matching
    solver config; warm-started paths and fleets (repro.tune) build
    this ONCE and reuse it across every solve in the sweep.

    The landmark draw folds ``opts.seed`` (like the schedule key), so
    Nystrom fits — uniform OR kmeans landmarks — are reproducible
    end-to-end from the single facade seed."""
    if opts.approx is None:
        if opts.stream:
            if opts.stream == AUTO:
                raise ValueError('stream="auto" is unresolved — fit() '
                                 'resolves it via repro.tune.autotune.'
                                 'resolve_options before building the '
                                 'representation')
            return (StreamingGramOperator.from_dense(
                A, cfg.kernel, chunk_rows=int(opts.stream)), A)
        return ExactGramOperator(A, cfg.kernel), A
    l = min(opts.landmarks, A.shape[0])
    lkey = jax.random.fold_in(jax.random.key(opts.seed), 1)
    fmap = fit_nystrom(lkey, A, cfg.kernel, l,
                       method=opts.landmark_method)
    op = lowrank_operator(fmap, A)
    return op, op.Phi


def _solve_cfg(cfg, opts: SolverOptions):
    """The config the solvers and convergence metrics run on: ``cfg``
    itself for exact, the linear-kernel replacement for low-rank runs
    (the factor Phi already carries the nonlinearity).  Cheap — safe to
    recompute per solve while the operator is reused (reg_path)."""
    if opts.approx is None:
        return cfg
    return dataclasses.replace(cfg, kernel=KernelConfig("linear"))


def _fit(problem: str, A, y, cfg, opts: SolverOptions, *,
         a0=None, rep=None, resume_from=None):
    """Telemetry shell around ``_fit_body``: when the fit carries an
    enabled handle, activate it (the contextvar target of the traced
    marks) and bracket the whole call in one phase="fit" span — the
    window obs/audit.py reconciles against the Hockney model."""
    tel = _active_tel(opts)
    if tel is None:
        return _fit_body(problem, A, y, cfg, opts, a0=a0, rep=rep,
                         resume_from=resume_from)
    with tel.activate(), tel.span("fit", phase="fit", problem=problem,
                                  m=int(A.shape[0]), n=int(A.shape[1])):
        return _fit_body(problem, A, y, cfg, opts, a0=a0, rep=rep,
                         resume_from=resume_from)


def _fit_body(problem: str, A, y, cfg, opts: SolverOptions, *,
              a0=None, rep=None, resume_from=None):
    m, n = A.shape

    plan = None
    if opts.needs_autotune:
        from repro.tune.autotune import resolve_options
        plan = resolve_options(m, n, cfg, opts, problem=problem,
                               A=A, y=y)
        opts = plan.options
    if opts.guard and opts.recompute_every == AUTO:
        # idempotent backstop behind autotune's own resolution: price the
        # exact recompute against the per-round cost and pick the cadence
        # that keeps guarded overhead under GUARD_OVERHEAD_BUDGET.  The
        # distributed layouts recompute from alpha every round already —
        # no drifting residual, so correction is off there.
        if opts.layout == "serial":
            rec = choose_recompute_every(
                m, n, cfg.kernel.name,
                b=opts.b if problem == "krr" else 1, s=opts.s_eff,
                approx=bool(opts.approx),
                landmarks=min(opts.landmarks, m) if opts.approx else 0)
        else:
            rec = 0
        opts = dataclasses.replace(opts, recompute_every=rec)
    if resume_from is not None and not opts.guard:
        raise ValueError("resume_from= requires options.guard=True (the "
                         "checkpoint holds a guarded-carry snapshot)")

    H = opts.max_iters
    s = opts.s_eff
    b = opts.b if problem == "krr" else 1
    key = jax.random.key(opts.seed)
    # re-resolve after the autotune replace: the handle rides on opts
    tel = _active_tel(opts)

    t0 = time.perf_counter()
    # representation build (inside the clock: it is part of the solve
    # cost, mirrored by comm["setup_time"] in the Hockney model) —
    # unless a prebuilt representation is injected (warm-started paths
    # amortize ONE build across the whole ladder)
    if rep is None:
        with _tspan(tel, "representation_build", "setup",
                    approx=bool(opts.approx)):
            rep = _build_representation(A, cfg, opts)
            # drain the async dispatch inside the span so the setup
            # phase owns its own cost (not the first solve chunk's)
            if tel is not None:
                jax.block_until_ready(rep[1])
    rep_op, A_s = rep
    cfg_s = _solve_cfg(cfg, opts)
    if problem == "ksvm":
        schedule = coordinate_schedule(key, H, m)
        metric_name = "duality_gap"
        gap = (ksvm_duality_gap_lowrank if opts.approx
               else ksvm_duality_gap)
        metric_host = lambda a: float(gap(A_s, y, a, cfg_s))
    else:
        schedule = block_schedule(key, H, m, b)
        metric_name = "rel_residual"
        # under approx, cfg_s is linear, so the residual's kernel matvec
        # contracts algebraically (kmv_slab_free linear branch:
        # Phi @ (Phi^T alpha)) — already O(m l), no factored twin needed
        metric_host = lambda a: float(krr_rel_residual(A_s, y, a, cfg_s))
    # warm start (repro.tune paths): replaying FitResult.schedule from
    # the SAME a0 reproduces alpha, so warm-started results stay
    # replayable — the schedule contract is unchanged
    a0 = (jnp.zeros(m, A.dtype) if a0 is None
          else jnp.asarray(a0, A.dtype))
    want_metric = opts.tol > 0.0 or opts.record
    tol = opts.tol if opts.tol > 0.0 else NO_TOL

    resume = None
    fp = None
    if opts.guard:
        fp = solve_fingerprint(problem, m, A.dtype, cfg, opts)
        if resume_from is not None:
            r_alpha, r_f, extra = load_solve_state(
                resume_from, expect_fingerprint=fp)
            resume = {"alpha": r_alpha, "f": r_f,
                      "iters_done": int(extra["iters_done"]),
                      "s_cur": int(extra["s_cur"]),
                      "method_cur": extra["method_cur"],
                      "path": resume_from}

    history = None
    converged = False
    health = None
    if opts.layout == "serial":
        P = 1
        # the training operator (K-SVM: diag(y)-scaled rows — a second
        # (m, n)/(m, l) buffer) is built ONLY where it is consumed: the
        # serial slab-free paths.  The shard_map bodies rebuild their
        # per-rank operators from their own shards, and the
        # materialized-slab oracle bypasses operators entirely.
        train_op = None
        if opts.slab_free:
            train_op = (rep_op.scale_rows(y) if problem == "ksvm"
                        else rep_op)
        if opts.guard:
            (alpha, history, converged, rounds_run, iters_run,
             health) = _run_guarded_serial(
                problem, A_s, y, a0, schedule, cfg_s, opts, train_op,
                fingerprint=fp, resume=resume)
        elif not want_metric:
            # the scan fast path has no sync points — no marks; the
            # host span still brackets dispatch + completion
            with _tspan(tel, "solve", "solve", path="fast", s=s):
                alpha = _serial_fast(problem, A_s, y, a0, schedule,
                                     cfg_s, s, opts.slab_free,
                                     op=train_op)
                if tel is not None:
                    jax.block_until_ready(alpha)
            rounds_run = -(-H // s)
        else:
            kw = ({"lowrank": bool(opts.approx)} if problem == "ksvm"
                  else {})
            solve = (_ksvm_serial_tol if problem == "ksvm"
                     else _krr_serial_tol)
            with _tspan(tel, "solve", "solve", path="tol", s=s):
                res = solve(A_s, y, a0, schedule, tol, cfg=cfg_s, s=s,
                            check_every=opts.check_every,
                            slab_free=opts.slab_free, op=train_op,
                            marks=tel is not None, **kw)
                alpha = res.state
                # rounds_run is the host sync; inside the span so the
                # measured interval covers the whole while-loop
                rounds_run = int(res.rounds_run)
            converged = bool(res.converged)
            history = np.asarray(res.metric_history())
        if not opts.guard:
            iters_run = min(rounds_run * s, H)
    else:
        # the shard_map bodies build their own per-rank operators from
        # the sharded solve matrix: for low-rank runs A_s IS Phi, so the
        # 1d layout shards Phi's l columns (and the linear-kernel psum
        # payload shrinks to the contracted (sb, sb+1) words).
        mesh = _resolve_mesh(opts)
        P = (mesh.shape["model"] if opts.layout == "1d"
             else mesh.shape["data"] * mesh.shape["model"])
        alpha = a0
        dist_kw = dict(problem=problem, layout=opts.layout, mesh=mesh,
                       cfg=cfg_s, s=s, slab_free=opts.slab_free)
        if opts.guard:
            (alpha, history, converged, rounds_run, iters_run,
             health) = _run_guarded_dist(
                problem, A_s, y, a0, schedule, cfg_s, opts, mesh,
                metric_host, fingerprint=fp, resume=resume)
        elif not want_metric:
            with _tspan(tel, "solve", "solve", path="dist_fast", s=s,
                        layout=opts.layout):
                alpha = _dist_chunk(A_s, y, alpha, schedule, **dist_kw)
                if tel is not None:
                    jax.block_until_ready(alpha)
            rounds_run, iters_run = -(-H // s), H
        else:
            # chunked early stopping: whole multiples of s per chunk keep
            # the round decomposition identical to the unchunked run.
            chunk = opts.check_every * s
            pos, rounds_run, hist = 0, 0, []
            while pos < H:
                sched_c = schedule[pos:pos + chunk]
                with _tspan(tel, "dist_chunk", "solve", iter_start=pos,
                            iters=int(sched_c.shape[0]), s=s,
                            layout=opts.layout):
                    alpha = _dist_chunk(A_s, y, alpha, sched_c,
                                        **dist_kw)
                    pos += sched_c.shape[0]
                    rounds_run += -(-sched_c.shape[0] // s)
                    # the metric read is the chunk's existing sync point
                    val = metric_host(alpha)
                hist.append(val)
                if opts.tol > 0.0 and val <= opts.tol:
                    converged = True
                    break
            iters_run = pos
            history = np.asarray(hist)
    jax.block_until_ready(alpha)
    wall = time.perf_counter() - t0

    l = A_s.shape[1] if opts.approx else 0
    comm = modeled_fit_cost(m, n, cfg.kernel.name, b=b, s=s,
                            iters=iters_run, P=P, approx=opts.approx,
                            landmarks=l)
    rep_name = f"nystrom(l={l})" if opts.approx else "exact"
    result = FitResult(alpha=alpha, schedule=schedule[:iters_run],
                       history=history, metric=metric_name,
                       converged=converged,
                       rounds_run=rounds_run, iters_run=iters_run,
                       wall_time_s=wall, comm=comm, options=opts,
                       representation=rep_name, plan=plan, health=health,
                       telemetry=tel)
    return result, rep_op


class KernelSVM:
    """Kernel SVM solved by (s-step) Dual Coordinate Descent.

    Estimator facade over ``core.dcd`` / ``core.sstep_dcd`` and their
    shard_map layouts; see module docstring and ``SolverOptions``.

    ``fit`` builds the kernel representation (a ``GramOperator``: exact
    or Nystrom low-rank per ``options.approx``) ONCE and keeps it on
    ``op_``; ``decision_function``/``predict`` serve through the same
    operator with the batched slab-free subsystem (``core/predict.py``),
    after compacting the model to its support vectors.
    """

    def __init__(self, C: float = 1.0, loss: str = "l1",
                 kernel: Union[str, KernelConfig, None] = None,
                 options: Optional[SolverOptions] = None,
                 predict_batch: int = 1024):
        _check_positive(C, "C")
        self.cfg = SVMConfig(C=C, loss=loss, kernel=_as_kernel(kernel))
        self.options = options or SolverOptions()
        self.predict_batch = _check_predict_batch(predict_batch)

    def fit(self, A, y, warm_start=None, resume_from=None) -> FitResult:
        """Solve the dual.  ``warm_start`` seeds alpha (shape (m,)) —
        e.g. the solution at a neighbouring C (see ``fit_path``);
        ``None`` is the usual cold start at zero.  ``resume_from``
        restores a mid-solve checkpoint directory written by a guarded
        fit (``options.checkpoint_every``) and continues from it."""
        _check_finite(A, "A")
        _check_finite(y, "y")
        result, op = _fit("ksvm", A, y, self.cfg, self.options,
                          a0=warm_start, resume_from=resume_from)
        self.A_, self.y_, self.alpha_ = A, y, result.alpha
        self.op_ = op
        self.result_ = result
        self._predictor = None
        return result

    def fit_path(self, A, y, Cs):
        """Warm-started solve ladder over a C grid
        (``repro.tune.path.reg_path``, DESIGN.md §10): one shared
        representation build, each solve seeded from its neighbour.
        Returns a ``PathResult``; the estimator is left fitted at the
        ladder's final (largest-C, least-regularized) member."""
        from repro.tune.path import reg_path
        path = reg_path(A, y, Cs=Cs, cfg=self.cfg, options=self.options)
        last = path.results[-1]
        self.cfg = dataclasses.replace(self.cfg, C=float(path.values[-1]))
        self.A_, self.y_, self.alpha_ = A, y, last.alpha
        self.op_ = path.op
        self.result_ = last
        self._predictor = None
        return path

    def decision_function(self, A_test):
        A_test = validate_queries(self.op_, A_test, name="A_test")
        _check_finite(A_test, "A_test")
        if self._predictor is None:
            self._predictor = BatchedPredictor(
                self.op_, self.alpha_ * self.y_,
                batch=self.predict_batch, compact=True)
        return self._predictor(A_test)

    def predict(self, A_test):
        return jnp.sign(self.decision_function(A_test))

    def save(self, directory: str) -> str:
        """Persist the fitted model as a serving artifact
        (``repro.serve.artifacts.save_model``, DESIGN.md §13): restore
        with ``repro.serve.load_model`` / ``ModelRegistry.load`` — no
        refit, no live estimator needed.  Returns the artifact path."""
        from repro.serve.artifacts import save_model
        return save_model(directory, self)


class KernelRidge:
    """Kernel ridge regression solved by (s-step) Block Dual Coordinate
    Descent.  Estimator facade over ``core.bdcd`` / ``core.sstep_bdcd``
    and their shard_map layouts; see module docstring and
    ``SolverOptions``.

    Like ``KernelSVM``, ``fit`` builds the representation operator once
    (``op_``) and ``predict`` serves through it batched and slab-free.
    """

    def __init__(self, lam: float = 1.0,
                 kernel: Union[str, KernelConfig, None] = None,
                 options: Optional[SolverOptions] = None,
                 predict_batch: int = 1024):
        _check_positive(lam, "lam")
        self.cfg = KRRConfig(lam=lam, kernel=_as_kernel(kernel))
        self.options = options or SolverOptions()
        self.predict_batch = _check_predict_batch(predict_batch)

    def fit(self, A, y, warm_start=None, resume_from=None) -> FitResult:
        """Solve the dual.  ``warm_start`` seeds alpha (shape (m,)) —
        e.g. the solution at a neighbouring lambda (see ``fit_path``);
        ``None`` is the usual cold start at zero.  ``resume_from``
        restores a mid-solve checkpoint directory written by a guarded
        fit (``options.checkpoint_every``) and continues from it."""
        _check_finite(A, "A")
        _check_finite(y, "y")
        result, op = _fit("krr", A, y, self.cfg, self.options,
                          a0=warm_start, resume_from=resume_from)
        self.A_, self.y_, self.alpha_ = A, y, result.alpha
        self.op_ = op
        self.result_ = result
        self._predictor = None
        return result

    def fit_path(self, A, y, lams):
        """Warm-started solve ladder over a lambda grid
        (``repro.tune.path.reg_path``, DESIGN.md §10): one shared
        representation build, each solve seeded from its neighbour.
        Returns a ``PathResult``; the estimator is left fitted at the
        ladder's final (smallest-lambda, least-regularized) member."""
        from repro.tune.path import reg_path
        path = reg_path(A, y, lams=lams, cfg=self.cfg,
                        options=self.options)
        last = path.results[-1]
        self.cfg = dataclasses.replace(self.cfg,
                                       lam=float(path.values[-1]))
        self.A_, self.y_, self.alpha_ = A, y, last.alpha
        self.op_ = path.op
        self.result_ = last
        self._predictor = None
        return path

    def predict(self, A_test):
        A_test = validate_queries(self.op_, A_test, name="A_test")
        _check_finite(A_test, "A_test")
        if self._predictor is None:
            self._predictor = BatchedPredictor(
                self.op_, self.alpha_, batch=self.predict_batch,
                scale=1.0 / self.cfg.lam)
        return self._predictor(A_test)

    def save(self, directory: str) -> str:
        """Persist the fitted model as a serving artifact
        (``repro.serve.artifacts.save_model``, DESIGN.md §13): restore
        with ``repro.serve.load_model`` / ``ModelRegistry.load`` — no
        refit, no live estimator needed.  Returns the artifact path."""
        from repro.serve.artifacts import save_model
        return save_model(directory, self)
