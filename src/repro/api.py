"""Unified solver facade: estimators over the paper's algorithm family.

The paper's value proposition is *same solution, tunable communication*:
classical vs s-step, block size b, and partition layout are tuning knobs
over ONE algorithm family.  This module is the single public seam that
reflects that (DESIGN.md §8):

    from repro.api import KernelSVM, KernelRidge, SolverOptions

    clf = KernelSVM(C=1.0, kernel="rbf",
                    options=SolverOptions(method="sstep", s=32,
                                          tol=1e-6, max_iters=2048))
    result = clf.fit(A, y)          # FitResult: alpha, history, comm model
    labels = clf.predict(A_test)

Dispatch covers {classical, sstep} x {serial, 1d, 2d}: the serial path
drives the shared round protocol (``core/loop.run_rounds``) directly —
one ``lax.scan`` when no tolerance/recording is requested (bit-compatible
with the legacy entrypoints), one ``lax.while_loop`` with a metric check
every ``check_every`` rounds otherwise.  The 1d/2d paths reuse the
``shard_map`` solvers in ``core/distributed``; their tolerance stopping
runs the same schedule in ``check_every``-round chunks with the metric
evaluated between chunks (round boundaries are identical because chunks
are whole multiples of s).

Convergence metrics: K-SVM stops on the duality gap
(``objectives.ksvm_duality_gap``); K-RR stops on the relative residual of
the optimality system (``objectives.krr_rel_residual``) — the paper's
rel-error needs the closed-form alpha*, which costs an m x m
factorization the facade refuses to hide inside ``fit``.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh_auto
from repro.core import (KernelConfig, KRRConfig, SVMConfig, NO_TOL,
                        bdcd_krr, block_schedule, coordinate_schedule,
                        dcd_ksvm, gram_slab, krr_predict, krr_rel_residual,
                        ksvm_duality_gap, ksvm_predict,
                        make_bdcd_round_fn, make_dcd_round_fn,
                        make_sstep_bdcd_round_fn, make_sstep_dcd_round_fn,
                        pad_rounds, run_rounds, sstep_bdcd_krr,
                        sstep_dcd_ksvm)
from repro.core import distributed
from repro.core.perf_model import modeled_fit_cost

METHODS = ("classical", "sstep")
LAYOUTS = ("serial", "1d", "2d")


@dataclasses.dataclass(frozen=True)
class SolverOptions:
    """How to run the solve — every knob of the paper's algorithm family.

    method:      "classical" (communicate every iteration) or "sstep"
                 (one communication round per s iterations, same iterates).
    s:           s-step depth (ignored for method="classical").
    b:           block size (K-RR only; K-SVM is scalar-coordinate).
    layout:      "serial", "1d" (paper's feature-partitioned shard_map
                 layout), or "2d" (samples x features, beyond paper).
    mesh:        jax Mesh for 1d/2d; auto-built over the host's devices
                 when None ("model"-major for 1d, "data"-major for 2d).
    slab_free:   consume kernel slabs through the GramOperator (default);
                 False forces the materialized-slab parity-oracle path
                 (serial and 1d only).
    tol:         stop once the convergence metric (duality gap for K-SVM,
                 relative residual for K-RR) falls to tol; 0 disables
                 early stopping.
    check_every: metric cadence, in outer rounds.
    max_iters:   total inner-iteration budget H.  H % s != 0 is fine —
                 the final short round is handled by pad-and-mask.
    record:      keep the metric history even when tol == 0.
    seed:        PRNG seed for the coordinate/block schedule.
    """

    method: str = "sstep"
    s: int = 16
    b: int = 1
    layout: str = "serial"
    mesh: Optional[object] = None
    slab_free: bool = True
    tol: float = 0.0
    check_every: int = 8
    max_iters: int = 1024
    record: bool = False
    seed: int = 0

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(
                f"method must be one of {METHODS}, got {self.method!r}")
        if self.layout not in LAYOUTS:
            raise ValueError(
                f"layout must be one of {LAYOUTS}, got {self.layout!r}")
        for name in ("s", "b", "max_iters", "check_every"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        if not self.tol >= 0.0:
            raise ValueError(f"tol must be >= 0, got {self.tol!r}")
        if not self.slab_free and self.layout == "2d":
            raise ValueError("the 2d layout is slab-free by construction; "
                             "slab_free=False is only meaningful for the "
                             "serial and 1d layouts")

    @property
    def s_eff(self) -> int:
        """Inner iterations per communication round (1 for classical)."""
        return self.s if self.method == "sstep" else 1


@dataclasses.dataclass
class FitResult:
    """Everything ``fit`` observed: the solution, the convergence
    trajectory, and the modeled communication cost of the run."""

    alpha: jnp.ndarray
    schedule: jnp.ndarray          # the iterations actually executed —
                                   # truncated to iters_run on early stop,
                                   # so replaying it through a legacy
                                   # entrypoint reproduces alpha
    history: Optional[np.ndarray]  # metric at each check point (or None)
    metric: str                    # "duality_gap" | "rel_residual"
    converged: bool
    rounds_run: int
    iters_run: int
    wall_time_s: float
    comm: dict                     # Hockney model: flops/words/msgs/time
    options: SolverOptions


def _as_kernel(kernel: Union[str, KernelConfig, None]) -> KernelConfig:
    if kernel is None:
        return KernelConfig()
    if isinstance(kernel, str):
        return KernelConfig(kernel)
    return kernel


def _resolve_mesh(opts: SolverOptions):
    """User mesh (validated for the layout's axis names) or an auto mesh
    over every visible device."""
    ndev = len(jax.devices())
    if opts.mesh is None:
        shape = (1, ndev) if opts.layout == "1d" else (ndev, 1)
        return make_mesh_auto(shape, ("data", "model"))
    need = ("model",) if opts.layout == "1d" else ("data", "model")
    missing = [ax for ax in need if ax not in opts.mesh.axis_names]
    if missing:
        raise ValueError(f"mesh lacks axes {missing} required by the "
                         f"{opts.layout!r} layout (has "
                         f"{opts.mesh.axis_names})")
    return opts.mesh


@partial(jax.jit, static_argnames=("cfg", "s", "check_every", "slab_free"))
def _ksvm_serial_tol(A, y, a0, schedule, tol, *, cfg: SVMConfig, s: int,
                     check_every: int, slab_free: bool):
    gram = None if slab_free else gram_slab
    if s == 1:
        rf, xs = make_dcd_round_fn(A, y, cfg, gram_fn=gram), schedule
    else:
        rf = make_sstep_dcd_round_fn(A, y, cfg, s, gram_fn=gram)
        xs = pad_rounds(schedule, s)
    return run_rounds(rf, a0, xs, tol=tol, check_every=check_every,
                      metric_fn=lambda a: ksvm_duality_gap(A, y, a, cfg))


@partial(jax.jit, static_argnames=("cfg", "s", "check_every", "slab_free"))
def _krr_serial_tol(A, y, a0, schedule, tol, *, cfg: KRRConfig, s: int,
                    check_every: int, slab_free: bool):
    gram = None if slab_free else gram_slab
    if s == 1:
        rf, xs = make_bdcd_round_fn(A, y, cfg, gram_fn=gram), schedule
    else:
        rf = make_sstep_bdcd_round_fn(A, y, cfg, s, gram_fn=gram)
        xs = pad_rounds(schedule, s)
    return run_rounds(rf, a0, xs, tol=tol, check_every=check_every,
                      metric_fn=lambda a: krr_rel_residual(A, y, a, cfg))


def _serial_fast(problem, A, y, a0, schedule, cfg, s, slab_free):
    """tol == 0, no recording: the legacy jitted entrypoints verbatim."""
    gram = None if slab_free else gram_slab
    if problem == "ksvm":
        if s == 1:
            return dcd_ksvm(A, y, a0, schedule, cfg, gram_fn=gram)[0]
        return sstep_dcd_ksvm(A, y, a0, schedule, cfg, s, gram_fn=gram)[0]
    if s == 1:
        return bdcd_krr(A, y, a0, schedule, cfg, gram_fn=gram)[0]
    return sstep_bdcd_krr(A, y, a0, schedule, cfg, s, gram_fn=gram)[0]


@partial(jax.jit, static_argnames=("problem", "layout", "mesh", "cfg",
                                   "s", "slab_free"))
def _dist_chunk(A, y, a0, schedule, *, problem, layout, mesh, cfg, s,
                slab_free):
    """Jit-cached wrapper around the shard_map solvers: the chunked
    tolerance loop re-enters here once per chunk, and every chunk of the
    same length hits the cache instead of re-tracing the shard_map body
    (at most two shapes compile per fit: the chunk and the ragged tail)."""
    return _dist_call(problem, layout, mesh, A, y, a0, schedule, cfg, s,
                      slab_free)


def _dist_call(problem, layout, mesh, A, y, a0, schedule, cfg, s,
               slab_free):
    if problem == "ksvm":
        if layout == "1d":
            return distributed.dist_sstep_dcd_ksvm(
                mesh, A, y, a0, schedule, cfg, s=s, slab_free=slab_free)
        return distributed.dist_sstep_dcd_ksvm_2d(
            mesh, A, y, a0, schedule, cfg, s=s)
    if layout == "1d":
        return distributed.dist_sstep_bdcd_krr(
            mesh, A, y, a0, schedule, cfg, s=s, slab_free=slab_free)
    return distributed.dist_sstep_bdcd_krr_2d(
        mesh, A, y, a0, schedule, cfg, s=s)


def _fit(problem: str, A, y, cfg, opts: SolverOptions) -> FitResult:
    m, n = A.shape
    H = opts.max_iters
    s = opts.s_eff
    b = opts.b if problem == "krr" else 1
    key = jax.random.key(opts.seed)
    if problem == "ksvm":
        schedule = coordinate_schedule(key, H, m)
        metric_name = "duality_gap"
        metric_host = lambda a: float(ksvm_duality_gap(A, y, a, cfg))
    else:
        schedule = block_schedule(key, H, m, b)
        metric_name = "rel_residual"
        metric_host = lambda a: float(krr_rel_residual(A, y, a, cfg))
    a0 = jnp.zeros(m, A.dtype)
    want_metric = opts.tol > 0.0 or opts.record
    tol = opts.tol if opts.tol > 0.0 else NO_TOL

    t0 = time.perf_counter()
    history = None
    converged = False
    if opts.layout == "serial":
        P = 1
        if not want_metric:
            alpha = _serial_fast(problem, A, y, a0, schedule, cfg, s,
                                 opts.slab_free)
            rounds_run = -(-H // s)
        else:
            solve = (_ksvm_serial_tol if problem == "ksvm"
                     else _krr_serial_tol)
            res = solve(A, y, a0, schedule, tol, cfg=cfg, s=s,
                        check_every=opts.check_every,
                        slab_free=opts.slab_free)
            alpha = res.state
            rounds_run = int(res.rounds_run)
            converged = bool(res.converged)
            history = np.asarray(res.metric_hist)[:int(res.checks_run)]
        iters_run = min(rounds_run * s, H)
    else:
        mesh = _resolve_mesh(opts)
        P = (mesh.shape["model"] if opts.layout == "1d"
             else mesh.shape["data"] * mesh.shape["model"])
        alpha = a0
        dist_kw = dict(problem=problem, layout=opts.layout, mesh=mesh,
                       cfg=cfg, s=s, slab_free=opts.slab_free)
        if not want_metric:
            alpha = _dist_chunk(A, y, alpha, schedule, **dist_kw)
            rounds_run, iters_run = -(-H // s), H
        else:
            # chunked early stopping: whole multiples of s per chunk keep
            # the round decomposition identical to the unchunked run.
            chunk = opts.check_every * s
            pos, rounds_run, hist = 0, 0, []
            while pos < H:
                sched_c = schedule[pos:pos + chunk]
                alpha = _dist_chunk(A, y, alpha, sched_c, **dist_kw)
                pos += sched_c.shape[0]
                rounds_run += -(-sched_c.shape[0] // s)
                val = metric_host(alpha)
                hist.append(val)
                if opts.tol > 0.0 and val <= opts.tol:
                    converged = True
                    break
            iters_run = pos
            history = np.asarray(hist)
    jax.block_until_ready(alpha)
    wall = time.perf_counter() - t0

    comm = modeled_fit_cost(m, n, cfg.kernel.name, b=b, s=s,
                            iters=iters_run, P=P)
    return FitResult(alpha=alpha, schedule=schedule[:iters_run],
                     history=history, metric=metric_name,
                     converged=converged,
                     rounds_run=rounds_run, iters_run=iters_run,
                     wall_time_s=wall, comm=comm, options=opts)


class KernelSVM:
    """Kernel SVM solved by (s-step) Dual Coordinate Descent.

    Estimator facade over ``core.dcd`` / ``core.sstep_dcd`` and their
    shard_map layouts; see module docstring and ``SolverOptions``.
    """

    def __init__(self, C: float = 1.0, loss: str = "l1",
                 kernel: Union[str, KernelConfig, None] = None,
                 options: Optional[SolverOptions] = None):
        self.cfg = SVMConfig(C=C, loss=loss, kernel=_as_kernel(kernel))
        self.options = options or SolverOptions()

    def fit(self, A, y) -> FitResult:
        result = _fit("ksvm", A, y, self.cfg, self.options)
        self.A_, self.y_, self.alpha_ = A, y, result.alpha
        self.result_ = result
        return result

    def decision_function(self, A_test):
        return ksvm_predict(self.A_, self.y_, self.alpha_, A_test, self.cfg)

    def predict(self, A_test):
        return jnp.sign(self.decision_function(A_test))


class KernelRidge:
    """Kernel ridge regression solved by (s-step) Block Dual Coordinate
    Descent.  Estimator facade over ``core.bdcd`` / ``core.sstep_bdcd``
    and their shard_map layouts; see module docstring and
    ``SolverOptions``.
    """

    def __init__(self, lam: float = 1.0,
                 kernel: Union[str, KernelConfig, None] = None,
                 options: Optional[SolverOptions] = None):
        self.cfg = KRRConfig(lam=lam, kernel=_as_kernel(kernel))
        self.options = options or SolverOptions()

    def fit(self, A, y) -> FitResult:
        result = _fit("krr", A, y, self.cfg, self.options)
        self.A_, self.alpha_ = A, result.alpha
        self.result_ = result
        return result

    def predict(self, A_test):
        return krr_predict(self.A_, self.alpha_, A_test, self.cfg)
