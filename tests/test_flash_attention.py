"""Flash attention Pallas kernels vs the jnp oracle (interpret mode):
forward values, logsumexp, and full gradients (dq, dk, dv) across shapes,
dtypes, causal/bidirectional, and distinct v head dims (MLA)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                    # property sweep is optional on bare envs
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.flash_attention import flash_attention, flash_bwd, \
    flash_fwd
from repro.kernels.ref import flash_attention_ref


def _rand(BH, S, T, hd, hdv, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (BH, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (BH, T, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (BH, T, hdv), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 128, 128, 32, 32),
                                   (1, 256, 256, 64, 64),
                                   (3, 64, 64, 16, 8)])
def test_fwd_matches_oracle(causal, shape):
    BH, S, T, hd, hdv = shape
    q, k, v = _rand(*shape, jnp.float32)
    o, lse = flash_fwd(q, k, v, causal=causal, bq=64, bk=64,
                       interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    assert bool(jnp.all(jnp.isfinite(lse)))


def test_fwd_bf16():
    q, k, v = _rand(2, 128, 128, 32, 32, jnp.bfloat16)
    o, _ = flash_fwd(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("causal", [True, False])
def test_grads_match_oracle(causal):
    BH, S, T, hd, hdv = 2, 128, 128, 32, 32
    q, k, v = _rand(BH, S, T, hd, hdv, jnp.float32, seed=3)

    def f_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal, None, 64, 64,
                                       True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, causal=causal) ** 2)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4,
                                   err_msg=f"d{name}")


def test_grads_mla_vdim():
    """v head dim != qk head dim (MLA: 192 qk / 128 v, scaled down)."""
    q, k, v = _rand(2, 64, 64, 48, 32, jnp.float32, seed=5)

    def f_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, None, 32, 32, True))

    def f_ref(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, causal=True))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(sblocks=st.integers(1, 4), hd=st.sampled_from([16, 32]),
           seed=st.integers(0, 5))
    def test_fwd_property_block_counts(sblocks, hd, seed):
        S = 32 * sblocks
        q, k, v = _rand(1, S, S, hd, hd, jnp.float32, seed=seed)
        o, _ = flash_fwd(q, k, v, causal=True, bq=32, bk=32,
                         interpret=True)
        want = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)


def test_model_forward_flash_matches_naive():
    """End-to-end: a dense model with attn_impl=flash equals the naive
    path (same params, same tokens)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import forward, init_params
    cfg = get_config("yi_6b", reduced=True)
    cfg_naive = dataclasses.replace(cfg, attn_impl="naive")
    cfg_flash = dataclasses.replace(cfg, attn_impl="flash")
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0,
                              cfg.vocab_size)
    a = forward(params, cfg_naive, toks)
    b = forward(params, cfg_flash, toks)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=5e-2, atol=5e-2)
