"""Dry-run methodology tests (subprocess: needs its own XLA device count).

Verifies the two facts the roofline extraction relies on:
  1. cost_analysis() is per-DEVICE under SPMD;
  2. a lax.scan (while) body is counted ONCE regardless of trip count, and
     the two-point unrolled probe recovers the true total.
Plus: HLO collective-byte parsing on a known program.
"""
import os
import pathlib
import subprocess
import sys

from repro.launch.hlo_analysis import collective_bytes, count_collectives

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")


def test_collective_parse_known_text():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = bf16[64]{0} all-gather(bf16[32]{0} %y), dimensions={0}
  %foo = f32[8,8]{1,0} add(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 256 * 4
    assert got["all-gather"] == 32 * 2          # operand bytes
    assert count_collectives(hlo) == {"all-reduce": 1, "all-gather": 1}


def test_cost_analysis_semantics():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.compat import make_mesh_auto
mesh = make_mesh_auto((4,), ("x",))
A = jax.ShapeDtypeStruct((1024, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P("x", None)))
B = jax.ShapeDtypeStruct((1024, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, None)))
c = jax.jit(lambda a, b: a @ b).lower(A, B).compile()
full = 2 * 1024**3
from repro.compat import cost_analysis
got = cost_analysis(c)["flops"]
assert abs(got - full / 4) / (full / 4) < 0.05, (got, full)  # per-device

def f(x):
    def body(h, _):
        return h @ h, None
    return jax.lax.scan(body, x, None, length=8)[0]
c2 = jax.jit(f).lower(jnp.ones((256, 256))).compile()
one = 2 * 256**3
got2 = cost_analysis(c2)["flops"]
assert abs(got2 - one) / one < 0.05, (got2, one)             # body once

def g(x):                                                    # unrolled
    for _ in range(8):
        x = x @ x
    return x
c3 = jax.jit(g).lower(jnp.ones((256, 256))).compile()
got3 = cost_analysis(c3)["flops"]
assert abs(got3 - 8 * one) / (8 * one) < 0.05, (got3,)      # full total
print("SEMANTICS-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "SEMANTICS-OK" in out.stdout


def test_probe_extrapolation_matches_unrolled():
    """extrapolated_costs(1,2 periods) must reproduce the true flops of a
    fully-unrolled model (within fp tolerance) on a small config."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.sharding import MeshRules
from repro.launch.dryrun import extrapolated_costs, _compile_costs, _probe_cfg

cfg = dataclasses.replace(get_config("qwen3_1p7b", reduced=True),
                          n_layers=6)
from repro.compat import make_mesh_auto
mesh = make_mesh_auto((2, 2), ("data", "model"))
rules = MeshRules(mesh)

# patch SHAPES with a tiny train shape for the probe
from repro.models import config as mc
mc.SHAPES["tiny_train"] = mc.ShapeConfig("tiny_train", 16, 8, "train")
est = extrapolated_costs(cfg, "tiny_train", rules)
truth, _ = _compile_costs(cfg, "tiny_train", rules, 1, unroll=True)
rel = abs(est["flops"] - truth["flops"]) / truth["flops"]
print("rel err", rel)
assert rel < 0.10, (est["flops"], truth["flops"])  # tiny-scale fusion jitter
print("PROBE-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "PROBE-OK" in out.stdout
