"""The paper's central mathematical claim: the s-step variants compute the
SAME iterates as the classical methods in exact arithmetic (Section 3).
We verify it in fp32 (tight tol) and fp64 (machine precision)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import (KernelConfig, KRRConfig, SVMConfig, bdcd_krr,
                        block_schedule, coordinate_schedule, dcd_ksvm,
                        krr_closed_form, ksvm_duality_gap,
                        relative_solution_error, sstep_bdcd_krr,
                        sstep_dcd_ksvm)
from repro.data.synthetic import classification_dataset, regression_dataset

KERNELS = [
    KernelConfig("linear"),
    KernelConfig("polynomial", degree=3, coef0=1.0),
    KernelConfig("rbf", sigma=1.0),
]


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("loss", ["l1", "l2"])
@pytest.mark.parametrize("s", [2, 8, 32])
def test_sstep_dcd_matches_dcd(kernel, loss, s):
    key = jax.random.key(0)
    A, y = classification_dataset(key, m=96, n=24)
    cfg = SVMConfig(C=1.0, loss=loss, kernel=kernel)
    H = 64
    sched = coordinate_schedule(jax.random.key(1), H, A.shape[0])
    a0 = jnp.zeros(A.shape[0])
    a_dcd, _ = dcd_ksvm(A, y, a0, sched, cfg)
    a_ss, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=s)
    np.testing.assert_allclose(np.asarray(a_ss), np.asarray(a_dcd),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("b", [1, 2, 4])
@pytest.mark.parametrize("s", [4, 16])
def test_sstep_bdcd_matches_bdcd(kernel, b, s):
    key = jax.random.key(2)
    A, y = regression_dataset(key, m=80, n=12)
    cfg = KRRConfig(lam=0.5, kernel=kernel)
    H = 32
    sched = block_schedule(jax.random.key(3), H, A.shape[0], b)
    a0 = jnp.zeros(A.shape[0])
    a_bd, _ = bdcd_krr(A, y, a0, sched, cfg)
    a_ss, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=s)
    np.testing.assert_allclose(np.asarray(a_ss), np.asarray(a_bd),
                               rtol=2e-4, atol=2e-5)


def test_equivalence_fp64_machine_precision():
    """Paper: 'compute the same solution as the existing methods in exact
    arithmetic' — at fp64 the deviation should be ~1e-12."""
    with enable_x64(True):
        key = jax.random.key(4)
        A, y = classification_dataset(key, m=64, n=16, dtype=jnp.float64)
        cfg = SVMConfig(C=1.0, loss="l1", kernel=KernelConfig("rbf"))
        sched = coordinate_schedule(jax.random.key(5), 64, 64)
        a0 = jnp.zeros(64, jnp.float64)
        a_dcd, _ = dcd_ksvm(A, y, a0, sched, cfg)
        a_ss, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=16)
        np.testing.assert_allclose(np.asarray(a_ss), np.asarray(a_dcd),
                                   rtol=1e-10, atol=1e-12)


def test_dcd_duality_gap_decreases():
    key = jax.random.key(6)
    A, y = classification_dataset(key, m=64, n=16)
    cfg = SVMConfig(C=1.0, loss="l1", kernel=KernelConfig("rbf"))
    sched = coordinate_schedule(jax.random.key(7), 512, 64)
    a0 = jnp.zeros(64)
    a_mid, _ = dcd_ksvm(A, y, a0, sched[:64], cfg)
    a_end, _ = dcd_ksvm(A, y, a0, sched, cfg)
    g0 = float(ksvm_duality_gap(A, y, a0, cfg))
    g1 = float(ksvm_duality_gap(A, y, a_mid, cfg))
    g2 = float(ksvm_duality_gap(A, y, a_end, cfg))
    assert g1 < g0 and g2 < g1
    assert g2 >= -1e-5   # gap stays nonnegative (weak duality)


def test_bdcd_converges_to_closed_form():
    key = jax.random.key(8)
    A, y = regression_dataset(key, m=48, n=8)
    cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf"))
    astar = krr_closed_form(A, y, cfg)
    sched = block_schedule(jax.random.key(9), 600, 48, 8)
    a, _ = bdcd_krr(A, y, jnp.zeros(48), sched, cfg)
    assert float(relative_solution_error(a, astar)) < 1e-4


def test_sstep_bdcd_converges_to_closed_form_large_s():
    """Paper Fig. 2: numerically stable even for s=256."""
    key = jax.random.key(10)
    A, y = regression_dataset(key, m=48, n=8)
    cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf"))
    astar = krr_closed_form(A, y, cfg)
    sched = block_schedule(jax.random.key(11), 512, 48, 4)
    a, _ = sstep_bdcd_krr(A, y, jnp.zeros(48), sched, cfg, s=256)
    assert float(relative_solution_error(a, astar)) < 1e-3


@pytest.mark.parametrize("s", [8, 32, 256])
@pytest.mark.parametrize("problem", ["ksvm", "krr"])
def test_guarded_sstep_stability_matrix(problem, s):
    """Numerical-stability matrix (DESIGN.md §12): the GUARDED s-step
    path — residual recurrence + periodic drift correction — matches the
    classical iterates in f32 even at deep s, and the recorded drift
    stays at roundoff level (no divergent residual-error growth in s)."""
    from repro.api import KernelRidge, KernelSVM, SolverOptions

    key = jax.random.key(20)
    H = 512
    opts = dict(max_iters=H, seed=5, slab_free=True)
    # cadence 1: s=256 leaves only ceil(512/256)=2 outer rounds, so the
    # correction must fire every round to be exercised at every s
    guard = dict(guard=True, recompute_every=1)
    if problem == "ksvm":
        A, y = classification_dataset(key, m=96, n=24)
        mk = lambda **kw: KernelSVM(
            C=1.0, kernel=KernelConfig("rbf", sigma=1.0),
            options=SolverOptions(**opts, **kw))
    else:
        A, y = regression_dataset(key, m=96, n=12)
        mk = lambda **kw: KernelRidge(
            lam=0.5, kernel=KernelConfig("rbf", sigma=1.0),
            options=SolverOptions(b=4, **opts, **kw))
    classical = mk(method="classical").fit(A, y)
    deep = mk(method="sstep", s=s, **guard).fit(A, y)
    np.testing.assert_allclose(np.asarray(deep.alpha),
                               np.asarray(classical.alpha),
                               rtol=2e-4, atol=2e-5)
    assert deep.health.corrections > 0
    assert deep.health.max_drift < 1e-4
