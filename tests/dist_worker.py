"""Subprocess worker for distributed-solver tests: forces 8 host devices
(must happen before jax import, and must NOT leak into the main pytest
process) and checks distributed == serial."""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.api import KernelRidge, KernelSVM, SolverOptions  # noqa: E402
from repro.core import (KernelConfig, KRRConfig, SVMConfig, bdcd_krr,
                        block_schedule, coordinate_schedule, dcd_ksvm,
                        sstep_bdcd_krr)                       # noqa: E402
from repro.core.distributed import (dist_bdcd_krr, dist_dcd_ksvm,
                                    dist_sstep_bdcd_krr,
                                    dist_sstep_bdcd_krr_2d,
                                    dist_sstep_dcd_ksvm,
                                    dist_sstep_dcd_ksvm_2d)   # noqa: E402
from repro.data.synthetic import (classification_dataset,
                                  regression_dataset)         # noqa: E402


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    failures = []

    # ---- K-SVM: serial DCD vs distributed s-step DCD (1D layout) ----
    # slab_free=True (default, fused-psum GramOperator) and =False (legacy
    # materialized-slab all-reduce) must BOTH match the serial solver.
    A, y = classification_dataset(jax.random.key(0), m=64, n=32)
    cfg = SVMConfig(C=1.0, loss="l1", kernel=KernelConfig("rbf"))
    sched = coordinate_schedule(jax.random.key(1), 32, 64)
    a0 = jnp.zeros(64)
    ref, _ = dcd_ksvm(A, y, a0, sched, cfg)
    for s in (1, 4, 16):
        for sf in (True, False):
            got = dist_sstep_dcd_ksvm(mesh, A, y, a0, sched, cfg, s=s,
                                      slab_free=sf)
            err = float(jnp.max(jnp.abs(got - ref)))
            print(f"dcd s={s} slab_free={sf} maxdiff={err:.3e}")
            if err > 5e-5:
                failures.append(f"dcd s={s} slab_free={sf}")
    got = dist_dcd_ksvm(mesh, A, y, a0, sched, cfg)
    if float(jnp.max(jnp.abs(got - ref))) > 5e-5:
        failures.append("dcd classical")

    # 2D DCD (samples x features) vs serial classical, incl. ragged H
    for H2 in (32, 27):
        sched2 = coordinate_schedule(jax.random.key(1), H2, 64)
        ref2, _ = dcd_ksvm(A, y, a0, sched2, cfg)
        got2 = dist_sstep_dcd_ksvm_2d(mesh, A, y, a0, sched2, cfg, s=8)
        err2 = float(jnp.max(jnp.abs(got2 - ref2)))
        print(f"dcd-2d H={H2} s=8 maxdiff={err2:.3e}")
        if err2 > 5e-5:
            failures.append(f"dcd2d H={H2}")

    # ---- repro.api facade on the REAL 8-device mesh ----
    # every (method, layout), with an explicit mesh and a ragged budget
    for method in ("classical", "sstep"):
        for layout in ("1d", "2d"):
            opts = SolverOptions(method=method, s=8, layout=layout,
                                 mesh=mesh, max_iters=27)
            clf = KernelSVM(C=1.0, loss="l1", kernel=KernelConfig("rbf"),
                            options=opts)
            res = clf.fit(A, y)
            reff, _ = dcd_ksvm(A, y, a0, res.schedule, clf.cfg)
            err = float(jnp.max(jnp.abs(res.alpha - reff)))
            print(f"api ksvm {method}/{layout} maxdiff={err:.3e}")
            if err > 5e-5:
                failures.append(f"api ksvm {method}/{layout}")

    # ---- K-RR: serial BDCD vs distributed (1D + 2D layouts) ----
    A, y = regression_dataset(jax.random.key(2), m=64, n=32)
    kcfg = KRRConfig(lam=0.7, kernel=KernelConfig("polynomial", degree=2,
                                                  coef0=1.0))
    bsched = block_schedule(jax.random.key(3), 16, 64, 4)
    ref, _ = bdcd_krr(A, y, a0, bsched, kcfg)
    for s in (1, 4):
        for sf in (True, False):
            got = dist_sstep_bdcd_krr(mesh, A, y, a0, bsched, kcfg, s=s,
                                      slab_free=sf)
            err = float(jnp.max(jnp.abs(got - ref)))
            print(f"bdcd-1d s={s} slab_free={sf} maxdiff={err:.3e}")
            if err > 5e-5:
                failures.append(f"bdcd1d s={s} slab_free={sf}")
        got2 = dist_sstep_bdcd_krr_2d(mesh, A, y, a0, bsched, kcfg, s=s)
        err2 = float(jnp.max(jnp.abs(got2 - ref)))
        print(f"bdcd-2d s={s} maxdiff={err2:.3e}")
        if err2 > 5e-5:
            failures.append(f"bdcd2d s={s}")
    got = dist_bdcd_krr(mesh, A, y, a0, bsched, kcfg)
    if float(jnp.max(jnp.abs(got - ref))) > 5e-5:
        failures.append("bdcd classical")

    # facade K-RR on the real mesh: tolerance-stopped 1d + 2d runs
    for layout in ("1d", "2d"):
        opts = SolverOptions(method="sstep", s=4, b=4, layout=layout,
                             mesh=mesh, tol=5e-2, check_every=2,
                             max_iters=400)
        res = KernelRidge(lam=1.0, kernel=KernelConfig("rbf"),
                          options=opts).fit(A, y)
        print(f"api krr {layout} tol-stop: converged={res.converged} "
              f"iters={res.iters_run} metric={res.metric_history()[-1]:.3e}")
        if not (res.converged and res.iters_run < 400):
            failures.append(f"api krr {layout} tol")

    # ---- linear kernel: the fully-contracted (no m x sb psum) path ----
    kcfg = KRRConfig(lam=0.7, kernel=KernelConfig("linear"))
    ref, _ = bdcd_krr(A, y, a0, bsched, kcfg)
    got = dist_sstep_bdcd_krr(mesh, A, y, a0, bsched, kcfg, s=4)
    err = float(jnp.max(jnp.abs(got - ref)))
    print(f"bdcd-1d linear slab-free maxdiff={err:.3e}")
    if err > 5e-5:
        failures.append("bdcd1d linear")

    # ---- custom per-rank operator through the op_factory seam ----
    # (DESIGN.md §9): injecting AllreduceGramOperator explicitly must
    # reproduce the default path bit-for-bit on both solver families.
    from repro.core.distributed import AllreduceGramOperator

    def custom_factory(A_loc, kcfg_):
        rs = None
        if kcfg_.name == "rbf":
            rs = jax.lax.psum(jnp.sum(A_loc * A_loc, axis=1), "model")
        return AllreduceGramOperator("model", A_loc, kcfg_, rs)

    kcfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf", sigma=0.5))
    ref, _ = sstep_bdcd_krr(A, y, a0, bsched, kcfg, s=4)
    got = dist_sstep_bdcd_krr(mesh, A, y, a0, bsched, kcfg, s=4,
                              op_factory=custom_factory)
    err = float(jnp.max(jnp.abs(got - ref)))
    print(f"bdcd-1d custom op_factory maxdiff={err:.3e}")
    if err > 5e-5:
        failures.append("bdcd1d op_factory")
    Ac, yc = classification_dataset(jax.random.key(0), m=64, n=32)
    scfg = SVMConfig(C=1.0, loss="l1", kernel=KernelConfig("rbf"))
    csched = coordinate_schedule(jax.random.key(1), 32, 64)
    ref, _ = dcd_ksvm(Ac, yc, a0, csched, scfg)
    got = dist_sstep_dcd_ksvm(mesh, Ac, yc, a0, csched, scfg, s=4,
                              op_factory=custom_factory)
    err = float(jnp.max(jnp.abs(got - ref)))
    print(f"dcd-1d custom op_factory maxdiff={err:.3e}")
    if err > 5e-5:
        failures.append("dcd1d op_factory")

    # ---- RBF kernel through the 2D path too ----
    kcfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf", sigma=0.5))
    ref, _ = sstep_bdcd_krr(A, y, a0, bsched, kcfg, s=4)
    got = dist_sstep_bdcd_krr_2d(mesh, A, y, a0, bsched, kcfg, s=4)
    err = float(jnp.max(jnp.abs(got - ref)))
    print(f"bdcd-2d rbf maxdiff={err:.3e}")
    if err > 5e-5:
        failures.append("bdcd2d rbf")

    # ---- low-rank representation on the REAL mesh (DESIGN.md §9) ----
    # same seed -> same landmarks/Phi, so every layout must land on the
    # serial Nystrom iterates; the 1d layout shards Phi's l columns.
    ny = dict(method="sstep", s=4, b=4, max_iters=16, seed=7,
              approx="nystrom", landmarks=16)
    ref_ny = KernelRidge(lam=1.0, kernel=KernelConfig("rbf", sigma=0.5),
                         options=SolverOptions(layout="serial", **ny)
                         ).fit(A, y).alpha
    for layout in ("1d", "2d"):
        res = KernelRidge(lam=1.0, kernel=KernelConfig("rbf", sigma=0.5),
                          options=SolverOptions(layout=layout, mesh=mesh,
                                                **ny)).fit(A, y)
        err = float(jnp.max(jnp.abs(res.alpha - ref_ny)))
        print(f"api krr nystrom {layout} maxdiff={err:.3e}")
        if err > 5e-5:
            failures.append(f"api krr nystrom {layout}")

    # ---- defer_s train step EXECUTES and matches plain training ----
    import dataclasses
    from repro.configs import get_config
    from repro.models import init_params
    from repro.optim import AdamWConfig, adamw_init
    from repro.train.train_step import (TrainConfig, make_defer_train_step,
                                        make_train_step)
    from repro.data.tokens import TokenPipeline

    cfg = dataclasses.replace(get_config("qwen3_1p7b", reduced=True),
                              remat="none")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    from repro.models.sharding import MeshRules
    rules = MeshRules(mesh)
    acfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                         global_batch=16, seed=3)

    p1 = init_params(jax.random.key(5), cfg)
    o1 = adamw_init(p1)
    plain = make_train_step(cfg, acfg, TrainConfig(microbatches=4))
    p2 = init_params(jax.random.key(5), cfg)   # fresh buffers: the steps
    o2 = adamw_init(p2)                        # donate their inputs
    defer = make_defer_train_step(cfg, acfg,
                                  TrainConfig(microbatches=4, defer_s=4),
                                  rules)
    for step in range(2):
        batch = pipe.batch(step)
        p1, o1, m1 = plain(p1, o1, batch)
        p2, o2, m2 = defer(p2, o2, batch)
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        print(f"defer step {step}: plain={float(m1['loss']):.5f} "
              f"defer={float(m2['loss']):.5f}")
        if dl > 5e-3:
            failures.append(f"defer loss mismatch {dl}")
    dev = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print(f"defer param maxdiff after 2 steps: {dev:.2e}")
    if dev > 5e-3:
        failures.append(f"defer param dev {dev}")

    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
