"""Fused KMV Pallas kernel (interpret mode on CPU) vs the materialized
oracle, plus the slab-free jnp contraction and GramOperator surface.

The contract under test: ``kmv(A, B, X) == K(A, B)^T X`` for all three
paper kernels, any (non-block-aligned) shape, vector and multi-column X —
WITHOUT the kernel ever writing the m x r slab (structural property of
the Pallas grid; numerics checked here)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels import (ExactGramOperator, KernelConfig, gram_slab,
                                kernel_diag, kmv_slab_free)
from repro.kernels.kmv import kmv_pallas
from repro.kernels.ref import kmv_ref

KERNELS = [
    KernelConfig("linear"),
    KernelConfig("polynomial", degree=3, coef0=1.0),
    KernelConfig("rbf", sigma=0.7),
]


def _data(m, r, n, c, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.key(m * 100 + r * 10 + n), 3)
    A = jax.random.normal(k1, (m, n), jnp.float32).astype(dtype)
    B = jax.random.normal(k2, (r, n), jnp.float32).astype(dtype)
    X = jax.random.normal(k3, (m, c), jnp.float32)
    return A, B, X


def _check_pallas(m, r, n, c, cfg, dtype=jnp.float32, bm=32, br=16, bk=128):
    A, B, X = _data(m, r, n, c, dtype)
    got = kmv_pallas(A, B, X, cfg, bm=bm, br=br, bk=bk, interpret=True)
    want = kmv_ref(A, B, X, cfg)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("cfg", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("shape", [(96, 24, 64, 1), (64, 32, 256, 4),
                                   (33, 17, 100, 2), (8, 1, 16, 1),
                                   (130, 70, 384, 3)])
def test_kmv_matches_oracle_f32(cfg, shape):
    _check_pallas(*shape, cfg=cfg)


@pytest.mark.parametrize("cfg", KERNELS, ids=lambda k: k.name)
def test_kmv_matches_oracle_bf16_inputs(cfg):
    _check_pallas(64, 24, 256, 2, cfg=cfg, dtype=jnp.bfloat16)


@pytest.mark.parametrize("blocks", [(16, 8, 128), (32, 32, 256),
                                    (64, 16, 128)])
def test_kmv_block_shape_invariance(blocks):
    bm, br, bk = blocks
    _check_pallas(96, 40, 384, 2, cfg=KernelConfig("rbf", sigma=1.0),
                  bm=bm, br=br, bk=bk)


@pytest.mark.parametrize("cfg", KERNELS, ids=lambda k: k.name)
def test_kmv_vector_rhs(cfg):
    """(m,) X must round-trip as a vector, matching the (m, 1) result."""
    A, B, X = _data(48, 12, 64, 1)
    got = kmv_pallas(A, B, X[:, 0], cfg, bm=16, br=8, bk=128,
                     interpret=True)
    assert got.shape == (12,)
    want = kmv_ref(A, B, X, cfg)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cfg", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("shape", [(96, 24, 64, 1), (50, 7, 33, 3)])
def test_kmv_slab_free_jnp_matches_oracle(cfg, shape):
    """The blocked-scan jnp contraction (GramOperator default backend)."""
    m, r, n, c = shape
    A, B, X = _data(m, r, n, c)
    got = kmv_slab_free(A, B, X, cfg, block=16)
    want = kmv_ref(A, B, X, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cfg", KERNELS, ids=lambda k: k.name)
def test_gram_operator_surface(cfg):
    """matvec / cross_block / diag / round_data against slab algebra."""
    A, _, X = _data(60, 1, 40, 1)
    idx = jnp.array([3, 17, 3, 59, 0])          # duplicates allowed
    op = ExactGramOperator(A, cfg, block=16)
    U = gram_slab(A, A[idx], cfg)
    np.testing.assert_allclose(np.asarray(op.matvec(idx, X[:, 0])),
                               np.asarray(U.T @ X[:, 0]), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(op.cross_block(idx)),
                               np.asarray(U[idx, :]), rtol=1e-6, atol=1e-6)
    # diag is EXACT (1.0 for RBF) while the slab diagonal suffers
    # ||a-a||^2 cancellation — compare at the slab's accuracy.
    np.testing.assert_allclose(np.asarray(op.diag(idx)),
                               np.asarray(jnp.diagonal(U[idx, :])),
                               rtol=1e-5, atol=1e-5)
    G, uTx = op.round_data(idx, X[:, 0])
    np.testing.assert_allclose(np.asarray(G), np.asarray(U[idx, :]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(uTx), np.asarray(U.T @ X[:, 0]),
                               rtol=2e-5, atol=2e-5)


def test_kernel_diag_matches_gram_diagonal():
    A = jax.random.normal(jax.random.key(7), (20, 16))
    for cfg in KERNELS:
        want = jnp.diagonal(gram_slab(A, A, cfg))
        np.testing.assert_allclose(np.asarray(kernel_diag(A, cfg)),
                                   np.asarray(want), rtol=1e-5, atol=1e-6)


def test_kmv_pallas_operator_end_to_end():
    """s-step DCD driven by the Pallas-KMV GramOperator backend == the
    materialized-slab solver (kernels.ops.make_solver_op_factory path)."""
    from repro.core import SVMConfig, coordinate_schedule, sstep_dcd_ksvm
    from repro.core.kernels import gram_slab as gs
    from repro.data.synthetic import classification_dataset
    from repro.kernels.ops import make_solver_op_factory

    A, y = classification_dataset(jax.random.key(1), m=48, n=32)
    cfg = SVMConfig(C=1.0, loss="l2", kernel=KernelConfig("rbf"))
    sched = coordinate_schedule(jax.random.key(2), 16, 48)
    a0 = jnp.zeros(48)
    ref, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=8, gram_fn=gs)
    factory = make_solver_op_factory(use_pallas=True, interpret=True,
                                     bm=16, br=8, bk=128)
    got, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=8, op_factory=factory)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
