"""Out-of-core streaming representation (DESIGN.md §14).

The contract under test: a ``StreamingGramOperator`` — X chunked into
row blocks, contractions streamed chunk-at-a-time (double-buffered DMA
on TPU, ``lax.scan`` elsewhere) — is numerically INTERCHANGEABLE with
the resident ``ExactGramOperator`` across every consumer (the four
round-fn factories via the facade, guarded solves, the fleet, batched
serving), while its device working set is bounded by ONE chunk instead
of all of X.  The device-memory claim is enforced through the perf
model (``streaming_required`` / ``stream_chunk_fits``): CPU CI has no
real HBM ceiling, so the acceptance test pins a budget under which the
resident representation is infeasible and the streamed one fits, then
demands ≤1e-5 solution parity anyway.
"""
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AUTO, KernelRidge, KernelSVM, SolverOptions
from repro.core.kernels import (ExactGramOperator, KernelConfig,
                                StreamingGramOperator)
from repro.core.perf_model import (STREAM_CHUNK_CANDIDATES,
                                   choose_chunk_rows, modeled_predict_cost,
                                   stream_chunk_fits, stream_pipeline_cost,
                                   stream_working_set_bytes,
                                   streaming_required)
from repro.core.predict import BatchedPredictor
from repro.data.synthetic import classification_dataset, regression_dataset
from repro.kernels.kmv_stream import kmv_stream_pallas
from repro.kernels.ref import kmv_ref

KERNELS = [
    KernelConfig("linear"),
    KernelConfig("polynomial", degree=3, coef0=1.0),
    KernelConfig("rbf", sigma=0.9),
]
TOL = dict(rtol=1e-5, atol=1e-5)
M, N = 56, 9                      # 56 % 16 != 0: ragged last chunk


def _ops(cfg, m=M, n=N, chunk_rows=16, dtype=jnp.float32, seed=3):
    A = jax.random.normal(jax.random.key(seed), (m, n),
                          jnp.float32).astype(dtype)
    return (ExactGramOperator(A, cfg),
            StreamingGramOperator.from_dense(A, cfg,
                                             chunk_rows=chunk_rows))


# ---------------------------------------------------------------------------
# operator parity: every GramOperator method, chunked vs resident
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("cfg", KERNELS, ids=lambda k: k.name)
def test_operator_parity(cfg, dtype):
    exact, stream = _ops(cfg, dtype=dtype)
    tol = dict(rtol=5e-2, atol=5e-1) if dtype == jnp.bfloat16 else TOL
    idx = jnp.asarray([0, 7, 19, 55])          # spans the ragged tail
    X = jax.random.normal(jax.random.key(9), (M, 3))
    w = jax.random.normal(jax.random.key(11), (M,))
    for name, got, want in [
        ("rows", stream.rows(idx), exact.rows(idx)),
        ("diag", stream.diag(idx), exact.diag(idx)),
        ("matvec", stream.matvec(idx, X), exact.matvec(idx, X)),
        ("cross", stream.cross_block(idx), exact.cross_block(idx)),
        ("apply_at", stream.apply_at(idx, X[:4]), exact.apply_at(idx,
                                                                 X[:4])),
        ("full_mv", stream.full_matvec(X[:, 0]), exact.full_matvec(
            X[:, 0])),
        ("serve", stream.serve_block(exact.rows(idx), w),
         exact.serve_block(exact.rows(idx), w)),
    ]:
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   err_msg=name, **tol)


@pytest.mark.parametrize("cfg", KERNELS, ids=lambda k: k.name)
def test_take_and_scale_rows_rechunk(cfg):
    exact, stream = _ops(cfg)
    y = jax.random.normal(jax.random.key(4), (M,))
    keep = jnp.asarray([3, 17, 20, 41, 55])
    se, ss = exact.scale_rows(y).take(keep), stream.scale_rows(y).take(keep)
    assert isinstance(ss, StreamingGramOperator)
    assert ss.n_samples == keep.size
    idx = jnp.arange(keep.size)
    np.testing.assert_allclose(np.asarray(ss.cross_block(idx)),
                               np.asarray(se.cross_block(idx)), **TOL)


def test_operator_is_pytree_and_jittable():
    _, stream = _ops(KernelConfig("rbf", sigma=0.9))
    leaves, treedef = jax.tree_util.tree_flatten(stream)
    assert jax.tree_util.tree_unflatten(treedef, leaves).chunk_rows \
        == stream.chunk_rows

    @jax.jit
    def f(op, v):
        return op.full_matvec(v)

    v = jnp.ones((M,))
    np.testing.assert_allclose(np.asarray(f(stream, v)),
                               np.asarray(stream.full_matvec(v)), **TOL)


def test_chunk_rows_validated():
    A = jnp.zeros((8, 3))
    cfg = KernelConfig("linear")
    for bad in (0, -1, 2.5, "16"):
        with pytest.raises((ValueError, TypeError)):
            StreamingGramOperator.from_dense(A, cfg, chunk_rows=bad)
    # larger than m clips instead of failing (single-chunk degenerate)
    op = StreamingGramOperator.from_dense(A, cfg, chunk_rows=64)
    assert op.n_chunks == 1 and op.chunk_rows == 8


# ---------------------------------------------------------------------------
# the double-buffered Pallas kernel (interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c", [1, 5], ids=["vec", "mat"])
@pytest.mark.parametrize("cfg", KERNELS, ids=lambda k: k.name)
def test_kmv_stream_pallas_matches_oracle(cfg, c):
    k1, k2, k3 = jax.random.split(jax.random.key(7), 3)
    nc, cr, n, r = 4, 14, 9, 11            # nothing lane/sublane aligned
    Xc = jax.random.normal(k1, (nc, cr, n), jnp.float32)
    B = jax.random.normal(k2, (r, n), jnp.float32)
    Xvc = jax.random.normal(k3, (nc, cr, c), jnp.float32)
    got = kmv_stream_pallas(Xc, B, Xvc, cfg, interpret=True)
    want = kmv_ref(Xc.reshape(nc * cr, n), B, Xvc.reshape(nc * cr, c), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_kmv_stream_ragged_tail_zero_padded():
    # a zero-padded tail chunk must contribute NOTHING even for RBF
    # (K(0, b) = exp(-s|b|^2) != 0): contraction safety comes from the
    # zero RHS rows, which is exactly what StreamingGramOperator pads
    cfg = KernelConfig("rbf", sigma=0.9)
    _, stream = _ops(cfg, m=50, chunk_rows=16)   # tail chunk: 2 live rows
    exact, _ = _ops(cfg, m=50, chunk_rows=16)
    v = jax.random.normal(jax.random.key(1), (50,))
    np.testing.assert_allclose(np.asarray(stream.full_matvec(v)),
                               np.asarray(exact.full_matvec(v)), **TOL)


# ---------------------------------------------------------------------------
# facade: streamed fits match resident fits across solvers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def krr_data():
    return regression_dataset(jax.random.key(2), m=64, n=8)


@pytest.fixture(scope="module")
def svm_data():
    return classification_dataset(jax.random.key(0), m=64, n=8)


@pytest.mark.parametrize("method", ["classical", "sstep"])
def test_krr_stream_matches_resident(krr_data, method):
    A, y = krr_data
    kw = dict(method=method, s=4, b=4, max_iters=24, record=False)
    res = KernelRidge(lam=0.5, kernel="rbf",
                      options=SolverOptions(**kw)).fit(A, y)
    strm = KernelRidge(lam=0.5, kernel="rbf",
                       options=SolverOptions(stream=16, **kw)).fit(A, y)
    np.testing.assert_allclose(np.asarray(strm.alpha), np.asarray(
        res.alpha), **TOL)


@pytest.mark.parametrize("method", ["classical", "sstep"])
def test_ksvm_stream_matches_resident(svm_data, method):
    A, y = svm_data
    kw = dict(method=method, s=4, max_iters=24, record=False)
    res = KernelSVM(C=1.0, kernel="rbf",
                    options=SolverOptions(**kw)).fit(A, y)
    strm = KernelSVM(C=1.0, kernel="rbf",
                     options=SolverOptions(stream=16, **kw)).fit(A, y)
    np.testing.assert_allclose(np.asarray(strm.alpha), np.asarray(
        res.alpha), **TOL)


def test_stream_options_validated():
    with pytest.raises(ValueError):
        SolverOptions(stream=0)
    with pytest.raises(ValueError):
        SolverOptions(stream=16, slab_free=False)
    with pytest.raises(ValueError):
        SolverOptions(stream=16, layout="1d")
    with pytest.raises(ValueError):
        SolverOptions(stream=16, approx="nystrom")
    assert SolverOptions(stream=True).stream == AUTO
    assert SolverOptions(stream=False).stream is None
    assert SolverOptions(stream=AUTO).needs_autotune


@pytest.mark.skipif(os.environ.get("REPRO_SANITIZE") == "1",
                    reason="the guard's health machinery carries "
                           "inf/-inf sentinels by design (same reason "
                           "the resilience modules sit outside "
                           "KERNEL_TEST_MODULES) — debug_infs trips on "
                           "them, not on the streamed kernel")
def test_guarded_stream_drift_correction(krr_data):
    A, y = krr_data
    kw = dict(max_iters=24, record=False, guard=True, recompute_every=2)
    res = KernelRidge(lam=0.5, kernel="rbf",
                      options=SolverOptions(**kw)).fit(A, y)
    strm = KernelRidge(lam=0.5, kernel="rbf",
                       options=SolverOptions(stream=16, **kw)).fit(A, y)
    np.testing.assert_allclose(np.asarray(strm.alpha),
                               np.asarray(res.alpha), **TOL)
    assert strm.health is not None and strm.health.guarded
    # the guard's drift correction ran through the STREAMED full_matvec
    assert strm.health.corrections > 0


# ---------------------------------------------------------------------------
# serving: streamed predict == resident predict
# ---------------------------------------------------------------------------

def test_predict_over_streamed_operator(krr_data):
    A, y = krr_data
    kw = dict(max_iters=24, record=False)
    Aq = np.asarray(jax.random.normal(jax.random.key(5), (37, A.shape[1])))
    mr = KernelRidge(lam=0.5, kernel="rbf", options=SolverOptions(**kw))
    mr.fit(A, y)
    ms = KernelRidge(lam=0.5, kernel="rbf",
                     options=SolverOptions(stream=16, **kw))
    ms.fit(A, y)
    np.testing.assert_allclose(np.asarray(ms.predict(jnp.asarray(Aq))),
                               np.asarray(mr.predict(jnp.asarray(Aq))),
                               **TOL)


def test_batched_predictor_query_streaming():
    cfg = KernelConfig("rbf", sigma=0.9)
    exact, stream_op = _ops(cfg)
    w = jax.random.normal(jax.random.key(6), (M,))
    Xq = np.asarray(jax.random.normal(jax.random.key(8),
                                      (301, N)), np.float32)  # host array
    want = BatchedPredictor(exact, w, batch=64)(jnp.asarray(Xq))
    # query-side streaming (host chunks) x representation-side streaming
    got = BatchedPredictor(stream_op, w, batch=64, stream=48)(Xq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    with pytest.raises(ValueError):
        BatchedPredictor(exact, w, stream=0)


def test_modeled_predict_cost_stream_terms():
    base = modeled_predict_cost(4096, 64, 2048, "rbf")
    strm = modeled_predict_cost(4096, 64, 2048, "rbf", stream=256)
    assert strm["stream_chunks"] == 2048 // 256
    # overlapped streamed serving costs at least the pure-compute bound
    # and at most compute + every chunk's DMA (no-overlap worst case)
    assert base["time"] <= strm["time"] \
        <= base["time"] + 2 * strm["t_dma"] + 1e-12
    assert strm["t_overlap"] >= 0.0


# ---------------------------------------------------------------------------
# fleet over a shared streamed operator
# ---------------------------------------------------------------------------

def test_fleet_over_stream(krr_data):
    from repro.tune.fleet import solve_fleet
    A, y = krr_data
    lams = [0.1, 1.0]
    kw = dict(max_iters=16, record=False)
    f0 = solve_fleet(A, y, lams=lams, kernel="rbf",
                     options=SolverOptions(**kw))
    f1 = solve_fleet(A, y, lams=lams, kernel="rbf",
                     options=SolverOptions(stream=16, **kw))
    assert isinstance(f1.op, StreamingGramOperator)
    np.testing.assert_allclose(np.asarray(f1.alpha), np.asarray(f0.alpha),
                               **TOL)


# ---------------------------------------------------------------------------
# autotuner: chunk_rows="auto" under the working-set constraint
# ---------------------------------------------------------------------------

def test_choose_chunk_rows_respects_working_set():
    m, n, sb = 1 << 20, 256, 64
    budget = 4 * 2 ** 20                    # 4 MB on-chip budget
    cr = choose_chunk_rows(m, n, sb, "rbf", budget_bytes=budget)
    assert stream_chunk_fits(cr, n, sb, budget_bytes=budget)
    # every INfeasible candidate the search rejected really is bigger
    _, frontier = choose_chunk_rows(m, n, sb, "rbf", budget_bytes=budget,
                                    return_frontier=True)
    for row in frontier:
        if not row["feasible"]:
            assert row["working_set_bytes"] > budget
    # candidates never exceed the problem (degenerate small m)
    assert choose_chunk_rows(10, n, sb, "rbf") <= 10


def test_facade_resolves_stream_auto(krr_data):
    A, y = krr_data
    est = KernelRidge(lam=0.5, kernel="rbf",
                      options=SolverOptions(stream="auto", max_iters=8,
                                            record=False))
    r = est.fit(A, y)
    assert isinstance(r.options.stream, int) and r.options.stream >= 1
    assert r.plan is not None
    assert isinstance(est.op_, StreamingGramOperator)
    sb = r.options.s_eff * (r.options.b if isinstance(r.options.b, int)
                            else 1)
    assert stream_chunk_fits(r.options.stream, A.shape[1], sb)


# ---------------------------------------------------------------------------
# perf model: pipeline overlap accounting
# ---------------------------------------------------------------------------

def test_stream_pipeline_cost_overlap_bounds():
    for cr in (128, 1024, 8192):
        p = stream_pipeline_cost(1 << 18, 128, 32, cr, "rbf")
        assert p["time"] <= p["time_unoverlapped"] + 1e-18
        assert 1.0 <= p["overlap_speedup"] <= 2.0 + 1e-12
        assert p["streamed_over_resident"] >= 1.0
        if p["compute_bound"]:
            # compute-bound: streaming costs one warm-up DMA, nothing per
            # steady chunk — the fig10 gate's modeled justification
            assert p["time"] <= p["resident_time"] + p["t_dma"] + 1e-18


def test_streaming_required_gate():
    # 1M x 256 f32 X is ~1 GB: resident fails a 256 MB device, streaming
    # with a fitting chunk succeeds — the acceptance criterion's gate
    m, n, sb = 1 << 20, 256, 64
    device = 256 * 2 ** 20
    assert streaming_required(m, n, sb, device_bytes=device)
    assert not streaming_required(1 << 10, n, sb, device_bytes=device)
    cr = choose_chunk_rows(m, n, sb, "rbf", budget_bytes=4 * 2 ** 20)
    assert stream_working_set_bytes(cr, n, sb) < device


def test_out_of_core_acceptance():
    """ISSUE acceptance: solve a problem whose resident working set
    EXCEEDS the configured device budget (perf-model-enforced — CPU CI
    has no real HBM ceiling) with the streamed representation, matching
    the resident solve to 1e-5."""
    m, n = 96, 24
    opts = SolverOptions(s=4, b=4, max_iters=24, record=False)
    sb = opts.s_eff * opts.b
    # budget chosen between the streamed and resident working sets:
    word = 4
    resident_bytes = word * (m * n + m + sb * n + sb)
    chunk = 16
    assert stream_chunk_fits(chunk, n, sb,
                             budget_bytes=resident_bytes - 1)
    assert streaming_required(m, n, sb,
                              device_bytes=resident_bytes - 1)
    A, y = regression_dataset(jax.random.key(12), m=m, n=n)
    res = KernelRidge(lam=0.5, kernel="rbf", options=opts).fit(A, y)
    strm = KernelRidge(
        lam=0.5, kernel="rbf",
        options=SolverOptions(stream=chunk, s=4, b=4, max_iters=24,
                              record=False)).fit(A, y)
    err = float(jnp.max(jnp.abs(strm.alpha - res.alpha)))
    assert err <= 1e-5, err


# ---------------------------------------------------------------------------
# analysis: CHK-DMA statics over the double-buffer discipline
# ---------------------------------------------------------------------------

_DMA_BAD = textwrap.dedent('''
    def k_never_waited(x_hbm, o_ref):
        def body(buf, sem):
            pltpu.make_async_copy(x_hbm.at[0], buf.at[0],
                                  sem.at[0]).start()
            o_ref[...] = buf[0]
        pl.run_scoped(body)


    def k_no_start(x_hbm, o_ref):
        def body(buf, sem):
            pltpu.make_async_copy(x_hbm.at[0], buf.at[0],
                                  sem.at[0]).wait()
        pl.run_scoped(body)


    def k_same_slot(x_hbm, o_ref, nc):
        def body(buf, sem):
            pltpu.make_async_copy(x_hbm.at[0], buf.at[0],
                                  sem.at[0]).start()
            def loop(i, _):
                slot = jax.lax.rem(i, 2)
                pltpu.make_async_copy(x_hbm.at[i + 1], buf.at[slot],
                                      sem.at[slot]).start()
                pltpu.make_async_copy(x_hbm.at[i], buf.at[slot],
                                      sem.at[slot]).wait()
            jax.lax.fori_loop(0, nc, loop, None)
        pl.run_scoped(body)
''')


def test_chk_dma_catches_all_three_races(tmp_path):
    from repro.analysis.pallas_check import _check_dma
    (tmp_path / "bad.py").write_text(_DMA_BAD)
    found = _check_dma(root=str(tmp_path))
    assert sorted(f.check for f in found) == ["CHK-DMA"] * 3
    msgs = " | ".join(f.message for f in found)
    assert "never waited" in msgs
    assert "no matching start" in msgs
    assert "must alternate" in msgs


def test_chk_dma_real_kernels_clean():
    from repro.analysis.pallas_check import _check_dma
    assert _check_dma() == []


def test_kmv_stream_site_is_registered():
    """The streaming pallas_call is exercised by the registry (no
    CHK-SITE blind spot) and its ANY-space inputs do not count against
    the CHK-VMEM block budget."""
    from repro.analysis.registry import capture_entry_points
    calls = [c for c in capture_entry_points()
             if c.path.endswith(os.path.join("kernels", "kmv_stream.py"))]
    assert calls, "kmv_stream_pallas not driven by any entry point"
    for call in calls:
        anys = [s for s in call.in_specs if s.is_any_space]
        assert len(anys) == 2              # Xc and Xvc stay off-chip
        assert call.block_bytes() < 2 ** 20
