"""Distributed == serial, on an 8-device (2 data x 4 model) host mesh.

Runs in a subprocess because --xla_force_host_platform_device_count must be
set before jax initializes, and the main pytest process must keep seeing a
single device (per the dry-run contract)."""
import os
import pathlib
import subprocess
import sys

import jax

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
WORKER = str(pathlib.Path(__file__).resolve().parent / "dist_worker.py")


def test_main_process_sees_one_device():
    """Smoke tests and benches must NOT inherit the 512-device dry-run env."""
    assert jax.device_count() == 1


def test_distributed_solvers_match_serial():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, WORKER], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL-OK" in out.stdout
