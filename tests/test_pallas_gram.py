"""Pallas fused gram kernel vs the pure-jnp oracle (interpret mode on CPU).

Sweeps shapes (including non-block-aligned), dtypes, and all three paper
kernels; plus a hypothesis property sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                    # property sweep is optional on bare envs
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.kernels import KernelConfig
from repro.kernels.gram import gram_pallas
from repro.kernels.ref import gram_ref

KERNELS = [
    KernelConfig("linear"),
    KernelConfig("polynomial", degree=3, coef0=1.0),
    KernelConfig("rbf", sigma=0.7),
]


def _check(m, r, n, cfg, dtype, bm=32, br=32, bk=128):
    k1, k2 = jax.random.split(jax.random.key(m * 1000 + r * 10 + n))
    A = jax.random.normal(k1, (m, n), jnp.float32).astype(dtype)
    B = jax.random.normal(k2, (r, n), jnp.float32).astype(dtype)
    got = gram_pallas(A, B, cfg, bm=bm, br=br, bk=bk, interpret=True)
    want = gram_ref(A, B, cfg)
    # f32 tol covers reduction-order differences (blocked k accumulation);
    # bf16 inputs dominate with their own rounding.
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("cfg", KERNELS, ids=lambda c: c.name)
@pytest.mark.parametrize("shape", [(32, 32, 128), (64, 32, 256),
                                   (33, 17, 100), (8, 8, 128),
                                   (130, 70, 384)])
def test_gram_matches_oracle_f32(cfg, shape):
    _check(*shape, cfg=cfg, dtype=jnp.float32)


@pytest.mark.parametrize("cfg", KERNELS, ids=lambda c: c.name)
def test_gram_matches_oracle_bf16(cfg):
    _check(64, 48, 256, cfg=cfg, dtype=jnp.bfloat16)


@pytest.mark.parametrize("blocks", [(8, 8, 128), (16, 32, 256), (64, 64, 128)])
def test_gram_block_shape_invariance(blocks):
    bm, br, bk = blocks
    _check(96, 80, 384, cfg=KernelConfig("rbf", sigma=1.0),
           dtype=jnp.float32, bm=bm, br=br, bk=bk)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(m=st.integers(1, 70), r=st.integers(1, 40), n=st.integers(1, 150),
           kidx=st.integers(0, 2))
    def test_gram_property_shapes(m, r, n, kidx):
        """Any (m, r, n) — padding must never contaminate real outputs."""
        _check(m, r, n, cfg=KERNELS[kidx], dtype=jnp.float32,
               bm=16, br=16, bk=128)


def test_gram_rbf_diagonal_is_one():
    A = jax.random.normal(jax.random.key(0), (40, 64))
    out = gram_pallas(A, A, KernelConfig("rbf", sigma=1.0),
                      bm=16, br=16, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(out)), 1.0, atol=1e-4)


def test_solver_with_pallas_gram_matches_jnp_gram():
    """End-to-end: s-step DCD with the Pallas slab == with the jnp slab."""
    from repro.core import (KernelConfig, SVMConfig, coordinate_schedule,
                            sstep_dcd_ksvm)
    from repro.data.synthetic import classification_dataset

    A, y = classification_dataset(jax.random.key(1), m=48, n=32)
    cfg = SVMConfig(C=1.0, loss="l2", kernel=KernelConfig("rbf"))
    sched = coordinate_schedule(jax.random.key(2), 16, 48)
    a0 = jnp.zeros(48)
    ref, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=8)

    def pallas_gram(Am, Bm, kcfg):
        return gram_pallas(Am, Bm, kcfg, bm=16, br=16, bk=128,
                           interpret=True).astype(Am.dtype)

    got, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=8, gram_fn=pallas_gram)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
