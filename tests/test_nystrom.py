"""Nystrom approximation (paper's future work): error decreases with the
number of landmarks; Nystrom-BDCD solves the approximated K-RR problem and
approaches the exact solution as l -> m; composes with the s-step solver
unchanged; kmeans landmarks cover clustered data better than uniform;
the setup result is a NamedTuple carrying the landmark set the predict
path needs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (KernelConfig, KRRConfig, bdcd_krr, block_schedule,
                        krr_closed_form, relative_solution_error,
                        sstep_bdcd_krr)
from repro.core.kernels import gram_slab
from repro.core.nystrom import (choose_landmarks, fit_nystrom,
                                kmeans_landmarks, nystrom_kernel_error,
                                nystrom_krr_setup, nystrom_map)
from repro.data.synthetic import regression_dataset


def test_error_decreases_with_landmarks():
    A, _ = regression_dataset(jax.random.key(0), 128, 6)
    cfg = KernelConfig("rbf", sigma=1.0)
    errs = []
    for l in (8, 32, 96):
        L = choose_landmarks(jax.random.key(1), A, l)
        errs.append(nystrom_kernel_error(A, L, cfg))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 0.15


def test_full_rank_nystrom_is_exact():
    """With l = m (all points as landmarks) the approximation is exact."""
    A, _ = regression_dataset(jax.random.key(2), 48, 5)
    cfg = KernelConfig("rbf", sigma=0.7)
    Phi = nystrom_map(A, A, cfg)
    K = gram_slab(A, A, cfg)
    np.testing.assert_allclose(np.asarray(Phi @ Phi.T), np.asarray(K),
                               rtol=1e-3, atol=1e-3)


def test_nystrom_bdcd_approaches_exact_krr():
    m = 96
    A, y = regression_dataset(jax.random.key(3), m, 6)
    cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf", sigma=1.0))
    astar = krr_closed_form(A, y, cfg)

    sched = block_schedule(jax.random.key(4), 256, m, 8)
    errs = []
    for l in (16, 88):
        setup = nystrom_krr_setup(jax.random.key(5), A, cfg, l)
        a, _ = bdcd_krr(setup.Phi, y, jnp.zeros(m), sched, setup.cfg)
        errs.append(float(relative_solution_error(a, astar)))
    assert errs[1] < errs[0]            # more landmarks -> closer to exact
    assert errs[1] < 0.1


def test_nystrom_composes_with_sstep():
    """s-step BDCD on the Nystrom features == classical BDCD on them
    (the paper's schedule is orthogonal to the approximation)."""
    m = 64
    A, y = regression_dataset(jax.random.key(6), m, 6)
    cfg = KRRConfig(lam=0.5, kernel=KernelConfig("rbf"))
    setup = nystrom_krr_setup(jax.random.key(7), A, cfg, 24)
    sched = block_schedule(jax.random.key(8), 64, m, 4)
    a1, _ = bdcd_krr(setup.Phi, y, jnp.zeros(m), sched, setup.cfg)
    a2, _ = sstep_bdcd_krr(setup.Phi, y, jnp.zeros(m), sched, setup.cfg,
                           s=16)
    np.testing.assert_allclose(np.asarray(a2), np.asarray(a1),
                               rtol=1e-4, atol=1e-5)


def test_setup_carries_landmarks_and_feature_map():
    """The named setup result keeps what predict time needs: the landmark
    set and a feature map that reproduces Phi on the training data (the
    old bare (Phi, cfg) tuple lost both)."""
    m, l = 48, 12
    A, y = regression_dataset(jax.random.key(9), m, 5)
    cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf", sigma=0.8))
    setup = nystrom_krr_setup(jax.random.key(10), A, cfg, l)
    assert setup.landmarks.shape == (l, 5)
    assert setup.cfg.kernel.name == "linear"
    np.testing.assert_allclose(np.asarray(setup.feature_map(A)),
                               np.asarray(setup.Phi), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(setup.feature_map.landmarks),
                               np.asarray(setup.landmarks))


def test_kmeans_landmarks_beat_uniform_on_clustered_data():
    """On strongly clustered data, l centroids capture the kernel's
    dominant rank-l structure better than l uniform rows (Zhang & Kwok):
    the rank-l approximation error must not be worse."""
    key = jax.random.key(11)
    centers = 4.0 * jax.random.normal(jax.random.key(12), (6, 8))
    assign = jax.random.randint(key, (192,), 0, 6)
    A = centers[assign] + 0.05 * jax.random.normal(jax.random.key(13),
                                                   (192, 8))
    cfg = KernelConfig("rbf", sigma=0.5)
    L_km = choose_landmarks(jax.random.key(14), A, 6, method="kmeans")
    L_un = choose_landmarks(jax.random.key(14), A, 6, method="uniform")
    err_km = nystrom_kernel_error(A, L_km, cfg)
    err_un = nystrom_kernel_error(A, L_un, cfg)
    assert err_km <= err_un + 1e-6
    assert err_km < 0.05                # 6 tight clusters ~= rank 6
    assert kmeans_landmarks(jax.random.key(15), A, 6).shape == (6, 8)


def test_fit_nystrom_map_on_new_points():
    """phi(X_new) uses the SAME landmarks/transform as training — the
    kernel between new and train points is approximated consistently:
    phi(X) phi(A)^T ~= K(X, A)."""
    A, _ = regression_dataset(jax.random.key(16), 96, 6)
    X = A[:24] + 0.01                    # near-training queries
    cfg = KernelConfig("rbf", sigma=1.0)
    fmap = fit_nystrom(jax.random.key(17), A, cfg, 64)
    K_xa = gram_slab(X, A, cfg)
    K_approx = fmap(X) @ fmap(A).T
    err = (jnp.linalg.norm(K_xa - K_approx) / jnp.linalg.norm(K_xa))
    assert float(err) < 0.1
