"""Nystrom approximation (paper's future work): error decreases with the
number of landmarks; Nystrom-BDCD solves the approximated K-RR problem and
approaches the exact solution as l -> m; composes with the s-step solver
unchanged."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (KernelConfig, KRRConfig, bdcd_krr, block_schedule,
                        krr_closed_form, relative_solution_error,
                        sstep_bdcd_krr)
from repro.core.kernels import gram_slab
from repro.core.nystrom import (choose_landmarks, nystrom_kernel_error,
                                nystrom_krr_setup, nystrom_map)
from repro.data.synthetic import regression_dataset


def test_error_decreases_with_landmarks():
    A, _ = regression_dataset(jax.random.key(0), 128, 6)
    cfg = KernelConfig("rbf", sigma=1.0)
    errs = []
    for l in (8, 32, 96):
        L = choose_landmarks(jax.random.key(1), A, l)
        errs.append(nystrom_kernel_error(A, L, cfg))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 0.15


def test_full_rank_nystrom_is_exact():
    """With l = m (all points as landmarks) the approximation is exact."""
    A, _ = regression_dataset(jax.random.key(2), 48, 5)
    cfg = KernelConfig("rbf", sigma=0.7)
    Phi = nystrom_map(A, A, cfg)
    K = gram_slab(A, A, cfg)
    np.testing.assert_allclose(np.asarray(Phi @ Phi.T), np.asarray(K),
                               rtol=1e-3, atol=1e-3)


def test_nystrom_bdcd_approaches_exact_krr():
    m = 96
    A, y = regression_dataset(jax.random.key(3), m, 6)
    cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf", sigma=1.0))
    astar = krr_closed_form(A, y, cfg)

    sched = block_schedule(jax.random.key(4), 256, m, 8)
    errs = []
    for l in (16, 88):
        Phi, lin_cfg = nystrom_krr_setup(jax.random.key(5), A, cfg, l)
        a, _ = bdcd_krr(Phi, y, jnp.zeros(m), sched, lin_cfg)
        errs.append(float(relative_solution_error(a, astar)))
    assert errs[1] < errs[0]            # more landmarks -> closer to exact
    assert errs[1] < 0.1


def test_nystrom_composes_with_sstep():
    """s-step BDCD on the Nystrom features == classical BDCD on them
    (the paper's schedule is orthogonal to the approximation)."""
    m = 64
    A, y = regression_dataset(jax.random.key(6), m, 6)
    cfg = KRRConfig(lam=0.5, kernel=KernelConfig("rbf"))
    Phi, lin_cfg = nystrom_krr_setup(jax.random.key(7), A, cfg, 24)
    sched = block_schedule(jax.random.key(8), 64, m, 4)
    a1, _ = bdcd_krr(Phi, y, jnp.zeros(m), sched, lin_cfg)
    a2, _ = sstep_bdcd_krr(Phi, y, jnp.zeros(m), sched, lin_cfg, s=16)
    np.testing.assert_allclose(np.asarray(a2), np.asarray(a1),
                               rtol=1e-4, atol=1e-5)
