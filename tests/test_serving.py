"""Continuous-batching engine tests: slot reuse, streaming admissions,
agreement with single-request greedy decoding."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_decode_state, init_params
from repro.train.serving import Request, ServingEngine
from repro.train import greedy_generate


def _setup(arch="qwen3_1p7b"):
    cfg = get_config(arch, reduced=True)
    params = init_params(jax.random.key(0), cfg)
    return cfg, params


def test_engine_completes_streaming_requests():
    cfg, params = _setup()
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[3 + i, 7, 11], max_new_tokens=5)
            for i in range(5)]          # more requests than slots
    for r in reqs[:3]:
        eng.submit(r)
    steps = 0
    while (eng.pending or any(eng.slots)) and steps < 200:
        eng.step()
        steps += 1
        if steps == 4:                  # late arrivals mid-flight
            eng.submit(reqs[3])
            eng.submit(reqs[4])
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 5 for r in reqs)
    assert all(0 <= t < cfg.vocab_size
               for r in reqs for t in r.generated)


def test_engine_matches_isolated_greedy():
    """A request decoded through the batched engine must equal the same
    request decoded alone (slot isolation)."""
    cfg, params = _setup()
    prompt = [5, 9, 2, 14]
    n_new = 6

    state = init_decode_state(cfg, 1, 32)
    ref, _ = greedy_generate(params, cfg, state,
                             jnp.array([prompt], jnp.int32), n_new)
    ref = [int(t) for t in ref[0]]

    eng = ServingEngine(params, cfg, n_slots=3, max_seq=32)
    # occupy other slots with decoy traffic
    target = Request(rid=1, prompt=prompt, max_new_tokens=n_new)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=8))
    eng.submit(target)
    eng.submit(Request(rid=2, prompt=[8, 8, 8], max_new_tokens=8))
    for _ in range(100):
        eng.step()
        if not eng.pending and all(s is None for s in eng.slots):
            break
    assert target.done
    assert target.generated == ref, (target.generated, ref)


def test_engine_slot_reuse_is_clean():
    """After a slot retires, a new request in that slot must not see stale
    cache state: decode the same request twice, once fresh and once after
    slot churn — outputs must match."""
    cfg, params = _setup()
    prompt = [4, 13, 6]
    n_new = 4

    def run_once(pre_churn):
        eng = ServingEngine(params, cfg, n_slots=1, max_seq=32)
        if pre_churn:
            eng.submit(Request(rid=99, prompt=[9, 9, 9, 9],
                               max_new_tokens=3))
            for _ in range(40):
                eng.step()
                if all(s is None for s in eng.slots) and not eng.pending:
                    break
        req = Request(rid=1, prompt=prompt, max_new_tokens=n_new)
        eng.submit(req)
        for _ in range(40):
            eng.step()
            if req.done:
                break
        return req.generated

    fresh = run_once(pre_churn=False)
    churned = run_once(pre_churn=True)
    assert fresh == churned, (fresh, churned)
