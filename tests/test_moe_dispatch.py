"""MoE dispatch equivalence: capacity-based dispatch (§Perf optimization)
must match dense dispatch when capacity is generous (no token drops)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import (init_moe, moe_forward, moe_forward_capacity)


def _cfg(capacity_factor=8.0):
    cfg = get_config("deepseek_v2_lite_16b", reduced=True)
    return dataclasses.replace(cfg, capacity_factor=capacity_factor)


def test_capacity_matches_dense_when_no_drops():
    cfg = _cfg(capacity_factor=8.0)     # cap >= T: nothing can drop
    p = init_moe(jax.random.key(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                                jnp.float32)
    dense = moe_forward(p, cfg, x)
    cap = moe_forward_capacity(p, cfg, x)
    np.testing.assert_allclose(np.asarray(cap), np.asarray(dense),
                               rtol=2e-3, atol=2e-4)


def test_capacity_drops_are_bounded():
    """With factor 1.0 some tokens may drop an expert, but outputs stay
    finite and close to dense (graceful degradation)."""
    cfg = _cfg(capacity_factor=1.0)
    p = init_moe(jax.random.key(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.key(2), (2, 32, cfg.d_model),
                                jnp.float32)
    dense = moe_forward(p, cfg, x)
    cap = moe_forward_capacity(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(cap)))
    # most tokens unaffected (synthetic routing is near-uniform)
    rel = jnp.linalg.norm(cap - dense) / jnp.linalg.norm(dense)
    assert float(rel) < 0.5


def test_capacity_flops_advantage_structural():
    """The whole point: capacity dispatch computes E*C*d*f expert flops
    instead of E*T*d*f.  C/T = top_k/E * factor << 1 for arctic-like
    configs."""
    cfg = _cfg(capacity_factor=1.25)
    T = 4096
    dense_tokens_per_expert = T
    cap_tokens_per_expert = int(T * cfg.top_k / cfg.n_experts
                                * cfg.capacity_factor)
    assert cap_tokens_per_expert * 3 < dense_tokens_per_expert
