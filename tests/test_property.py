"""Hypothesis property tests on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (KernelConfig, KRRConfig, SVMConfig, bdcd_krr,
                        block_schedule, coordinate_schedule, dcd_ksvm,
                        ksvm_dual_objective, sstep_bdcd_krr, sstep_dcd_ksvm)
from repro.core.kernels import gram_slab
from repro.core.perf_model import (Machine, Problem, bdcd_cost,
                                   sstep_bdcd_cost)
from repro.data.synthetic import classification_dataset, regression_dataset

KERN = [KernelConfig("linear"), KernelConfig("polynomial", 2, 1.0),
        KernelConfig("rbf", sigma=0.5)]


@settings(max_examples=10, deadline=None)
@given(m=st.integers(8, 48), n=st.integers(2, 32), kidx=st.integers(0, 2),
       seed=st.integers(0, 10))
def test_gram_slab_psd_diag(m, n, kidx, seed):
    """K(A, A) must be symmetric; RBF diag == 1; linear/poly PSD-ish."""
    A, _ = classification_dataset(jax.random.key(seed), m, n)
    K = gram_slab(A, A, KERN[kidx])
    np.testing.assert_allclose(np.asarray(K), np.asarray(K).T, atol=1e-4)
    if KERN[kidx].name == "rbf":
        np.testing.assert_allclose(np.asarray(jnp.diagonal(K)), 1.0,
                                   atol=1e-5)
        assert float(K.min()) >= 0.0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), s=st.sampled_from([2, 4, 8, 16]),
       loss=st.sampled_from(["l1", "l2"]), kidx=st.integers(0, 2))
def test_sstep_dcd_equivalence_property(seed, s, loss, kidx):
    """INVARIANT (paper Thm): s-step DCD == DCD for ANY schedule/kernel."""
    m, n, H = 32, 8, 16
    A, y = classification_dataset(jax.random.key(seed), m, n)
    cfg = SVMConfig(C=0.5, loss=loss, kernel=KERN[kidx])
    sched = coordinate_schedule(jax.random.key(seed + 1), H, m)
    a0 = jnp.zeros(m)
    a1, _ = dcd_ksvm(A, y, a0, sched, cfg)
    a2, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=s)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), s=st.sampled_from([2, 4, 8]),
       b=st.integers(1, 4))
def test_sstep_bdcd_equivalence_property(seed, s, b):
    m, n, H = 32, 8, 8
    A, y = regression_dataset(jax.random.key(seed), m, n)
    cfg = KRRConfig(lam=0.8, kernel=KERN[seed % 3])
    sched = block_schedule(jax.random.key(seed + 1), H, m, b)
    a0 = jnp.zeros(m)
    a1, _ = bdcd_krr(A, y, a0, sched, cfg)
    a2, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=s)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_dcd_feasibility_invariant(seed):
    """0 <= alpha_i <= C must hold at every DCD/s-step iterate (L1)."""
    m, n = 24, 6
    A, y = classification_dataset(jax.random.key(seed), m, n)
    cfg = SVMConfig(C=0.7, loss="l1", kernel=KERN[seed % 3])
    sched = coordinate_schedule(jax.random.key(seed + 5), 32, m)
    a, _ = sstep_dcd_ksvm(A, y, jnp.zeros(m), sched, cfg, s=8)
    assert float(a.min()) >= -1e-6
    assert float(a.max()) <= 0.7 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), h=st.integers(1, 8))
def test_dcd_monotone_dual_decrease(seed, h):
    """Exact coordinate minimization can never increase the dual."""
    m, n = 24, 6
    A, y = classification_dataset(jax.random.key(seed), m, n)
    cfg = SVMConfig(C=1.0, loss="l2", kernel=KernelConfig("rbf"))
    sched = coordinate_schedule(jax.random.key(seed + 9), 8 * h, m)
    a0 = jnp.zeros(m)
    prev = float(ksvm_dual_objective(A, y, a0, cfg))
    a, _ = dcd_ksvm(A, y, a0, sched, cfg)
    cur = float(ksvm_dual_objective(A, y, a, cfg))
    assert cur <= prev + 1e-6


@settings(max_examples=20, deadline=None)
@given(s=st.sampled_from([2, 4, 8, 16, 32]), P=st.sampled_from([2, 8, 64]),
       b=st.integers(1, 8))
def test_perf_model_invariants(s, P, b):
    """Theorem 2 invariants: s-step moves the SAME total words, s x fewer
    messages, and >= the flops of classical BDCD."""
    prob = Problem(m=1024, n=4096, f=0.1, b=b, H=256)
    mach = Machine()
    c = bdcd_cost(prob, mach, P)
    cs = sstep_bdcd_cost(prob, mach, P, s)
    np.testing.assert_allclose(cs["words"], c["words"], rtol=1e-9)
    np.testing.assert_allclose(cs["msgs"], c["msgs"] / s, rtol=1e-9)
    assert cs["flops"] >= c["flops"] - 1e-6


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 1000), seed=st.integers(0, 5))
def test_data_pipeline_deterministic(step, seed):
    """Batch k is a pure function of (seed, k) — the fault-tolerance
    contract (any worker can reconstruct any batch)."""
    from repro.data.tokens import TokenPipeline
    p1 = TokenPipeline(vocab_size=97, seq_len=12, global_batch=4, seed=seed)
    p2 = TokenPipeline(vocab_size=97, seq_len=12, global_batch=4, seed=seed)
    b1, b2 = p1.batch(step), p2.batch(step)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert int(b1["tokens"].max()) < 97
    # shifted-by-one label structure
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
