"""Shared pytest configuration.

``REPRO_SANITIZE=1`` enables the opt-in runtime sanitizer (the dynamic
half of ``repro.analysis``, DESIGN.md §11): the kernel test modules run
with ``jax_debug_nans`` + ``jax_debug_infs`` so a NaN/Inf produced
inside a kernel body raises at the producing op, and ``kernels/ops.py``
forces ``interpret=True`` so the Pallas bodies run under the Python
evaluator even on TPU.  Off by default — the flags re-run every jitted
computation un-jitted on failure, which is far too slow for tier-1;
CI runs it as a separate non-blocking job.
"""
from __future__ import annotations

import os

import pytest

# test modules that drive the Pallas kernels (directly or through the
# solver op_factory) — the sanitizer flags apply only here: debug_nans
# on the distributed/system tests false-positives on masked lanes
KERNEL_TEST_MODULES = frozenset({
    "test_kmv", "test_pallas_gram", "test_pallas_rmsnorm",
    "test_flash_attention", "test_streaming",
})

# modules whose accumulated jit cache is large enough to destabilize the
# rest of a single-process full-suite run (the pre-existing full-suite
# XLA crash): their compiled executables are dropped when the module
# finishes so later modules start from a clean compilation cache.  CI
# additionally shards tier-1 into separate pytest PROCESSES (see
# .github/workflows/ci.yml) — this fixture is the in-process half for
# plain local `pytest` runs.
HEAVY_JIT_MODULES = frozenset({
    "test_distributed", "test_flash_attention", "test_moe_dispatch",
    "test_models_smoke", "test_pallas_gram", "test_ssd",
    "test_streaming",
})


def sanitize_enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") == "1"


@pytest.fixture(scope="module", autouse=True)
def _clear_jit_caches_after_heavy_module(request):
    yield
    name = getattr(getattr(request, "module", None), "__name__",
                   "").rsplit(".", 1)[-1]
    if name in HEAVY_JIT_MODULES:
        import jax
        jax.clear_caches()


@pytest.fixture(autouse=True)
def _repro_sanitize(request):
    if not sanitize_enabled():
        yield
        return
    module = getattr(request, "module", None)
    name = getattr(module, "__name__", "").rsplit(".", 1)[-1]
    if name not in KERNEL_TEST_MODULES:
        yield
        return
    import jax
    with jax.debug_nans(True), jax.debug_infs(True):
        yield
