"""Checkpoint + fault-tolerance tests: atomic save, resume-latest, GC,
async writer, elastic restore, and bit-exact preemption recovery of a real
training loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.train import (CheckpointManager, load_checkpoint,
                         make_train_step, save_checkpoint)
from repro.train.train_step import TrainConfig


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "blocks": ({"w": jnp.ones((4,))}, {"w": 2 * jnp.ones((4,))}),
            "step": jnp.int32(7)}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    got, meta = load_checkpoint(str(tmp_path), template=t)
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_latest_ignores_partial(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 5, t)
    # a torn write (crash mid-save) must be invisible
    os.makedirs(tmp_path / "step_00000009.tmp")
    _, meta = load_checkpoint(str(tmp_path), template=t)
    assert meta["step"] == 5


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, save_every=10)
    t = _tree()
    for s in (10, 20, 30, 40):
        assert mgr.should_save(s)
        mgr.save_async(s, t)
    mgr.wait()
    from repro.train.checkpoint import available_steps
    assert available_steps(str(tmp_path)) == [30, 40]
    got, meta = mgr.restore_latest(template=t)
    assert meta["step"] == 40


def test_preemption_resume_bit_exact(tmp_path):
    """Train 6 steps; separately train 3, checkpoint, 'preempt', restore,
    train 3 more — final params must match bit-for-bit (deterministic
    index-derived data pipeline + checkpointed opt state)."""
    cfg = get_config("qwen3_1p7b", reduced=True)
    acfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    tcfg = TrainConfig()
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=8,
                         global_batch=4, seed=0)
    step_fn = make_train_step(cfg, acfg, tcfg)

    def train(params, opt, s0, s1):
        for s in range(s0, s1):
            params, opt, _ = step_fn(params, opt, pipe.batch(s))
        return params, opt

    p0 = init_params(jax.random.key(0), cfg)
    o0 = adamw_init(p0)
    ref_p, _ = train(p0, o0, 0, 6)

    p = init_params(jax.random.key(0), cfg)
    o = adamw_init(p)
    p, o = train(p, o, 0, 3)
    save_checkpoint(str(tmp_path), 3, {"params": p, "opt": o})
    del p, o                                     # the preemption
    restored, meta = load_checkpoint(
        str(tmp_path), template={"params": p0, "opt": adamw_init(p0)})
    p2, o2 = train(restored["params"], restored["opt"], meta["step"], 6)

    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_extension_dtype_roundtrip(tmp_path):
    """bfloat16 (a numpy extension dtype, kind 'V') must survive the
    .npy round-trip bit-for-bit — regression: it used to come back as a
    raw void view."""
    t = {"w": jnp.arange(16, dtype=jnp.bfloat16) / 7,
         "b": jnp.ones((3,), jnp.float16)}
    save_checkpoint(str(tmp_path), 1, t)
    got, _ = load_checkpoint(str(tmp_path), template=t)
    assert got["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["w"]).view(np.uint16),
                                  np.asarray(t["w"]).view(np.uint16))
    assert got["b"].dtype == jnp.float16


def test_operator_pytree_roundtrip(tmp_path):
    """A registered-pytree GramOperator round-trips through the generic
    leaf machinery — regression: attribute path keys used to render as
    garbage ('.A'), colliding across operators."""
    from repro.core.kernels import ExactGramOperator, KernelConfig
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((8, 3)), jnp.float32)
    op = ExactGramOperator(A, KernelConfig("rbf", sigma=0.5))
    save_checkpoint(str(tmp_path), 2, {"op": op, "alpha": jnp.ones(8)})
    got, meta = load_checkpoint(str(tmp_path), step=2,
                                template={"op": op,
                                          "alpha": jnp.zeros(8)})
    # paths must name the leaves distinctly (not a bare attr fallback)
    assert len(set(meta["paths"])) == len(meta["paths"])
    np.testing.assert_array_equal(np.asarray(got["op"].A), np.asarray(A))
    assert got["op"].cfg == op.cfg


def test_save_fit_load_fit_roundtrip(tmp_path):
    """A completed FitResult + its operator round-trip through
    repro.resilience.checkpoint.save_fit/load_fit."""
    from repro.api import KernelRidge, SolverOptions
    from repro.resilience.checkpoint import load_fit, save_fit
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.standard_normal((32, 4)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(32), jnp.float32)
    kr = KernelRidge(lam=0.5, kernel="linear",
                     options=SolverOptions(max_iters=32, record=True))
    res = kr.fit(A, y)
    save_fit(str(tmp_path), res, op=kr.op_)
    res2, op2 = load_fit(str(tmp_path), op_template=kr.op_)
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(res2.alpha))
    np.testing.assert_array_equal(np.asarray(res.schedule),
                                  np.asarray(res2.schedule))
    np.testing.assert_array_equal(np.asarray(res.history),
                                  np.asarray(res2.history))
    assert res2.converged == res.converged
    assert res2.options.max_iters == 32
    np.testing.assert_array_equal(np.asarray(op2.A), np.asarray(A))


def test_save_fit_persists_health(tmp_path):
    """Regression: a guarded fit's SolveHealth ledger (drift array,
    events, scalars) survives save_fit/load_fit — it used to be dropped
    as a session object."""
    from repro.api import KernelRidge, SolverOptions
    from repro.resilience.checkpoint import load_fit, save_fit
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.standard_normal((48, 4)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(48), jnp.float32)
    kr = KernelRidge(lam=0.5, kernel="rbf",
                     options=SolverOptions(method="sstep", s=4, b=4,
                                           tol=1e-10, check_every=4,
                                           max_iters=64, guard=True,
                                           recompute_every=4))
    res = kr.fit(A, y)
    assert res.health is not None and res.health.guarded
    save_fit(str(tmp_path), res, op=kr.op_)
    res2, _ = load_fit(str(tmp_path), op_template=kr.op_)
    h, h2 = res.health, res2.health
    assert h2 is not None and h2.guarded
    assert h2.recompute_every == h.recompute_every
    assert h2.corrections == h.corrections
    np.testing.assert_array_equal(np.asarray(h.drift),
                                  np.asarray(h2.drift))
    assert h2.events == h.events
    assert h2.checkpoints == h.checkpoints
    assert h2.resumed_from == h.resumed_from
    assert h2.max_drift == h.max_drift
    # an unguarded fit still round-trips with health=None
    kr2 = KernelRidge(lam=0.5, kernel="linear",
                      options=SolverOptions(max_iters=16))
    res3 = kr2.fit(A, y)
    save_fit(str(tmp_path / "plain"), res3, op=kr2.op_)
    res4, _ = load_fit(str(tmp_path / "plain"), op_template=kr2.op_)
    assert res4.health is None


def test_solve_state_fingerprint_mismatch(tmp_path):
    """load_solve_state refuses a checkpoint from a different solve and
    names the mismatched fingerprint fields."""
    import pytest
    from repro.resilience.checkpoint import (load_solve_state,
                                             save_solve_state)
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    fp = {"problem": "krr", "m": 32, "seed": 0}
    save_solve_state(mgr, 16, jnp.ones(32), jnp.zeros(32),
                     s_cur=4, method_cur="sstep", fingerprint=fp)
    mgr.wait()
    alpha, f, extra = load_solve_state(str(tmp_path),
                                       expect_fingerprint=fp)
    assert extra["iters_done"] == 16 and extra["s_cur"] == 4
    assert f is not None
    with pytest.raises(ValueError, match="seed"):
        load_solve_state(str(tmp_path),
                         expect_fingerprint={**fp, "seed": 7})
    with pytest.raises(FileNotFoundError):
        load_solve_state(str(tmp_path / "empty"))


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint written replicated restores onto a sharded layout (the
    1-device degenerate case exercises the device_put path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    t = {"w": jnp.arange(8.0)}
    save_checkpoint(str(tmp_path), 1, t)
    sh = {"w": NamedSharding(mesh, P("data"))}
    got, _ = load_checkpoint(str(tmp_path), template=t, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0))
