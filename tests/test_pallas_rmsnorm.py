"""Fused RMSNorm kernel vs the models.layers oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                    # property sweep is optional on bare envs
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.models.layers import rmsnorm


@pytest.mark.parametrize("shape", [(4, 16, 128), (2, 128), (3, 7, 384),
                                   (1, 1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype):
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, shape, jnp.float32).astype(dtype)
    scale = jax.random.normal(k2, (shape[-1],), jnp.float32)
    got = rmsnorm_pallas(x, scale, interpret=True, block_rows=8)
    want = rmsnorm({"scale": scale}, x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(rows=st.integers(1, 40), d=st.sampled_from([128, 256, 384]),
           seed=st.integers(0, 5))
    def test_rmsnorm_property(rows, d, seed):
        x = jax.random.normal(jax.random.key(seed), (rows, d))
        scale = jnp.ones((d,))
        got = rmsnorm_pallas(x, scale, interpret=True, block_rows=16)
        # unit-RMS invariant
        rms = jnp.sqrt(jnp.mean(got * got, axis=-1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)
