"""Serving subsystem tests (DESIGN.md §13): artifact round-trips,
registry dedup, the continuous-batching engine's no-recompile / shed /
deadline behavior, eager predict-path validation, the BatchedPredictor
edge cases, and refit-then-swap equivalence to a cold fit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (KernelRidge, KernelSVM, KernelConfig,
                       SolverOptions)
from repro.core.kernels import ExactGramOperator, LowRankGramOperator
from repro.core.predict import (BatchedPredictor, compact_support,
                                serve_cache_size, validate_queries)
from repro.serve import (MANIFEST_VERSION, ModelRegistry, ServableModel,
                         ServingEngine, load_model, operator_key,
                         save_model, SHED, EXPIRED, DONE)


def _data(m=96, n=8, seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    w = rng.standard_normal(n)
    yc = jnp.asarray(np.sign(A @ w + 0.1 * rng.standard_normal(m)),
                     jnp.float32)
    yr = jnp.asarray(A @ w + 0.1 * rng.standard_normal(m), jnp.float32)
    return A, yc, yr


def _opts(**kw):
    base = dict(method="sstep", s=8, max_iters=512, tol=1e-6, seed=3)
    base.update(kw)
    return SolverOptions(**base)


@pytest.fixture(scope="module")
def fitted():
    A, yc, yr = _data()
    svm = KernelSVM(C=1.0, kernel="rbf", options=_opts())
    svm.fit(A, yc)
    svm2 = KernelSVM(C=0.25, kernel="rbf", options=_opts())
    svm2.fit(A, yc)
    krr = KernelRidge(lam=0.5, kernel="rbf", options=_opts())
    krr.fit(A, yr)
    return dict(A=A, yc=yc, yr=yr, svm=svm, svm2=svm2, krr=krr)


# ---------------------------------------------------------------------------
# artifacts
# ---------------------------------------------------------------------------

class TestArtifacts:
    def test_roundtrip_exact_ksvm(self, fitted, tmp_path):
        svm, A = fitted["svm"], fitted["A"]
        path = svm.save(str(tmp_path))
        assert path
        m = load_model(str(tmp_path))
        assert m.problem == "ksvm"
        assert jnp.allclose(m.alpha, svm.alpha_)
        assert jnp.allclose(m.y, svm.y_)
        assert isinstance(m.op, ExactGramOperator)
        assert m.cfg == svm.cfg
        assert m.options == svm.result_.options
        # restored model serves identically to the live estimator
        Xq = A[:7]
        reg = ModelRegistry()
        reg.register("m", m)
        np.testing.assert_allclose(
            np.asarray(reg.predict("m", Xq)),
            np.asarray(svm.decision_function(Xq)), atol=1e-6)

    def test_roundtrip_nystrom_krr(self, tmp_path):
        A, _, yr = _data(seed=4)
        krr = KernelRidge(lam=0.5, kernel="rbf",
                          options=_opts(approx="nystrom", landmarks=32))
        krr.fit(A, yr)
        krr.save(str(tmp_path))
        m = load_model(str(tmp_path))
        assert m.problem == "krr"
        assert isinstance(m.op, LowRankGramOperator)
        assert m.op.fmap is not None
        assert m.A_raw is not None            # refit base travels along
        assert jnp.allclose(m.A_raw, A)
        reg = ModelRegistry()
        reg.register("m", m)
        np.testing.assert_allclose(
            np.asarray(reg.predict("m", A[:6])),
            np.asarray(krr.predict(A[:6])), atol=1e-6)

    def test_refuses_newer_manifest(self, fitted, tmp_path):
        fitted["svm"].save(str(tmp_path))
        meta = tmp_path / "step_00000000" / "meta.json"
        import json
        doc = json.loads(meta.read_text())
        doc["extra"]["serve_manifest"]["version"] = MANIFEST_VERSION + 1
        meta.write_text(json.dumps(doc))
        with pytest.raises(ValueError, match="manifest version"):
            load_model(str(tmp_path))

    def test_refuses_non_model_checkpoint(self, fitted, tmp_path):
        from repro.resilience.checkpoint import save_fit
        save_fit(str(tmp_path), fitted["svm"].result_, fitted["svm"].op_)
        with pytest.raises(ValueError, match="serve_manifest"):
            load_model(str(tmp_path))

    def test_unfitted_estimator_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="not fitted"):
            save_model(str(tmp_path), KernelSVM())

    def test_fingerprint_persists(self, fitted, tmp_path):
        fitted["krr"].save(str(tmp_path))
        m = load_model(str(tmp_path))
        assert m.fingerprint is not None
        assert m.fingerprint["problem"] == "krr"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_dedup_two_models_one_operator(self, fitted):
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        reg.register("b", fitted["svm2"])
        assert reg.n_groups == 1
        group = reg.group("a")
        assert group is reg.group("b")
        assert group.size == 2
        # the shared operator is ONE object, not two equal copies
        assert reg.models["a"].op is reg.models["b"].op
        assert group.W.shape == (fitted["A"].shape[0], 2)

    def test_dedup_across_artifact_roundtrip(self, fitted, tmp_path):
        """A model restored from disk joins the group of a live-fitted
        sibling — dedup keys on operator CONTENT, not object identity."""
        fitted["svm"].save(str(tmp_path))
        reg = ModelRegistry()
        reg.register("live", fitted["svm2"])
        reg.load("restored", str(tmp_path))
        assert reg.n_groups == 1
        assert (reg.models["live"].op is reg.models["restored"].op)

    def test_distinct_data_distinct_groups(self, fitted):
        A2, yc2, _ = _data(seed=9)
        other = KernelSVM(C=1.0, kernel="rbf", options=_opts())
        other.fit(A2, yc2)
        reg = ModelRegistry()
        reg.register("a", fitted["svm"])
        reg.register("b", other)
        assert reg.n_groups == 2

    def test_group_predict_matches_estimator(self, fitted):
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        reg.register("b", fitted["svm2"])
        reg.register("r", fitted["krr"])
        Xq = fitted["A"][:9]
        np.testing.assert_allclose(
            np.asarray(reg.predict("a", Xq)),
            np.asarray(fitted["svm"].decision_function(Xq)), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(reg.predict("b", Xq)),
            np.asarray(fitted["svm2"].decision_function(Xq)), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(reg.predict("r", Xq)),
            np.asarray(fitted["krr"].predict(Xq)), atol=1e-5)

    def test_unregister_shrinks_group(self, fitted):
        reg = ModelRegistry()
        reg.register("a", fitted["svm"])
        reg.register("b", fitted["svm2"])
        gen = reg.generation
        reg.unregister("b")
        assert reg.generation > gen
        assert reg.n_groups == 1
        assert reg.group("a").size == 1
        reg.unregister("a")
        assert reg.n_groups == 0

    def test_unknown_name(self, fitted):
        reg = ModelRegistry()
        with pytest.raises(KeyError, match="ghost"):
            reg.predict("ghost", fitted["A"][:2])

    def test_register_rejects_junk(self):
        with pytest.raises(TypeError, match="fitted estimator"):
            ModelRegistry().register("x", {"not": "a model"})


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class TestEngine:
    def test_mixed_traffic_zero_recompiles(self, fitted):
        """The acceptance criterion: after warmup, steady mixed-model
        traffic grows the jit cache by exactly zero entries."""
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        reg.register("b", fitted["svm2"])
        reg.register("r", fitted["krr"])
        eng = ServingEngine(reg, slots=32, max_queue=256)
        eng.warmup()
        before = serve_cache_size()
        A = fitted["A"]
        rng = np.random.default_rng(0)
        tickets = []
        for i in range(60):                 # varying counts, all models
            name = ("a", "b", "r")[i % 3]
            rows = int(rng.integers(1, 5))
            tickets.append(eng.submit(name, A[:rows]))
            if i % 7 == 0:
                eng.step()
        eng.run_until_idle()
        assert serve_cache_size() == before
        assert all(t.status == DONE for t in tickets)
        assert eng.stats["served"] == 60

    def test_results_match_direct_predict(self, fitted):
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        reg.register("r", fitted["krr"])
        eng = ServingEngine(reg, slots=16)
        Xq = fitted["A"][3:8]
        ta = eng.submit("a", Xq)
        tr = eng.submit("r", Xq)
        eng.run_until_idle()
        np.testing.assert_allclose(
            np.asarray(ta.result),
            np.asarray(fitted["svm"].decision_function(Xq)), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(tr.result),
            np.asarray(fitted["krr"].predict(Xq)), atol=1e-5)

    def test_bounded_queue_sheds(self, fitted):
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        eng = ServingEngine(reg, slots=8, max_queue=3)
        tickets = [eng.submit("a", fitted["A"][:1]) for _ in range(6)]
        shed = [t for t in tickets if t.status == SHED]
        assert len(shed) == 3               # beyond max_queue: shed
        assert eng.stats["shed"] == 3
        eng.run_until_idle()
        done = [t for t in tickets if t.status == DONE]
        assert len(done) == 3               # accepted traffic serves

    def test_deadline_expires_unserved(self, fitted):
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        vt = [0.0]
        eng = ServingEngine(reg, slots=8, clock=lambda: vt[0])
        t_late = eng.submit("a", fitted["A"][:1], deadline_s=0.5)
        t_ok = eng.submit("a", fitted["A"][:1], deadline_s=100.0)
        vt[0] = 1.0                         # miss the first deadline
        eng.step()
        assert t_late.status == EXPIRED
        assert t_late.result is None
        assert t_ok.status == DONE
        assert eng.stats["expired"] == 1

    def test_oversized_request_rejected_not_stuck(self, fitted):
        """A request wider than ``slots`` can never be admitted; the
        queue must not wedge behind it."""
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        eng = ServingEngine(reg, slots=4)
        big = eng.submit("a", fitted["A"][:10])
        small = eng.submit("a", fitted["A"][:2])
        eng.step()
        assert small.status == DONE         # FIFO skip, no head-of-line
        assert big.status != DONE           # block on the oversized one
        assert eng.pending == 1

    def test_refit_swap_mid_stream(self, fitted):
        """Traffic before and after a refit serves from consistent
        weights: post-swap answers match a direct registry predict on
        the refitted model."""
        A, yr = fitted["A"], fitted["yr"]
        reg = ModelRegistry(predict_batch=64)
        reg.register("r", fitted["krr"])
        eng = ServingEngine(reg, slots=16)
        t_pre = eng.submit("r", A[:3])
        eng.step()
        pre = np.asarray(t_pre.result)
        reg.refit("r", A[:5] + 0.25, yr[:5])
        t_post = eng.submit("r", A[:3])
        eng.step()
        assert t_post.status == DONE
        np.testing.assert_allclose(np.asarray(t_post.result),
                                   np.asarray(reg.predict("r", A[:3])),
                                   atol=1e-6)
        # the swap actually changed the model
        assert not np.allclose(pre, np.asarray(t_post.result))

    def test_single_row_submit(self, fitted):
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        eng = ServingEngine(reg, slots=8)
        t = eng.submit("a", fitted["A"][0])     # (n,) promotes to (1, n)
        eng.step()
        assert t.status == DONE and t.result.shape == (1,)


# ---------------------------------------------------------------------------
# eager predict-path validation (satellite a)
# ---------------------------------------------------------------------------

class TestValidation:
    def test_estimator_wrong_width(self, fitted):
        with pytest.raises(ValueError, match="A_test.*4 features.*8"):
            fitted["svm"].decision_function(jnp.zeros((3, 4)))
        with pytest.raises(ValueError, match="A_test.*4 features.*8"):
            fitted["krr"].predict(jnp.zeros((3, 4)))

    def test_estimator_wrong_ndim(self, fitted):
        with pytest.raises(ValueError, match="A_test must be 2-D"):
            fitted["svm"].decision_function(jnp.zeros((3, 8, 1)))

    def test_estimator_wrong_dtype(self, fitted):
        with pytest.raises(ValueError, match="A_test has dtype int32"):
            fitted["krr"].predict(jnp.zeros((3, 8), jnp.int32))

    def test_submit_names_argument(self, fitted):
        reg = ModelRegistry()
        reg.register("a", fitted["svm"])
        eng = ServingEngine(reg, slots=8)
        with pytest.raises(ValueError, match="X has 5 features"):
            eng.submit("a", jnp.zeros((2, 5)))
        with pytest.raises(ValueError, match="X has dtype int32"):
            eng.submit("a", jnp.zeros((2, 8), jnp.int32))
        assert eng.stats["submitted"] == 0   # rejected before enqueue

    def test_refit_names_argument(self, fitted):
        reg = ModelRegistry()
        reg.register("r", fitted["krr"])
        with pytest.raises(ValueError, match="X_new"):
            reg.refit("r", jnp.zeros((2, 5)), jnp.zeros(2))
        with pytest.raises(ValueError, match="y_new has 3 rows"):
            reg.refit("r", jnp.zeros((2, 8)), jnp.zeros(3))

    def test_lowrank_without_fmap_cannot_serve(self):
        op = LowRankGramOperator(Phi=jnp.ones((4, 2)), fmap=None)
        with pytest.raises(ValueError, match="feature map"):
            validate_queries(op, jnp.zeros((1, 2)), name="Xq")


# ---------------------------------------------------------------------------
# BatchedPredictor edge cases (satellite b)
# ---------------------------------------------------------------------------

class TestPredictorEdges:
    def _op_w(self, m=40, n=6, seed=0):
        rng = np.random.default_rng(seed)
        A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(m), jnp.float32)
        return ExactGramOperator(A, KernelConfig("rbf")), A, w

    def test_empty_query_batch(self):
        op, A, w = self._op_w()
        pred = BatchedPredictor(op, w, batch=16)
        out = pred(jnp.zeros((0, 6), jnp.float32))
        assert out.shape == (0,)
        # stacked weights: empty keeps the model axis
        W = jnp.stack([w, 2 * w], axis=1)
        out2 = BatchedPredictor(op, W, batch=16)(
            jnp.zeros((0, 6), jnp.float32))
        assert out2.shape == (0, 2)

    def test_batch_larger_than_largest_bucket(self):
        """q > batch splits into full blocks + bucketed tail — same
        values as one dense call, no new compilation beyond the warmed
        bucket set."""
        op, A, w = self._op_w(m=40)
        pred = BatchedPredictor(op, w, batch=16)
        pred.warmup()
        before = serve_cache_size()
        rng = np.random.default_rng(1)
        Xq = jnp.asarray(rng.standard_normal((53, 6)), jnp.float32)
        out = pred(Xq)
        assert out.shape == (53,)
        assert serve_cache_size() == before
        dense = BatchedPredictor(op, w, batch=64)(Xq)
        np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                                   atol=1e-5)

    def test_compact_support_zero_svs(self):
        op, A, _ = self._op_w()
        w0 = jnp.zeros(40, jnp.float32)
        cop, cw = compact_support(op, w0)
        assert cw.shape[0] == 1             # operators cannot be empty
        assert float(jnp.max(jnp.abs(cw))) == 0.0
        out = BatchedPredictor(cop, cw, batch=8)(A[:5])
        np.testing.assert_array_equal(np.asarray(out), np.zeros(5))

    def test_compact_support_zero_svs_above_tol(self):
        """tol leaves sub-threshold residue everywhere: the kept row's
        weight is still forced to exact zero."""
        op, A, _ = self._op_w()
        w = jnp.full((40,), 1e-6, jnp.float32)
        cop, cw = compact_support(op, w, tol=1e-3)
        assert float(jnp.max(jnp.abs(cw))) == 0.0

    def test_compact_support_stacked(self):
        """A row survives when ANY stacked member uses it."""
        op, A, w = self._op_w()
        w1 = w.at[10:].set(0.0)
        w2 = w.at[:30].set(0.0)             # disjoint-ish supports
        W = jnp.stack([w1, w2], axis=1)
        cop, cW = compact_support(op, W)
        assert cW.shape == (20, 2)          # union of supports
        out = BatchedPredictor(cop, cW, batch=8)(A[:5])
        full = BatchedPredictor(op, W, batch=8)(A[:5])
        np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                                   atol=1e-5)

    def test_bucket_sizes(self):
        op, A, w = self._op_w()
        assert BatchedPredictor(op, w, batch=64).bucket_sizes() == \
            [8, 16, 32, 64]
        assert BatchedPredictor(op, w, batch=8).bucket_sizes() == [8]


# ---------------------------------------------------------------------------
# refit == cold fit (satellite c)
# ---------------------------------------------------------------------------

class TestRefitEquivalence:
    def test_refit_matches_cold_fit(self):
        """Warm-started refit on grown data converges to the same
        predictions as a cold fit on the combined data (both to tight
        tolerance — the warm start changes the path, not the fixed
        point)."""
        A, _, yr = _data(m=64, seed=7)
        opts = _opts(tol=1e-7, max_iters=4096, check_every=4)
        est = KernelRidge(lam=1.0, kernel="rbf", options=opts)
        est.fit(A, yr)
        reg = ModelRegistry(predict_batch=64)
        reg.register("m", est)
        rng = np.random.default_rng(11)
        X_new = jnp.asarray(rng.standard_normal((12, 8)), jnp.float32)
        y_new = jnp.asarray(rng.standard_normal(12), jnp.float32)
        res = reg.refit("m", X_new, y_new)
        assert res.converged
        cold = KernelRidge(lam=1.0, kernel="rbf", options=opts)
        cold.fit(jnp.concatenate([A, X_new]),
                 jnp.concatenate([yr, y_new]))
        Xq = A[:16]
        np.testing.assert_allclose(np.asarray(reg.predict("m", Xq)),
                                   np.asarray(cold.predict(Xq)),
                                   atol=1e-5)

    def test_refit_moves_model_to_new_group(self, fitted):
        """Siblings on the OLD data keep their shared operator; the
        refitted model forms its own group over the grown data."""
        A, yc, yr = fitted["A"], fitted["yc"], fitted["yr"]
        reg = ModelRegistry(predict_batch=64)
        reg.register("a", fitted["svm"])
        reg.register("b", fitted["svm2"])
        assert reg.n_groups == 1
        rng = np.random.default_rng(5)
        X_new = jnp.asarray(rng.standard_normal((6, 8)), jnp.float32)
        y_new = jnp.asarray(np.sign(rng.standard_normal(6)), jnp.float32)
        reg.refit("a", X_new, y_new)
        assert reg.n_groups == 2
        assert reg.group("b").size == 1
        assert reg.models["a"].op is not reg.models["b"].op


# ---------------------------------------------------------------------------
# serve modules stay lint-clean (satellite e)
# ---------------------------------------------------------------------------

def test_serve_package_passes_repro_lint():
    """The jit-hygiene lint walks all of src/repro — including serve/.
    The serve modules must come back clean: their host-side record
    dataclasses (ServableModel, Ticket) carry JUSTIFIED suppressions,
    so no ACTIVE finding may anchor inside the package."""
    import os
    from repro.analysis import apply_suppressions
    from repro.analysis import lint
    findings = apply_suppressions(lint.run())
    active = [f for f in findings
              if not f.suppressed and os.sep + "serve" + os.sep in f.path]
    assert active == [], [f.format() for f in active]
    # and the suppressions themselves are anchored + justified
    supp = [f for f in findings
            if f.suppressed and os.sep + "serve" + os.sep in f.path]
    assert {os.path.basename(f.path) for f in supp} == \
        {"artifacts.py", "engine.py"}
