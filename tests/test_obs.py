"""repro.obs tests: metrics registry semantics, span/mark recording,
zero-cost-when-disabled (jaxpr identity), end-to-end instrumented fits,
the modeled-vs-measured audit, Chrome-trace export, serving metrics,
and the CLI (DESIGN.md §15)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelRidge, SolverOptions
from repro.obs import (MetricsRegistry, Telemetry, active_telemetry,
                       default_registry)
from repro.obs.audit import audit_fit
from repro.obs.export import (load_trace, save_trace, to_chrome_trace,
                              validate_chrome_trace)


def _problem(m=48, n=4, seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    y = jnp.asarray(np.asarray(A) @ rng.standard_normal(n), jnp.float32)
    return A, y


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_inc_and_labels(self):
        reg = MetricsRegistry()
        c = reg.counter("requests_total", "total requests")
        c.inc()
        c.inc(2.0, route="a")
        c.inc(route="a")
        assert c.value() == 1.0
        assert c.value(route="a") == 3.0

    def test_counter_rejects_decrease(self):
        c = MetricsRegistry().counter("x_total")
        with pytest.raises(ValueError, match="cannot decrease"):
            c.inc(-1.0)

    def test_gauge_set_and_negative_inc(self):
        g = MetricsRegistry().gauge("depth")
        g.set(5.0)
        g.inc(-2.0)
        assert g.value() == 3.0

    def test_histogram_quantile_and_overflow(self):
        h = MetricsRegistry().histogram("lat", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 0.7, 5.0):   # 5.0 lands in +Inf overflow
            h.observe(v)
        q50 = h.quantile(0.5)
        assert 0.1 <= q50 <= 1.0
        with pytest.raises(ValueError, match="quantile"):
            h.quantile(1.5)

    def test_histogram_empty_quantile_nan(self):
        h = MetricsRegistry().histogram("lat2", buckets=(1.0,))
        assert np.isnan(h.quantile(0.5))

    def test_kind_clash_raises(self):
        reg = MetricsRegistry()
        reg.counter("thing")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("thing")
        # same kind + name returns the same instrument
        assert reg.counter("thing") is reg.counter("thing")

    def test_bound_labels_fast_path(self):
        reg = MetricsRegistry()
        c = reg.counter("t_total")
        done = c.labels(status="done")
        done.inc()
        done.inc(2.0)
        assert c.value(status="done") == 3.0
        with pytest.raises(ValueError, match="cannot decrease"):
            done.inc(-1.0)
        with pytest.raises(TypeError, match="no set"):
            done.set(5.0)
        g = reg.gauge("d")
        bound = g.labels()
        bound.set(4.0)
        bound.inc(-1.0)
        assert g.value() == 3.0

    def test_prometheus_text_shape(self):
        reg = MetricsRegistry()
        reg.counter("c_total", "a counter").inc(status="ok")
        reg.gauge("g", "a gauge").set(2.5)
        reg.histogram("h_seconds", "a histogram",
                      buckets=(0.1, 1.0)).observe(0.5)
        text = reg.to_prometheus_text()
        assert "# HELP c_total a counter" in text
        assert "# TYPE c_total counter" in text
        assert 'c_total{status="ok"} 1' in text
        assert "# TYPE h_seconds histogram" in text
        assert 'h_seconds_bucket{le="+Inf"} 1' in text
        assert "h_seconds_sum" in text and "h_seconds_count 1" in text
        # json round-trips
        payload = json.loads(reg.to_json())
        assert set(payload) == {"c_total", "g", "h_seconds"}
        assert payload["c_total"]["kind"] == "counter"
        assert payload["h_seconds"]["values"]["count"] == 1

    def test_default_registry_is_process_singleton(self):
        assert default_registry() is default_registry()


# ---------------------------------------------------------------------------
# Telemetry spans, marks, activation
# ---------------------------------------------------------------------------

class TestTelemetry:
    def test_span_and_mark_recording(self):
        tel = Telemetry()
        with tel.span("build", "setup", m=8):
            tel.mark("seam", phase="solve", value=3.0)
        assert len(tel.spans) == 1 and len(tel.marks) == 1
        sp = tel.spans[0]
        assert sp.name == "build" and sp.phase == "setup"
        assert sp.duration >= 0 and sp.args == {"m": 8}
        assert tel.marks[0].value == 3.0
        lo, hi = tel.window()
        assert lo <= hi
        tel.clear()
        assert tel.spans == [] and tel.marks == []
        assert tel.window() is None

    def test_disabled_handle_records_nothing(self):
        tel = Telemetry(enabled=False)
        with tel.span("x"):
            tel.mark("y")
        assert tel.spans == [] and tel.marks == []
        with tel.activate():
            # disabled handles activate as None: callbacks stay silent
            assert active_telemetry() is None

    def test_activation_nests_and_restores(self):
        a, b = Telemetry(), Telemetry()
        assert active_telemetry() is None
        with a.activate():
            assert active_telemetry() is a
            with b.activate():
                assert active_telemetry() is b
            assert active_telemetry() is a
        assert active_telemetry() is None

    def test_paired_marks_lifo_and_unmatched_dropped(self):
        from repro.obs.spans import Mark
        tel = Telemetry()
        tel.marks = [Mark("a", "round", 1.0, "B"),
                     Mark("a", "round", 2.0, "B"),
                     Mark("a", "round", 3.0, "E", value=7.0),
                     Mark("b", "round", 4.0, "B"),   # never closed
                     Mark("a", "round", 5.0, "E")]
        pairs = tel.paired_marks()
        assert [(p.t0, p.t1) for p in pairs] == [(2.0, 3.0), (1.0, 5.0)]
        assert pairs[0].args == {"value": 7.0}
        assert all(p.name == "a" for p in pairs)

    def test_traced_marks_recorded_under_jit(self):
        from repro.obs.spans import chunk_mark, span_begin, span_end

        @jax.jit
        def f(x):
            span_begin("seg")
            y = x * 2.0
            chunk_mark("seam", value=jnp.sum(y))
            span_end("seg")
            return y

        tel = Telemetry()
        with tel.activate():
            jax.block_until_ready(f(jnp.ones(4)))
        kinds = sorted(m.kind for m in tel.marks)
        assert kinds == ["B", "E", "i"]
        seam = [m for m in tel.marks if m.name == "seam"][0]
        assert seam.value == 8.0
        assert len(tel.paired_marks()) == 1

    def test_no_active_handle_is_silent(self):
        from repro.obs.spans import chunk_mark

        @jax.jit
        def f(x):
            chunk_mark("quiet")
            return x + 1

        jax.block_until_ready(f(jnp.zeros(2)))   # must not raise


# ---------------------------------------------------------------------------
# zero ops when disabled (the acceptance bar: jaxpr-identical)
# ---------------------------------------------------------------------------

class TestZeroCostDisabled:
    def _jaxpr(self, marks):
        from repro.api import _krr_serial_tol
        from repro.core.bdcd import KRRConfig
        from repro.core.kernels import KernelConfig
        cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf", sigma=1.0))
        A = jnp.ones((16, 3))
        y = jnp.ones(16)
        a0 = jnp.zeros(16)
        sched = jnp.zeros((8, 4), jnp.int32)
        return str(jax.make_jaxpr(
            lambda A, y, a0, sched: _krr_serial_tol(
                A, y, a0, sched, 1e-6, cfg=cfg, s=4, check_every=2,
                slab_free=False, marks=marks))(A, y, a0, sched))

    def test_marks_off_has_no_callback_and_is_deterministic(self):
        off1, off2 = self._jaxpr(False), self._jaxpr(False)
        assert off1 == off2
        assert "callback" not in off1

    def test_marks_on_adds_only_callbacks(self):
        on = self._jaxpr(True)
        assert "callback" in on


# ---------------------------------------------------------------------------
# instrumented fits end to end
# ---------------------------------------------------------------------------

class TestInstrumentedFit:
    def _fit(self, tel, **opt_kw):
        A, y = _problem()
        kw = dict(method="sstep", s=4, b=4, tol=1e-10, check_every=4,
                  max_iters=64, telemetry=tel)
        kw.update(opt_kw)
        kr = KernelRidge(lam=0.5, kernel="rbf",
                         options=SolverOptions(**kw))
        return kr.fit(A, y)

    def test_fit_records_spans_and_result_carries_handle(self):
        tel = Telemetry()
        res = self._fit(tel)
        assert res.telemetry is tel
        phases = {s.phase for s in tel.spans}
        assert {"setup", "solve", "fit"} <= phases
        names = [s.name for s in tel.spans]
        assert "representation_build" in names and "fit" in names
        # the tolerance path fired traced metric-check marks
        assert any(m.name == "metric_check" for m in tel.marks)
        assert len(tel.paired_marks()) >= 1

    def test_guarded_fit_counts_corrections(self):
        tel = Telemetry()
        self._fit(tel, guard=True, recompute_every=4)
        c = tel.metrics.counter("repro_guard_corrections_total")
        assert c.value() >= 1
        assert any(m.name == "drift_correction" for m in tel.marks)

    def test_no_telemetry_fit_unchanged(self):
        res = self._fit(None)
        assert res.telemetry is None

    def test_audit_reconciles_instrumented_fit(self):
        tel = Telemetry()
        res = self._fit(tel, guard=True, recompute_every=4)
        report = audit_fit(res)
        assert report.rows
        names = {r.phase for r in report.rows}
        assert {"setup", "compute", "check"} <= names
        assert report.measured_total_s > 0
        d = report.to_dict()
        assert set(d) >= {"rows", "ratio", "tol", "flagged"}
        assert "phase" in report.render()

    def test_audit_requires_telemetry(self):
        res = self._fit(None)
        with pytest.raises(ValueError, match="telemetry"):
            audit_fit(res)


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------

class TestTraceExport:
    def _recorded(self):
        tel = Telemetry()
        res = self._res = KernelRidge(
            lam=0.5, kernel="rbf",
            options=SolverOptions(method="sstep", s=4, b=4, tol=1e-10,
                                  check_every=4, max_iters=32,
                                  telemetry=tel)).fit(*_problem())
        return res.telemetry

    def test_chrome_trace_schema(self, tmp_path):
        tel = self._recorded()
        trace = to_chrome_trace(tel)
        validate_chrome_trace(trace)          # must not raise
        evs = trace["traceEvents"]
        assert any(e["ph"] == "X" for e in evs)
        assert all({"name", "ph", "ts", "pid", "tid"} <= set(e)
                   for e in evs if e["ph"] != "M")
        path = save_trace(str(tmp_path / "t.json"), tel)
        back = load_trace(path)
        assert len(back["traceEvents"]) == len(evs)

    def test_validate_rejects_bad_traces(self):
        with pytest.raises(ValueError):
            validate_chrome_trace({})
        with pytest.raises(ValueError):
            validate_chrome_trace({"traceEvents": [
                {"name": "x", "ph": "Q", "ts": 0.0, "pid": 1,
                 "tid": 1}]})
        with pytest.raises(ValueError):
            validate_chrome_trace({"traceEvents": [
                {"name": "x", "ph": "X", "ts": -1.0, "dur": 1.0,
                 "pid": 1, "tid": 1}]})
        with pytest.raises(ValueError):   # unbalanced B without E
            validate_chrome_trace({"traceEvents": [
                {"name": "x", "ph": "B", "ts": 0.0, "pid": 1,
                 "tid": 1}]})


# ---------------------------------------------------------------------------
# serving metrics
# ---------------------------------------------------------------------------

class TestServeMetrics:
    def test_engine_instruments(self):
        from repro.serve import ModelRegistry, ServingEngine
        A, y = _problem(m=32)
        kr = KernelRidge(lam=0.5, kernel="rbf",
                         options=SolverOptions(method="sstep", s=4, b=4,
                                               max_iters=32))
        kr.fit(A, y)
        reg = ModelRegistry(predict_batch=8)
        reg.register("krr", kr)
        tel = Telemetry()
        eng = ServingEngine(reg, slots=8, telemetry=tel)
        Q = np.asarray(_problem(m=16)[0])
        for i in range(16):
            eng.submit("krr", Q[i][None, :])
        eng.run_until_idle()
        c = tel.metrics.counter("repro_serve_tickets_total")
        assert c.value(status="submitted") == 16
        assert c.value(status="done") == 16
        occ = tel.metrics.histogram("repro_serve_batch_occupancy")
        assert occ.quantile(0.5) > 0
        lat = tel.metrics.histogram("repro_serve_ticket_latency_seconds")
        assert not np.isnan(lat.quantile(0.5))
        assert any(s.name == "engine_step" for s in tel.spans)
        text = tel.metrics.to_prometheus_text()
        assert "repro_serve_queue_depth" in text

    def test_engine_without_telemetry_unchanged(self):
        from repro.serve import ModelRegistry, ServingEngine
        A, y = _problem(m=32)
        kr = KernelRidge(lam=0.5, kernel="linear",
                         options=SolverOptions(max_iters=16))
        kr.fit(A, y)
        reg = ModelRegistry(predict_batch=8)
        reg.register("krr", kr)
        eng = ServingEngine(reg, slots=8)
        eng.submit("krr", np.asarray(A[:1]))
        assert eng.run_until_idle() >= 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCLI:
    def test_report(self, capsys):
        from repro.obs.__main__ import main
        assert main(["report", "--m", "48", "--iters", "32"]) == 0
        out = capsys.readouterr().out
        assert "phase" in out and "ratio" in out

    def test_trace_and_scrape(self, tmp_path, capsys):
        from repro.obs.__main__ import main
        out_path = tmp_path / "t.json"
        assert main(["trace", "--m", "48", "--iters", "32",
                     "--out", str(out_path)]) == 0
        validate_chrome_trace(json.loads(out_path.read_text()))
        assert main(["scrape", "--m", "48", "--iters", "32",
                     "--tickets", "8"]) == 0
        out = capsys.readouterr().out
        assert "repro_serve_tickets_total" in out
