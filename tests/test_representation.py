"""Representation hierarchy + predict subsystem (ISSUE 4 acceptance):

  * ``LowRankGramOperator`` reductions match the materialized
    ``Phi Phi^T`` gram algebra (matvec / cross_block / diag / rows /
    round_data / scale_rows / take);
  * the batched slab-free predict path matches the legacy dense
    ``objectives.ksvm_predict`` / ``krr_predict`` oracles to <= 1e-5 on
    both estimators, at every batch/ragged-tail shape;
  * SV-compacted K-SVM serving returns the full model's decision values;
  * ``SolverOptions(approx="nystrom", landmarks=l)`` fit/predict
    round-trips on the serial and 1d layouts, with the Nystrom solution's
    relative error vs the exact solver bounded by the measured
    ``nystrom_kernel_error``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelRidge, KernelSVM, SolverOptions
from repro.core import (KernelConfig, KRRConfig, SVMConfig,
                        relative_solution_error)
from repro.core.kernels import (ExactGramOperator, LowRankGramOperator,
                                gram_slab)
from repro.core.nystrom import fit_nystrom, nystrom_kernel_error
from repro.core.objectives import krr_predict, ksvm_predict
from repro.core.predict import (BatchedPredictor, batched_predict,
                                compact_support)
from repro.data.synthetic import classification_dataset, regression_dataset

TOL = dict(rtol=1e-5, atol=1e-5)
KERN = KernelConfig("rbf", sigma=1.0)


@pytest.fixture(scope="module")
def krr_data():
    return regression_dataset(jax.random.key(2), m=96, n=8)


@pytest.fixture(scope="module")
def svm_data():
    return classification_dataset(jax.random.key(0), m=96, n=16)


# ---------------------------------------------------------------------------
# LowRankGramOperator vs the materialized Phi Phi^T gram
# ---------------------------------------------------------------------------

class TestLowRankOperatorParity:
    def _op_and_gram(self, krr_data):
        A, _ = krr_data
        fmap = fit_nystrom(jax.random.key(5), A, KERN, 24)
        op = LowRankGramOperator(Phi=fmap(A), fmap=fmap)
        K = op.Phi @ op.Phi.T                      # materialized oracle
        return op, K

    def test_reductions_match_materialized(self, krr_data):
        op, K = self._op_and_gram(krr_data)
        m = K.shape[0]
        idx = jnp.array([3, 17, 3, 95, 0])         # duplicates allowed
        X = jax.random.normal(jax.random.key(6), (m,))
        U = K[:, idx]                              # (m, r) slab
        np.testing.assert_allclose(np.asarray(op.matvec(idx, X)),
                                   np.asarray(U.T @ X), **TOL)
        np.testing.assert_allclose(np.asarray(op.cross_block(idx)),
                                   np.asarray(U[idx, :]), **TOL)
        np.testing.assert_allclose(np.asarray(op.diag(idx)),
                                   np.asarray(jnp.diagonal(U[idx, :])),
                                   **TOL)
        G, uTx = op.round_data(idx, X)
        np.testing.assert_allclose(np.asarray(G), np.asarray(U[idx, :]),
                                   **TOL)
        np.testing.assert_allclose(np.asarray(uTx), np.asarray(U.T @ X),
                                   **TOL)
        np.testing.assert_allclose(np.asarray(op.rows(idx)),
                                   np.asarray(op.Phi[idx]))
        assert op.n_samples == m and op.rank == 24

    def test_scale_rows_and_take(self, krr_data):
        op, K = self._op_and_gram(krr_data)
        m = K.shape[0]
        y = jnp.where(jnp.arange(m) % 2 == 0, 1.0, -1.0)
        idx = jnp.array([1, 2, 5])
        X = jnp.ones(m)
        scaled = op.scale_rows(y)                  # diag(y) K diag(y)
        Ky = (y[:, None] * K) * y[None, :]
        np.testing.assert_allclose(np.asarray(scaled.matvec(idx, X)),
                                   np.asarray(Ky[:, idx].T @ X), **TOL)
        sub = op.take(jnp.array([4, 9, 19]))
        np.testing.assert_allclose(np.asarray(sub.Phi),
                                   np.asarray(op.Phi[jnp.array([4, 9, 19])]))
        assert sub.fmap is op.fmap                 # serving map survives

    def test_sstep_solver_runs_on_lowrank_operator(self, krr_data):
        """Injecting the low-rank operator into the s-step solver equals
        running it on the materialized feature map with a linear kernel
        — the operator IS the representation seam."""
        from repro.core import block_schedule, sstep_bdcd_krr
        A, y = krr_data
        m = A.shape[0]
        op, _ = self._op_and_gram(krr_data)
        lin = KRRConfig(lam=1.0, kernel=KernelConfig("linear"))
        sched = block_schedule(jax.random.key(7), 32, m, 4)
        a_op, _ = sstep_bdcd_krr(op.Phi, y, jnp.zeros(m), sched, lin,
                                 s=8, op=op)
        a_ref, _ = sstep_bdcd_krr(op.Phi, y, jnp.zeros(m), sched, lin,
                                  s=8)
        np.testing.assert_allclose(np.asarray(a_op), np.asarray(a_ref),
                                   **TOL)


# ---------------------------------------------------------------------------
# batched slab-free predict vs the legacy dense oracles
# ---------------------------------------------------------------------------

class TestBatchedPredict:
    @pytest.mark.parametrize("batch", [7, 32, 96, 1024])
    def test_krr_exact_matches_legacy_dense(self, krr_data, batch):
        A, y = krr_data
        reg = KernelRidge(lam=1.0, kernel=KERN,
                          options=SolverOptions(s=8, b=4, max_iters=64),
                          predict_batch=batch)
        res = reg.fit(A, y)
        legacy = krr_predict(A, res.alpha, A, reg.cfg)
        np.testing.assert_allclose(np.asarray(reg.predict(A)),
                                   np.asarray(legacy), **TOL)

    def test_ksvm_exact_matches_legacy_dense(self, svm_data):
        A, y = svm_data
        clf = KernelSVM(C=1.0, kernel=KERN,
                        options=SolverOptions(s=8, max_iters=128),
                        predict_batch=25)       # ragged tail: 96 % 25 != 0
        res = clf.fit(A, y)
        legacy = ksvm_predict(A, y, res.alpha, A, clf.cfg)
        np.testing.assert_allclose(np.asarray(clf.decision_function(A)),
                                   np.asarray(legacy), **TOL)
        assert jnp.all(clf.predict(A) == jnp.sign(legacy))

    def test_sv_compaction_preserves_decision_values(self, svm_data):
        """Dropping zero-alpha rows from the serving representation is
        exact: hinge duals are sparse, the compacted model must serve
        the SAME decision values as the full one."""
        A, y = svm_data
        clf = KernelSVM(C=1.0, kernel=KERN,
                        options=SolverOptions(s=8, max_iters=256))
        res = clf.fit(A, y)
        w = res.alpha * y
        full_op = ExactGramOperator(A, KERN)
        cop, cw = compact_support(full_op, w)
        n_sv = int(jnp.sum(res.alpha != 0))
        assert 0 < n_sv < A.shape[0]            # compaction is non-trivial
        assert cop.A.shape[0] == n_sv
        full = batched_predict(full_op, w, A, batch=31)
        compact = batched_predict(cop, cw, A, batch=31)
        np.testing.assert_allclose(np.asarray(compact), np.asarray(full),
                                   **TOL)
        # the estimator path compacts internally and must agree too
        np.testing.assert_allclose(np.asarray(clf.decision_function(A)),
                                   np.asarray(full), **TOL)

    def test_compact_support_degenerate_all_zero(self):
        op = ExactGramOperator(jnp.ones((4, 3)), KERN)
        cop, cw = compact_support(op, jnp.zeros(4))
        assert cop.A.shape[0] == 1 and float(cw[0]) == 0.0

    def test_predictor_jit_cache_reuse(self, krr_data):
        """Different query counts reuse bucketed block shapes (padded) —
        values must be identical to the one-shot call."""
        A, y = krr_data
        op = ExactGramOperator(A, KERN)
        w = jax.random.normal(jax.random.key(8), (A.shape[0],))
        pred = BatchedPredictor(op, w, batch=40)
        for q in (1, 39, 40, 41, 96):
            np.testing.assert_allclose(
                np.asarray(pred(A[:q])),
                np.asarray(gram_slab(A[:q], A, KERN) @ w), **TOL)

    def test_predictor_block_buckets_and_empty(self, krr_data):
        """A stream of varying query counts compiles at most
        log2(batch) block shapes (power-of-two buckets), and a drained
        queue (q=0) returns an empty array instead of crashing."""
        A, _ = krr_data
        op = ExactGramOperator(A, KERN)
        w = jax.random.normal(jax.random.key(9), (A.shape[0],))
        pred = BatchedPredictor(op, w, batch=64)
        blocks = {pred.block_shape(q) for q in range(1, 97)}
        assert blocks <= {8, 16, 32, 64}
        # ragged tail reuses a smaller bucket, values unchanged
        np.testing.assert_allclose(
            np.asarray(pred(A[:65])),
            np.asarray(gram_slab(A[:65], A, KERN) @ w), **TOL)
        empty = pred(A[:0])
        assert empty.shape == (0,)
        with pytest.raises(ValueError):
            BatchedPredictor(op, w, batch=0)
        from repro.api import KernelRidge
        with pytest.raises(ValueError):
            KernelRidge(predict_batch=-1)


# ---------------------------------------------------------------------------
# facade approx="nystrom" round-trips (serial + 1d)
# ---------------------------------------------------------------------------

class TestFacadeNystrom:
    @pytest.mark.parametrize("layout", ["serial", "1d"])
    def test_krr_fit_predict_roundtrip(self, krr_data, layout):
        A, y = krr_data
        opts = SolverOptions(method="sstep", s=8, b=4, max_iters=512,
                             layout=layout, approx="nystrom", landmarks=80)
        reg = KernelRidge(lam=1.0, kernel=KERN, options=opts)
        res = reg.fit(A, y)
        assert res.representation == "nystrom(l=80)"
        assert res.comm["approx"] == "nystrom"
        assert res.comm["setup_flops"] > 0

        # acceptance bound: solution error vs the EXACT solver stays
        # within the measured rank-l kernel error
        exact = KernelRidge(
            lam=1.0, kernel=KERN,
            options=SolverOptions(method="sstep", s=8, b=4,
                                  max_iters=512)).fit(A, y)
        rel = float(relative_solution_error(res.alpha, exact.alpha))
        kerr = nystrom_kernel_error(A, reg.op_.fmap.landmarks, KERN)
        assert rel <= kerr, (rel, kerr)

        # predictions serve through the SAME fitted feature map, and the
        # batched path matches the legacy dense predict on Phi
        pred = reg.predict(A)
        lin_cfg = KRRConfig(lam=1.0, kernel=KernelConfig("linear"))
        legacy = krr_predict(reg.op_.Phi, res.alpha, reg.op_.Phi, lin_cfg)
        np.testing.assert_allclose(np.asarray(pred), np.asarray(legacy),
                                   **TOL)

    @pytest.mark.parametrize("layout", ["serial", "1d"])
    def test_ksvm_fit_predict_roundtrip(self, svm_data, layout):
        A, y = svm_data
        opts = SolverOptions(method="sstep", s=8, max_iters=256,
                             layout=layout, approx="nystrom", landmarks=64)
        clf = KernelSVM(C=1.0, kernel=KERN, options=opts)
        res = clf.fit(A, y)
        assert res.representation == "nystrom(l=64)"
        d = clf.decision_function(A)
        assert d.shape == (A.shape[0],)
        # decision values equal the low-rank kernel expansion
        Phi = clf.op_.Phi
        want = Phi @ (Phi.T @ (res.alpha * y))
        np.testing.assert_allclose(np.asarray(d), np.asarray(want), **TOL)
        # approximate-kernel training should still classify comparably
        exact = KernelSVM(C=1.0, kernel=KERN,
                          options=SolverOptions(method="sstep", s=8,
                                                max_iters=256)).fit(A, y)
        acc_exact = float(jnp.mean(jnp.sign(
            ksvm_predict(A, y, exact.alpha, A, clf.cfg)) == y))
        acc_ny = float(jnp.mean(clf.predict(A) == y))
        assert acc_ny >= acc_exact - 0.1

    def test_full_rank_nystrom_matches_exact_solver(self, krr_data):
        """l = m: the representation is exact (up to the jitter floor),
        so the facade's low-rank path must land on the exact solution."""
        A, y = krr_data
        m = A.shape[0]
        base = dict(method="sstep", s=8, b=4, max_iters=256)
        res_n = KernelRidge(lam=1.0, kernel=KERN,
                            options=SolverOptions(approx="nystrom",
                                                  landmarks=m, **base)
                            ).fit(A, y)
        res_e = KernelRidge(lam=1.0, kernel=KERN,
                            options=SolverOptions(**base)).fit(A, y)
        assert float(relative_solution_error(res_n.alpha,
                                             res_e.alpha)) < 1e-2

    def test_kmeans_landmark_option(self, krr_data):
        A, y = krr_data
        opts = SolverOptions(s=8, b=4, max_iters=64, approx="nystrom",
                             landmarks=32, landmark_method="kmeans")
        res = KernelRidge(lam=1.0, kernel=KERN, options=opts).fit(A, y)
        assert res.alpha.shape == (A.shape[0],)

    def test_landmarks_clip_to_m(self, krr_data):
        A, y = krr_data
        opts = SolverOptions(s=8, b=4, max_iters=32, approx="nystrom",
                             landmarks=10_000)
        res = KernelRidge(lam=1.0, kernel=KERN, options=opts).fit(A, y)
        assert res.representation == f"nystrom(l={A.shape[0]})"

    @pytest.mark.parametrize("bad", [
        dict(approx="rff"),
        dict(approx="nystrom", landmarks=0),
        dict(approx="nystrom", landmark_method="leverage"),
    ])
    def test_bad_options_raise_eagerly(self, bad):
        with pytest.raises(ValueError):
            SolverOptions(**bad)

    @pytest.mark.parametrize("l", [16, 64, 256])
    @pytest.mark.parametrize("s", [1, 8])
    def test_lowrank_pricing_invariants(self, l, s):
        """Representation pricing (DESIGN.md §9): for l << n the
        low-rank round flops undercut exact ones (setup aside), the
        setup cost is what separates total from round cost, low-rank
        serving beats exact per query, and SV compaction scales exact
        serving linearly."""
        from repro.core.perf_model import (lowrank_setup_cost,
                                           modeled_fit_cost,
                                           modeled_predict_cost)
        m, n, q = 4096, 2048, 512
        exact = modeled_fit_cost(m, n, "rbf", s=s, iters=64, P=1)
        low = modeled_fit_cost(m, n, "rbf", s=s, iters=64, P=1,
                               approx="nystrom", landmarks=l)
        setup = lowrank_setup_cost(m, n, l, "rbf")
        np.testing.assert_allclose(low["setup_flops"], setup["flops"])
        assert low["flops"] - low["setup_flops"] < exact["flops"]
        # linear-factor rounds psum only the contracted (sb, sb+1)
        # words; the exact nonlinear payload is m-sized (Thm 2)
        sb, rounds = s * 1, (64 if s == 1 else 64 / s)
        np.testing.assert_allclose(low["words"], rounds * sb * (sb + 1))
        assert low["words"] < exact["words"]
        pe = modeled_predict_cost(m, n, q, "rbf")
        pl = modeled_predict_cost(m, n, q, "rbf", approx="nystrom",
                                  landmarks=l)
        assert pl["flops_per_query"] < pe["flops_per_query"]
        half = modeled_predict_cost(m, n, q, "rbf", sv_fraction=0.5)
        np.testing.assert_allclose(half["flops"], pe["flops"] / 2,
                                   rtol=1e-2)

    def test_lowrank_gap_matches_dense_oracle(self, svm_data):
        """The O(m l) factored duality gap equals the generic oracle
        evaluated with a linear kernel over Phi (which builds the m x m
        gram) — for both loss variants."""
        from repro.core import SVMConfig, ksvm_duality_gap
        from repro.core.objectives import ksvm_duality_gap_lowrank
        A, y = svm_data
        fmap = fit_nystrom(jax.random.key(21), A, KERN, 32)
        Phi = fmap(A)
        alpha = jax.random.uniform(jax.random.key(22), (A.shape[0],))
        for loss in ("l1", "l2"):
            cfg = SVMConfig(C=1.0, loss=loss,
                            kernel=KernelConfig("linear"))
            np.testing.assert_allclose(
                float(ksvm_duality_gap_lowrank(Phi, y, alpha, cfg)),
                float(ksvm_duality_gap(Phi, y, alpha, cfg)),
                rtol=1e-4)

    def test_ksvm_tol_stopping_under_approx(self, svm_data):
        """K-SVM low-rank tolerance stopping runs the factored gap (no
        m x m gram) and terminates."""
        A, y = svm_data
        opts = SolverOptions(method="sstep", s=8, max_iters=4096,
                             tol=1e-3, check_every=8, approx="nystrom",
                             landmarks=64)
        res = KernelSVM(C=1.0, kernel=KERN, options=opts).fit(A, y)
        assert res.converged
        assert res.metric_history()[-1] <= 1e-3

    def test_tol_stopping_under_approx(self, krr_data):
        """The stopping metric is evaluated under the SAME approximate
        kernel the solver optimizes, so tolerance stopping terminates."""
        A, y = krr_data
        opts = SolverOptions(method="sstep", s=8, b=4, max_iters=2048,
                             tol=1e-4, check_every=4, approx="nystrom",
                             landmarks=80)
        res = KernelRidge(lam=1.0, kernel=KERN, options=opts).fit(A, y)
        assert res.converged
        assert res.metric_history()[-1] <= 1e-4
