"""End-to-end behaviour tests for the whole system."""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")


def _run(args, timeout=900, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + str(ROOT) + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    if extra_env:
        env.update(extra_env)
    out = subprocess.run([sys.executable] + args, env=env, cwd=str(ROOT),
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, \
        f"cmd {args} failed\nstdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_quickstart_example():
    out = _run(["examples/quickstart.py"])
    assert "same solution" in out


def test_solve_driver_ksvm():
    out = _run(["-m", "repro.launch.solve", "--problem", "ksvm",
                "--dataset", "duke", "--s", "16", "--H", "128"])
    assert "duality gap" in out


def test_solve_driver_krr():
    out = _run(["-m", "repro.launch.solve", "--problem", "krr",
                "--dataset", "bodyfat", "--b", "8", "--s", "8",
                "--H", "64"])
    assert "rel err" in out


def test_train_driver_tiny_loss_decreases():
    out = _run(["examples/lm_train.py", "--tiny", "--steps", "20"])
    assert "loss decreased" in out


def test_serve_example_mamba():
    out = _run(["examples/lm_serve.py", "--arch", "falcon-mamba-7b",
                "--new-tokens", "4", "--prompt-len", "4"])
    assert out.strip().endswith("ok")


def test_krr_example_with_lm_features():
    out = _run(["examples/krr_regression.py", "--features-from",
                "qwen3-1.7b", "--m", "64", "--H", "32", "--b", "8",
                "--s", "8"])
    assert "rel err" in out


def test_defer_s_reduces_collective_count():
    """Paper fidelity in the LM trainer: defer_s=4 must execute ~4x fewer
    gradient psums per step than defer_s=1 (the s-step claim, verified
    structurally at the jaxpr level where scan trip counts are visible)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.launch.jaxpr_analysis import count_collective_executions
from repro.models.sharding import MeshRules
from repro.models import abstract_params
from repro.optim import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig, make_defer_train_step

cfg = get_config("qwen3_1p7b", reduced=True)
mesh = jax.make_mesh((4, 1), ("data", "model"))
rules = MeshRules(mesh)
acfg = AdamWConfig()
ap = abstract_params(cfg)
aopt = jax.eval_shape(adamw_init, ap)
batch = {
    "tokens": jax.ShapeDtypeStruct((16, 16), jnp.int32),
    "labels": jax.ShapeDtypeStruct((16, 16), jnp.int32),
}
counts = {}
for s in (1, 4):
    tcfg = TrainConfig(microbatches=4, defer_s=s)
    step = make_defer_train_step(cfg, acfg, tcfg, rules)
    jaxpr = jax.make_jaxpr(
        lambda p, o, b: step(p, o, b))(ap, aopt, batch)
    counts[s] = count_collective_executions(jaxpr)
    print("defer_s", s, "collective executions:", counts[s])
print("RATIO", counts[1] / max(counts[4], 1))
assert counts[1] >= 3 * counts[4], counts
"""
    out = _run(["-c", code])
    assert "RATIO" in out


def test_benchmarks_fast_subset():
    out = _run(["-m", "benchmarks.run", "--fast", "--only", "fig2,fig4"],
               timeout=1200)
    assert "fig2/" in out and "fig4/" in out
    assert "FAILED" not in out
