"""Optimizer + compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import global_norm, schedule
from repro.optim.compression import (compress_int8, decompress_int8,
                                     error_feedback_compress, init_residual)


def _toy_params():
    return {"w": jnp.ones((4, 4)), "blocks": ({"b": jnp.ones((3,))},)}


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, m = adamw_update(cfg, params, g, opt)
    assert float(loss(params)) < 0.5


def test_adamw_handles_tuple_pytrees():
    cfg = AdamWConfig()
    params = _toy_params()
    opt = adamw_init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    newp, newo, metrics = adamw_update(cfg, params, grads, opt)
    assert jax.tree_util.tree_structure(newp) == \
        jax.tree_util.tree_structure(params)
    assert int(newo["step"]) == 1
    assert float(metrics["grad_norm"]) > 0


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, 5)) < 1.0
    np.testing.assert_allclose(float(schedule(cfg, 10)), 1.0, rtol=1e-5)
    assert float(schedule(cfg, 100)) <= 0.1 + 1e-6


def test_grad_clip_limits_update_norm():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros((8,))}
    opt = adamw_init(params)
    huge = {"w": 1e6 * jnp.ones((8,))}
    _, _, m = adamw_update(cfg, params, huge, opt)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (1000,))
    q, s, meta = compress_int8(x)
    y = decompress_int8(q, s, meta)
    assert q.dtype == jnp.int8
    # per-block max/127 quantization error bound
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(s)) * 0.51


def test_error_feedback_recovers_mean():
    """With error feedback, the accumulated quantized sum converges to the
    true sum (unbiasedness over repeated steps)."""
    g = {"w": 0.01 * jnp.ones((64,))}
    r = init_residual(g)
    total = jnp.zeros((64,))
    for _ in range(100):
        deq, r = error_feedback_compress(g, r)
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total), 1.0, atol=0.02)
