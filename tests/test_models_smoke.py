"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED config of each family, run one forward + one train-grad step and a
few decode steps on CPU; assert shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, loss_fn, prefill_cross_kv)

B, S = 2, 16


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.stack([pos, pos // 4, pos % 4])
    if cfg.encoder_layers:
        batch["audio_embed"] = jax.random.normal(
            jax.random.fold_in(key, 7), (B, cfg.encoder_seq, cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))

    logits = forward(params, cfg, batch["tokens"],
                     positions=batch.get("positions"),
                     audio_embed=batch.get("audio_embed"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # gradient must reach the embedding and at least one block param
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """Teacher-forced decode logits must match the training forward pass
    position-by-position (validates KV caches / SSM streaming states).

    MoE archs are pinned to dense dispatch here: capacity dispatch can
    drop overflow tokens at prefill (per-row capacity) but never at
    decode (S=1) — the standard train/serve routing drift of
    capacity-based MoE, not a cache bug."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_impl="dense")
    if cfg.mrope:
        pytest.skip("M-RoPE decode uses 3D positions; covered separately")
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    toks = batch["tokens"]

    ref = forward(params, cfg, toks,
                  audio_embed=batch.get("audio_embed"))

    state = init_decode_state(cfg, B, S, with_encoder=bool(cfg.encoder_layers))
    if cfg.encoder_layers:
        state["cross_kv"] = prefill_cross_kv(params, cfg,
                                             batch["audio_embed"])
    outs = []
    for t in range(S):
        logits, state = decode_step(params, cfg, state, toks[:, t:t + 1])
        outs.append(logits)
    got = jnp.stack(outs, axis=1)
    assert bool(jnp.all(jnp.isfinite(got)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


def test_param_count_sanity():
    """Full-config analytic param counts are in the advertised ballpark."""
    expect = {
        "llama3_405b": (350e9, 480e9),
        # assigned dims (52L x 6144 x 24576, untied 49k vocab) -> 28.2B
        "granite_20b": (15e9, 30e9),
        "yi_6b": (5e9, 8e9),
        "qwen3_1p7b": (1.2e9, 2.6e9),
        "zamba2_1p2b": (0.8e9, 1.8e9),
        "qwen2_vl_72b": (60e9, 85e9),
        "deepseek_v2_lite_16b": (12e9, 20e9),
        "arctic_480b": (380e9, 560e9),
        "falcon_mamba_7b": (5e9, 9e9),
        "whisper_tiny": (20e6, 80e6),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]B"


def test_reduced_configs_preserve_family_traits():
    for arch in ARCHS:
        full, red = get_config(arch), get_config(arch, reduced=True)
        assert full.pattern == red.pattern or len(full.pattern) == len(red.pattern)
        assert full.attn_type == red.attn_type
        assert bool(full.n_experts) == bool(red.n_experts)
        assert full.qk_norm == red.qk_norm
        assert full.mrope == red.mrope
        assert bool(full.encoder_layers) == bool(red.encoder_layers)
        assert bool(full.shared_attn_every) == bool(red.shared_attn_every)


def test_mrope_decode_matches_prefill_when_streams_align():
    """qwen2-vl decode uses (t,t,t) position streams; with the same
    streams at train time the teacher-forced decode must match prefill."""
    import numpy as np
    from repro.models import decode_step, forward, init_decode_state
    cfg = get_config("qwen2_vl_72b", reduced=True)
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    pos3 = jnp.stack([pos, pos, pos])
    ref = forward(params, cfg, toks, positions=pos3)

    state = init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        logits, state = decode_step(params, cfg, state, toks[:, t:t + 1])
        outs.append(logits)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)
