"""The ``repro.tune`` subsystem contract (ISSUE 5, DESIGN.md §10):

  * a vmapped fleet of F lambdas/Cs is elementwise-equal (scan path,
    shared schedule) to F sequential facade fits — serial AND 1d;
  * fleet tolerance stopping is per member: converged members freeze,
    the history is (checks, F), and every converged member really is at
    or below tol under the facade's own metric;
  * warm-started solves at tight tolerance land on the cold solution
    (property test over seeds/lambdas);
  * ``reg_path`` spends no more total iterations than cold solves and
    its rungs match cold fits at the same tolerance;
  * ``cross_validate`` reports per-fold, per-value scores for both
    composition modes (fleet, path);
  * ``SolverOptions(s="auto")`` resolves through the perf model for
    BOTH representations (exact, nystrom), respects the HBM working-set
    constraint (as does ``perf_model.best_s``), and lands its
    ``TunedPlan`` on ``FitResult.plan``;
  * Nystrom kmeans landmark draws are reproducible end-to-end from
    ``SolverOptions.seed``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelRidge, KernelSVM, SolverOptions
from repro.core import KernelConfig, KRRConfig, NO_TOL, run_rounds
from repro.core.perf_model import Machine, Problem, best_s, slab_fits_hbm
from repro.data.synthetic import classification_dataset, regression_dataset
from repro.tune import (TunedPlan, cross_validate, reg_path,
                        resolve_options, solve_fleet)

M, N, H, S, B = 96, 16, 64, 8, 4
LAMS = (0.25, 1.0, 4.0, 16.0)
CS = (0.25, 1.0, 4.0)
TOL = dict(rtol=1e-5, atol=1e-5)


@pytest.fixture(scope="module")
def krr_data():
    return regression_dataset(jax.random.key(0), m=M, n=N)


@pytest.fixture(scope="module")
def svm_data():
    return classification_dataset(jax.random.key(1), m=M, n=N)


def _opts(**kw):
    base = dict(method="sstep", s=S, b=B, max_iters=H, seed=5)
    base.update(kw)
    return SolverOptions(**base)


# ---------------------------------------------------------------------------
# fleet parity vs sequential facade fits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["serial", "1d"])
def test_krr_fleet_matches_sequential(krr_data, layout):
    A, y = krr_data
    opts = _opts(layout=layout)
    fleet = solve_fleet(A, y, lams=LAMS, kernel="rbf", options=opts)
    assert fleet.alpha.shape == (len(LAMS), M)
    for i, lam in enumerate(LAMS):
        ref = KernelRidge(lam=lam, kernel="rbf", options=opts).fit(A, y)
        np.testing.assert_allclose(np.asarray(fleet.alpha[i]),
                                   np.asarray(ref.alpha), **TOL)


@pytest.mark.parametrize("layout", ["serial", "1d"])
def test_ksvm_fleet_matches_sequential(svm_data, layout):
    A, y = svm_data
    opts = _opts(b=1, layout=layout)
    fleet = solve_fleet(A, y, Cs=CS, kernel="rbf", options=opts)
    for i, C in enumerate(CS):
        ref = KernelSVM(C=C, kernel="rbf", options=opts).fit(A, y)
        np.testing.assert_allclose(np.asarray(fleet.alpha[i]),
                                   np.asarray(ref.alpha), **TOL)


def test_nystrom_fleet_matches_sequential(krr_data):
    A, y = krr_data
    opts = _opts(approx="nystrom", landmarks=24)
    fleet = solve_fleet(A, y, lams=LAMS, kernel="rbf", options=opts)
    assert fleet.representation == "nystrom(l=24)"
    for i, lam in enumerate(LAMS):
        ref = KernelRidge(lam=lam, kernel="rbf", options=opts).fit(A, y)
        np.testing.assert_allclose(np.asarray(fleet.alpha[i]),
                                   np.asarray(ref.alpha), **TOL)


def test_fleet_modeled_comm_amortizes(krr_data):
    A, y = krr_data
    fleet = solve_fleet(A, y, lams=LAMS, kernel="rbf", options=_opts())
    assert fleet.comm["modeled_speedup"] > 1.0
    assert fleet.comm["sequential_time"] > fleet.comm["time"]


def test_fleet_input_validation(krr_data):
    A, y = krr_data
    with pytest.raises(ValueError, match="exactly one"):
        solve_fleet(A, y, lams=LAMS, Cs=CS)
    with pytest.raises(ValueError, match="exactly one"):
        solve_fleet(A, y)
    with pytest.raises(ValueError, match="positive"):
        solve_fleet(A, y, lams=[1.0, -2.0])
    with pytest.raises(ValueError, match="slab-free"):
        solve_fleet(A, y, lams=LAMS, options=_opts(slab_free=False))
    with pytest.raises(ValueError, match="fleet layout"):
        solve_fleet(A, y, lams=LAMS, options=_opts(layout="2d"))


# ---------------------------------------------------------------------------
# per-member tolerance stopping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["serial", "1d"])
def test_fleet_per_member_stopping(krr_data, layout):
    A, y = krr_data
    opts = _opts(layout=layout, max_iters=1024, tol=5e-2, check_every=2)
    fleet = solve_fleet(A, y, lams=LAMS, kernel="rbf", options=opts)
    assert fleet.converged.all()
    assert fleet.history.shape[1] == len(LAMS)
    assert fleet.metric == "rel_residual"
    # every member's final state satisfies the facade's own stopper
    from repro.core import krr_rel_residual
    for i, lam in enumerate(LAMS):
        cfg = KRRConfig(lam=float(lam), kernel=KernelConfig("rbf"))
        assert float(krr_rel_residual(A, y, fleet.alpha[i], cfg)) <= 5e-2
    # member trajectories are per-member, not fleet-wide copies
    assert fleet.metric_history(0).shape == fleet.metric_history(1).shape
    assert not np.allclose(fleet.metric_history(0),
                           fleet.metric_history(len(LAMS) - 1))


def test_fleet_frozen_members_do_not_drift(krr_data):
    """A member that converges early must hold its state while the rest
    of the fleet keeps iterating (the vmap-safe freeze mask)."""
    A, y = krr_data
    # lam -> inf converges almost immediately; lam small converges last
    lams = (1000.0, 0.25)
    opts = _opts(max_iters=2048, tol=2e-2, check_every=2)
    fleet = solve_fleet(A, y, lams=lams, kernel="rbf", options=opts)
    assert fleet.converged.all()
    hist = fleet.metric_history(0)
    k = int(np.argmax(hist <= 2e-2))
    # once member 0 hit tol, its recorded metric never changes again
    np.testing.assert_allclose(hist[k:], hist[k], rtol=1e-6)


# ---------------------------------------------------------------------------
# warm starts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("lam", [0.5, 4.0])
def test_warm_start_matches_cold_property(seed, lam):
    """Property: a warm-started solve at tight tol lands on the same
    solution as a cold solve — the warm start changes the trajectory,
    not the fixed point."""
    A, y = regression_dataset(jax.random.key(100 + seed), m=64, n=8)
    opts = _opts(max_iters=4096, tol=1e-5, check_every=4, seed=seed)
    reg = KernelRidge(lam=lam, kernel="rbf", options=opts)
    cold = reg.fit(A, y)
    assert cold.converged
    # warm-start from a perturbed neighbourhood of another solution
    other = KernelRidge(lam=4.0 * lam, kernel="rbf", options=opts)
    w0 = other.fit(A, y).alpha
    warm = reg.fit(A, y, warm_start=w0)
    assert warm.converged
    assert warm.iters_run <= cold.iters_run
    np.testing.assert_allclose(np.asarray(warm.alpha),
                               np.asarray(cold.alpha), rtol=5e-4,
                               atol=5e-5)


def test_reg_path_warm_start_saves_iterations(krr_data):
    A, y = krr_data
    opts = _opts(max_iters=4096, tol=2e-2, check_every=4)
    path = reg_path(A, y, lams=LAMS, kernel="rbf", options=opts)
    assert path.param == "lam"
    assert list(path.values) == sorted(LAMS, reverse=True)
    assert all(r.converged for r in path.results)
    cold_total = sum(
        KernelRidge(lam=float(v), kernel="rbf", options=opts)
        .fit(A, y).iters_run for v in path.values)
    assert path.total_iters < cold_total
    # each rung matches its cold twin at the same tolerance scale
    for v, r in zip(path.values, path.results):
        cold = KernelRidge(lam=float(v), kernel="rbf",
                           options=opts).fit(A, y)
        np.testing.assert_allclose(np.asarray(r.alpha),
                                   np.asarray(cold.alpha), rtol=0.05,
                                   atol=5e-3)


def test_fit_path_updates_estimator_state(krr_data):
    A, y = krr_data
    opts = _opts(max_iters=1024, tol=5e-2, check_every=4)
    reg = KernelRidge(lam=123.0, kernel="rbf", options=opts)
    path = reg.fit_path(A, y, LAMS)
    assert reg.cfg.lam == float(path.values[-1]) == min(LAMS)
    np.testing.assert_allclose(np.asarray(reg.alpha_),
                               np.asarray(path.results[-1].alpha))
    assert reg.predict(A).shape == (M,)


def test_ksvm_fit_path(svm_data):
    A, y = svm_data
    opts = _opts(b=1, max_iters=512)
    clf = KernelSVM(C=1.0, kernel="rbf", options=opts)
    path = clf.fit_path(A, y, CS)
    assert path.param == "C"
    assert list(path.values) == sorted(CS)        # ascending C ladder
    assert clf.cfg.C == max(CS)
    assert clf.predict(A).shape == (M,)


# ---------------------------------------------------------------------------
# cross-validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("via", ["fleet", "path"])
def test_cross_validate_krr(krr_data, via):
    A, y = krr_data
    opts = _opts(max_iters=512, tol=5e-2, check_every=4)
    cv = cross_validate(A, y, lams=LAMS, kernel="rbf", options=opts,
                        folds=3, via=via)
    assert cv.scores.shape == (3, len(LAMS))
    assert cv.score_name == "mse" and np.all(cv.scores > 0)
    assert cv.best_value == cv.values[cv.best_index]
    assert cv.mean_scores[cv.best_index] == cv.mean_scores.min()


def test_cross_validate_ksvm():
    # wide-margin blobs: genuinely separable, so accuracy is informative
    A, y = classification_dataset(jax.random.key(9), m=M, n=N,
                                  margin=3.0)
    cv = cross_validate(A, y, Cs=CS, kernel="rbf",
                        options=_opts(b=1, max_iters=256), folds=3)
    assert cv.score_name == "accuracy"
    assert np.all((cv.scores >= 0) & (cv.scores <= 1))
    assert cv.mean_scores[cv.best_index] > 0.8


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("approx", [None, "nystrom"])
def test_auto_s_resolves_through_perf_model(krr_data, approx):
    A, y = krr_data
    opts = _opts(s="auto", approx=approx, landmarks=24)
    assert opts.needs_autotune
    res = KernelRidge(lam=1.0, kernel="rbf", options=opts).fit(A, y)
    plan = res.plan
    assert isinstance(plan, TunedPlan)
    assert isinstance(res.options.s, int) and res.options.s >= 1
    assert res.options.approx == approx
    assert len(plan.frontier) > 1
    # the winner is the cheapest FEASIBLE modeled candidate
    feas = [f for f in plan.frontier if f["feasible"]]
    assert plan.modeled["time"] == min(f["time"] for f in feas)
    # and the solve actually ran with it
    assert res.alpha.shape == (M,)


def test_auto_b_and_unresolved_s_eff(krr_data):
    A, y = krr_data
    opts = _opts(s="auto", b="auto")
    with pytest.raises(ValueError, match="unresolved"):
        _ = opts.s_eff
    res = KernelRidge(lam=1.0, kernel="rbf", options=opts).fit(A, y)
    assert isinstance(res.options.s, int)
    assert isinstance(res.options.b, int)


def test_auto_ksvm_with_probe(svm_data):
    A, y = svm_data
    opts = _opts(b=1, s="auto", probe=2, max_iters=32)
    res = KernelSVM(C=1.0, kernel="rbf", options=opts).fit(A, y)
    assert res.plan.probed is not None and len(res.plan.probed) >= 1
    assert all("measured_s" in p for p in res.plan.probed)


def test_autotune_respects_hbm_constraint():
    """With a tiny HBM budget the tuner must refuse deep s even when the
    model says deeper is faster."""
    cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf"))
    opts = SolverOptions(method="sstep", s="auto", b=8, max_iters=1024)
    budget = 4 * 50_000 * 8 * 4        # only slabs with s*b < 32 fit
    plan = resolve_options(50_000, 64, cfg, opts, problem="krr",
                           hbm_bytes=budget)
    s = plan.options.s
    assert s == 1 or slab_fits_hbm(50_000, s * 8, budget)
    infeasible = [f for f in plan.frontier if not f["feasible"]]
    assert infeasible, "frontier must expose the clipped candidates"


def test_autotune_pinned_infeasible_s_does_not_crash():
    """A PINNED s above the HBM budget must not crash the tuner (the
    feasibility filter only guards what autotune itself selects): the
    remaining auto knobs resolve best-effort toward the smallest
    working set."""
    cfg = KRRConfig(lam=1.0, kernel=KernelConfig("rbf"))
    opts = SolverOptions(method="sstep", s=256, b="auto", max_iters=1024)
    budget = 4 * 50_000 * 8            # nothing with s=256 fits
    plan = resolve_options(50_000, 64, cfg, opts, problem="krr",
                           hbm_bytes=budget)
    assert plan.options.s == 256       # the pinned knob is respected
    assert plan.options.b == 1         # smallest working set wins
    assert not any(f["feasible"] for f in plan.frontier)


def test_best_s_respects_feasibility():
    prob = Problem(m=1 << 20, n=64, b=8, H=1024)
    mach = Machine()
    budget = 64 * 2 ** 20              # 64 MiB: only tiny slabs fit
    s, t, frontier = best_s(prob, mach, P=64, hbm_bytes=budget,
                            return_frontier=True)
    assert s == 1 or slab_fits_hbm(prob.m, s * prob.b, budget)
    assert any(not f["feasible"] for f in frontier)
    # unconstrained search may pick deeper s (the constraint binds)
    s_free, _ = best_s(prob, mach, P=64)
    assert s_free >= s


def test_solver_options_auto_validation():
    with pytest.raises(ValueError, match="positive int"):
        SolverOptions(s="AUTO")
    with pytest.raises(ValueError, match="positive int"):
        SolverOptions(b=0)
    with pytest.raises(ValueError, match="probe"):
        SolverOptions(probe=-1)
    assert SolverOptions(s="auto", b="auto", layout="auto",
                         approx="auto").needs_autotune
    assert not SolverOptions().needs_autotune


# ---------------------------------------------------------------------------
# satellites: metric_history accessor, reproducible Nystrom seeding
# ---------------------------------------------------------------------------

def test_metric_history_accessors(krr_data):
    A, y = krr_data
    res = KernelRidge(lam=1.0, kernel="rbf",
                      options=_opts(record=True, check_every=2)).fit(A, y)
    np.testing.assert_array_equal(res.metric_history(), res.history)
    assert len(res.metric_history()) == -(-res.rounds_run // 2)
    # no-record fits expose None, not a stale buffer
    res2 = KernelRidge(lam=1.0, kernel="rbf", options=_opts()).fit(A, y)
    assert res2.metric_history() is None
    # LoopResult-level accessor slices the padded buffer to checks_run
    lr = run_rounds(lambda a, x: a + 1.0, jnp.zeros(()),
                    jnp.zeros((7,)), tol=NO_TOL, check_every=3,
                    metric_fn=lambda a: a)
    assert lr.metric_history().shape == (int(lr.checks_run),)


@pytest.mark.parametrize("method", ["uniform", "kmeans"])
def test_nystrom_seed_reproducible_end_to_end(krr_data, method):
    """SolverOptions.seed alone must pin the landmark draw — kmeans
    farthest-first included — so Nystrom fits replay exactly."""
    A, y = krr_data
    mk = lambda seed: KernelRidge(
        lam=1.0, kernel="rbf",
        options=_opts(approx="nystrom", landmarks=16,
                      landmark_method=method, seed=seed))
    m1, m2, m3 = (mk(s) for s in (7, 7, 8))
    a1, a2, a3 = m1.fit(A, y), m2.fit(A, y), m3.fit(A, y)
    np.testing.assert_array_equal(np.asarray(m1.op_.fmap.landmarks),
                                  np.asarray(m2.op_.fmap.landmarks))
    np.testing.assert_array_equal(np.asarray(a1.alpha),
                                  np.asarray(a2.alpha))
    assert not np.array_equal(np.asarray(m1.op_.fmap.landmarks),
                              np.asarray(m3.op_.fmap.landmarks))
