"""Minimal positive/negative Pallas launch fixtures for the
``repro.analysis`` sanitizer tests.

Each function issues one ``pl.pallas_call`` with a deliberately broken
(or deliberately clean) launch geometry.  They are ONLY ever driven
under ``repro.analysis.registry.capture``, which replaces the launch
with a recorder — the kernel bodies never execute, so a no-op body is
enough.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import CompilerParams


def _nop(*refs):
    pass


def racing_out_spec():
    """Two PARALLEL grid points both map to output block (0, 0)."""
    x = jnp.zeros((16, 128), jnp.float32)
    pl.pallas_call(
        _nop,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
    )(x)


def accumulating_out_spec():
    """Clean twin of ``racing_out_spec``: the same revisit pattern along
    an ARBITRARY (sequential) axis — the legal accumulate idiom."""
    x = jnp.zeros((16, 128), jnp.float32)
    pl.pallas_call(
        _nop,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(x)


def coverage_hole():
    """Output has two row blocks; the index map only ever writes the
    first."""
    x = jnp.zeros((16, 128), jnp.float32)
    pl.pallas_call(
        _nop,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(x)


def full_coverage():
    """Clean twin of ``coverage_hole``: identity index map."""
    x = jnp.zeros((16, 128), jnp.float32)
    pl.pallas_call(
        _nop,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
    )(x)


def misaligned_block():
    """Lane-dim block of 100 on a 200-wide f32 array: neither a
    128-multiple nor the full array extent."""
    x = jnp.zeros((8, 200), jnp.float32)
    pl.pallas_call(
        _nop,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 100), lambda i: (0, i))],
        out_specs=pl.BlockSpec((8, 100), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, 200), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(x)


def aligned_block():
    """Clean twin of ``misaligned_block``: (8, 128) f32 tiles."""
    x = jnp.zeros((8, 256), jnp.float32)
    pl.pallas_call(
        _nop,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, i))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, 256), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(x)


def vmem_hog():
    """(2048, 2048) f32 in + out blocks: 32 MB of blocks, 64 MB
    double-buffered — 4x the 16 MB VMEM budget."""
    x = jnp.zeros((2048, 2048), jnp.float32)
    pl.pallas_call(
        _nop,
        grid=(1,),
        in_specs=[pl.BlockSpec((2048, 2048), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((2048, 2048), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2048, 2048), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(x)


def vmem_modest():
    """Clean twin of ``vmem_hog``: (128, 128) blocks fit trivially."""
    x = jnp.zeros((2048, 2048), jnp.float32)
    pl.pallas_call(
        _nop,
        grid=(16, 16),
        in_specs=[pl.BlockSpec((128, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((2048, 2048), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(x)
