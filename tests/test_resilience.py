"""Guarded-solve tests (DESIGN.md §12): drift correction, divergence
detection + escalation-ladder fallback, mid-solve checkpoint/resume, and
the fault-injection harness.

NOTE: this module deliberately injects NaN/Inf into solver carries — it
must NOT be added to conftest.KERNEL_TEST_MODULES (jax_debug_nans would
raise at the injection site instead of letting the guard catch it).
"""
import os
import pathlib
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (KernelRidge, KernelSVM, KernelConfig,
                       SolverOptions)
from repro.resilience import (DivergenceError, FaultPlan, SimulatedKill,
                              finite_health, inject, next_fallback)

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _data(m=192, n=12, seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    w = rng.standard_normal(n)
    yc = jnp.asarray(np.sign(A @ w + 0.1 * rng.standard_normal(m)),
                     jnp.float32)
    yr = jnp.asarray(A @ w + 0.1 * rng.standard_normal(m), jnp.float32)
    return A, yc, yr


def _opts(**kw):
    base = dict(method="sstep", s=8, max_iters=384, seed=3,
                slab_free=True)
    base.update(kw)
    return SolverOptions(**base)


# ----------------------------------------------------------------- guard


@pytest.mark.parametrize("problem", ["ksvm", "krr"])
@pytest.mark.parametrize("kernel", ["linear", "rbf"])
def test_guarded_matches_plain(problem, kernel):
    """The guarded carry protocol is an algebraic rearrangement: same
    iterate sequence as the plain solver, to f32 roundoff."""
    A, yc, yr = _data()
    kcfg = (KernelConfig(kernel) if kernel == "linear"
            else KernelConfig("rbf", sigma=0.3))
    if problem == "ksvm":
        plain = KernelSVM(C=1.0, kernel=kcfg, options=_opts())
        guard = KernelSVM(C=1.0, kernel=kcfg,
                          options=_opts(guard=True, recompute_every=16))
        y = yc
    else:
        plain = KernelRidge(lam=0.5, kernel=kcfg, options=_opts(b=8))
        guard = KernelRidge(lam=0.5, kernel=kcfg,
                            options=_opts(b=8, guard=True,
                                          recompute_every=16))
        y = yr
    rp, rg = plain.fit(A, y), guard.fit(A, y)
    np.testing.assert_allclose(np.asarray(rp.alpha),
                               np.asarray(rg.alpha), atol=5e-6)
    assert rg.health is not None and rg.health.guarded
    assert rg.health.corrections > 0
    assert rg.health.max_drift < 1e-4
    assert rg.health.fallbacks == ()
    assert rp.health is None


def test_drift_history_recorded():
    A, yc, _ = _data()
    svm = KernelSVM(C=1.0, kernel="rbf",
                    options=_opts(guard=True, recompute_every=8))
    r = svm.fit(A, yc)
    h = r.health
    assert len(h.drift) == h.corrections
    assert np.all(np.isfinite(h.drift))
    assert h.recompute_every == 8


def test_recompute_every_auto_resolves_under_budget():
    from repro.core.perf_model import (GUARD_OVERHEAD_BUDGET,
                                       guard_overhead)
    A, yc, _ = _data()
    svm = KernelSVM(C=1.0, kernel="linear", options=_opts(guard=True))
    r = svm.fit(A, yc)
    rec = r.options.recompute_every
    assert isinstance(rec, int) and rec >= 1
    over = guard_overhead(A.shape[0], A.shape[1], "linear", s=8,
                          recompute_every=rec)
    assert over <= GUARD_OVERHEAD_BUDGET + 1e-12


# --------------------------------------------- divergence + the ladder


@pytest.mark.parametrize("target", ["f", "alpha"])
def test_nan_fault_recovers_to_clean_solution(target):
    """Acceptance: injected NaN -> guard discards the poisoned round,
    the ladder halves s, and the final alpha matches an unguarded clean
    run within 1e-5."""
    A, yc, _ = _data()
    clean = KernelSVM(C=1.0, kernel="rbf", options=_opts()).fit(A, yc)
    svm = KernelSVM(C=1.0, kernel="rbf",
                    options=_opts(guard=True, recompute_every=16))
    with inject(FaultPlan(nan_at_iter=96, target=target)) as plan:
        r = svm.fit(A, yc)
    assert plan.carry_fired
    fb = r.health.fallbacks
    assert len(fb) == 1 and fb[0].kind == "nonfinite"
    assert fb[0].action == "halve_s:8->4"
    np.testing.assert_allclose(np.asarray(r.alpha),
                               np.asarray(clean.alpha), atol=1e-5)


def test_ladder_descends_to_classical_then_f64():
    """Three injected faults walk halve_s -> halve_s -> halve_s; a fault
    on an already-classical run escalates to f64."""
    A, _, yr = _data()
    clean = KernelRidge(lam=0.5, kernel="linear",
                        options=_opts(b=4, method="classical")).fit(A, yr)
    kr = KernelRidge(lam=0.5, kernel="linear",
                     options=_opts(b=4, method="classical", guard=True))
    with inject(FaultPlan(nan_at_iter=40, target="alpha")):
        r = kr.fit(A, yr)
    assert [e.action for e in r.health.fallbacks] == ["f64"]
    np.testing.assert_allclose(np.asarray(r.alpha),
                               np.asarray(clean.alpha), atol=1e-5)


def test_fallback_disabled_raises():
    A, yc, _ = _data()
    svm = KernelSVM(C=1.0, kernel="rbf",
                    options=_opts(guard=True, fallback=False))
    with inject(FaultPlan(nan_at_iter=96)):
        with pytest.raises(DivergenceError, match="fallback is disabled"):
            svm.fit(A, yc)


def test_next_fallback_ladder():
    assert next_fallback(8, "sstep", False) == ("halve_s:8->4", 4,
                                                "sstep", False)
    assert next_fallback(2, "sstep", False)[1:] == (1, "sstep", False)
    assert next_fallback(1, "sstep", False) == ("classical", 1,
                                                "classical", False)
    assert next_fallback(1, "classical", False) == ("f64", 1,
                                                    "classical", True)
    with pytest.raises(DivergenceError, match="exhausted"):
        next_fallback(1, "classical", True)


def test_finite_health_sees_every_leaf():
    carry = (jnp.ones(4), jnp.zeros(3))
    assert bool(finite_health(carry))
    assert not bool(finite_health((carry[0].at[1].set(jnp.inf),
                                   carry[1])))
    assert not bool(finite_health((carry[0],
                                   carry[1].at[0].set(jnp.nan))))


# ------------------------------------------------- checkpoint / resume


def test_kill_and_resume_reaches_same_solution(tmp_path):
    """Acceptance: a fit killed mid-solve and resumed via resume_from=
    reaches the same solution as the uninterrupted run."""
    A, yc, _ = _data()
    d = str(tmp_path)
    opts = _opts(guard=True, recompute_every=16, checkpoint_every=8,
                 checkpoint_dir=d)
    full = KernelSVM(C=1.0, kernel="rbf",
                     options=_opts(guard=True, recompute_every=16))
    ref = full.fit(A, yc)

    svm = KernelSVM(C=1.0, kernel="rbf", options=opts)
    with inject(FaultPlan(kill_at_iter=192)) as plan:
        with pytest.raises(SimulatedKill) as ei:
            svm.fit(A, yc)
    assert plan.kill_fired
    assert ei.value.checkpoint_dir == d

    r = svm.fit(A, yc, resume_from=d)
    assert r.health.resumed_from == d
    assert r.health.events[0].kind == "resume"
    np.testing.assert_allclose(np.asarray(r.alpha),
                               np.asarray(ref.alpha), atol=1e-5)


def test_resume_refuses_foreign_checkpoint(tmp_path):
    A, yc, _ = _data()
    d = str(tmp_path)
    opts = _opts(guard=True, checkpoint_every=8, checkpoint_dir=d,
                 recompute_every=16)
    svm = KernelSVM(C=1.0, kernel="rbf", options=opts)
    with inject(FaultPlan(kill_at_iter=192)):
        with pytest.raises(SimulatedKill):
            svm.fit(A, yc)
    other = KernelSVM(C=1.0, kernel="rbf",
                      options=_opts(guard=True, recompute_every=16,
                                    seed=9))
    with pytest.raises(ValueError, match="fingerprint"):
        other.fit(A, yc, resume_from=d)


def test_resume_requires_guard():
    A, yc, _ = _data()
    svm = KernelSVM(C=1.0, kernel="rbf", options=_opts())
    with pytest.raises(ValueError, match="guard"):
        svm.fit(A, yc, resume_from="/nonexistent")


# ------------------------------------------------------ eager validation


def test_nonfinite_inputs_rejected_by_name():
    A, yc, _ = _data()
    svm = KernelSVM(C=1.0, kernel="rbf", options=_opts())
    with pytest.raises(ValueError, match=r"^A contains"):
        svm.fit(A.at[3, 2].set(jnp.nan), yc)
    with pytest.raises(ValueError, match=r"^y contains"):
        svm.fit(A, yc.at[0].set(jnp.inf))
    svm.fit(A, yc)
    with pytest.raises(ValueError, match=r"^A_test contains"):
        svm.predict(A.at[1, 1].set(jnp.nan))


def test_bad_hyperparameters_rejected_by_name():
    with pytest.raises(ValueError, match="C must be > 0"):
        KernelSVM(C=0.0)
    with pytest.raises(ValueError, match="lam must be > 0"):
        KernelRidge(lam=-1.0)


def test_guard_option_validation():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        SolverOptions(guard=True, checkpoint_every=4)
    with pytest.raises(ValueError, match="guard"):
        SolverOptions(checkpoint_every=4, checkpoint_dir="/tmp/x")
    with pytest.raises(ValueError, match="recompute_every"):
        SolverOptions(guard=True, recompute_every=-1)
    with pytest.raises(ValueError, match="recompute_every"):
        SolverOptions(guard=True, recompute_every="sometimes")


# ------------------------------------------------------ distributed (1d)

_DIST_SCRIPT = r"""
import numpy as np, jax.numpy as jnp
from repro.api import KernelRidge, SolverOptions
from repro.resilience import FaultPlan, inject

rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
y = jnp.asarray(A @ rng.standard_normal(16) + 0.1, jnp.float32)
kw = dict(method="sstep", s=8, b=8, max_iters=256, seed=3, layout="1d",
          slab_free=True)
plain = KernelRidge(lam=0.5, kernel="linear",
                    options=SolverOptions(**kw)).fit(A, y)
guard = KernelRidge(lam=0.5, kernel="linear",
                    options=SolverOptions(**kw, guard=True))
r = guard.fit(A, y)
assert np.allclose(np.asarray(plain.alpha), np.asarray(r.alpha)), \
    "guarded 1d != plain 1d"
with inject(FaultPlan(nan_at_iter=64)) as plan:
    rf = KernelRidge(lam=0.5, kernel="linear",
                     options=SolverOptions(**kw, guard=True)).fit(A, y)
assert plan.carry_fired
acts = [e.action for e in rf.health.fallbacks]
assert acts == ["halve_s:8->4"], acts
err = float(np.max(np.abs(np.asarray(rf.alpha) - np.asarray(plain.alpha))))
assert err < 1e-5, err
print("DIST-GUARD-OK")
"""


def test_guarded_1d_fault_recovery_subprocess():
    """Poisoned-psum fault on a 4-device host mesh: the chunk-boundary
    guard detects it, the ladder halves s, the re-run chunk recovers.
    Subprocess because device count must be set before jax init."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "DIST-GUARD-OK" in out.stdout
