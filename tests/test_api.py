"""The ``repro.api`` facade contract (ISSUE 3 acceptance criteria):

  * ``KernelSVM``/``KernelRidge`` + ``SolverOptions`` dispatch to every
    (method, layout) in {classical, sstep} x {serial, 1d, 2d} and match
    the legacy functional entrypoints' iterates to <= 1e-5 in f32;
  * tolerance-based early stopping terminates for every variant with a
    decreasing reported metric history;
  * bad ``SolverOptions`` raise eagerly (at construction);
  * ``H % s != 0`` no longer raises — the masked final short round keeps
    parity with the classical solvers (pad-and-mask, DESIGN.md §8).

The 1d/2d layouts run on an auto-built 1-device mesh here (the main
pytest process must keep seeing one device, per the dry-run contract);
the real 8-device parity sweep lives in tests/dist_worker.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FitResult, KernelRidge, KernelSVM, SolverOptions
from repro.core import (KernelConfig, bdcd_krr, dcd_ksvm, sstep_bdcd_krr,
                        sstep_dcd_ksvm)
from repro.data.synthetic import classification_dataset, regression_dataset

KERNELS = [
    KernelConfig("linear"),
    KernelConfig("polynomial", degree=3, coef0=1.0),
    KernelConfig("rbf", sigma=1.0),
]
METHODS = ("classical", "sstep")
LAYOUTS = ("serial", "1d", "2d")
TOL = dict(rtol=1e-5, atol=1e-5)

M, N, H, S, B = 64, 16, 32, 8, 4


@pytest.fixture(scope="module")
def svm_data():
    return classification_dataset(jax.random.key(0), m=M, n=N)


@pytest.fixture(scope="module")
def krr_data():
    return regression_dataset(jax.random.key(2), m=M, n=8)


# ---------------------------------------------------------------------------
# dispatch parity vs the legacy functional entrypoints
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("method", METHODS)
def test_ksvm_matches_legacy(svm_data, kernel, method, layout):
    A, y = svm_data
    opts = SolverOptions(method=method, s=S, layout=layout, max_iters=H)
    clf = KernelSVM(C=1.0, loss="l1", kernel=kernel, options=opts)
    res = clf.fit(A, y)
    a0 = jnp.zeros(M)
    if method == "classical":
        ref, _ = dcd_ksvm(A, y, a0, res.schedule, clf.cfg)
    else:
        ref, _ = sstep_dcd_ksvm(A, y, a0, res.schedule, clf.cfg, s=S)
    np.testing.assert_allclose(np.asarray(res.alpha), np.asarray(ref),
                               **TOL)
    # predict runs through the fitted state
    assert clf.predict(A).shape == (M,)


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("method", METHODS)
def test_krr_matches_legacy(krr_data, kernel, method, layout):
    A, y = krr_data
    opts = SolverOptions(method=method, s=S, b=B, layout=layout,
                         max_iters=H)
    reg = KernelRidge(lam=0.5, kernel=kernel, options=opts)
    res = reg.fit(A, y)
    a0 = jnp.zeros(M)
    if method == "classical":
        ref, _ = bdcd_krr(A, y, a0, res.schedule, reg.cfg)
    else:
        ref, _ = sstep_bdcd_krr(A, y, a0, res.schedule, reg.cfg, s=S)
    np.testing.assert_allclose(np.asarray(res.alpha), np.asarray(ref),
                               **TOL)
    assert reg.predict(A).shape == (M,)


def test_slab_free_false_matches_materialized_oracle(svm_data):
    A, y = svm_data
    opts = SolverOptions(method="sstep", s=S, max_iters=H, slab_free=False)
    res = KernelSVM(kernel="rbf", options=opts).fit(A, y)
    from repro.core import gram_slab
    ref, _ = sstep_dcd_ksvm(A, y, jnp.zeros(M), res.schedule,
                            KernelSVM(kernel="rbf").cfg, s=S,
                            gram_fn=gram_slab)
    np.testing.assert_allclose(np.asarray(res.alpha), np.asarray(ref),
                               **TOL)


# ---------------------------------------------------------------------------
# tolerance-based stopping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("method", METHODS)
def test_krr_tol_stops_every_variant(krr_data, method, layout):
    A, y = krr_data
    opts = SolverOptions(method=method, s=S, b=B, layout=layout,
                         tol=5e-2, check_every=2, max_iters=800)
    res = KernelRidge(lam=1.0, kernel="rbf", options=opts).fit(A, y)
    assert res.converged
    assert res.iters_run < 800
    assert res.metric == "rel_residual"
    hist = res.metric_history()
    assert hist is not None and len(hist) >= 1
    # reported history decreases overall and ends at/below tol
    assert hist[-1] <= 5e-2
    assert hist[-1] <= hist[0]


@pytest.mark.parametrize("layout", LAYOUTS)
def test_ksvm_tol_stops(svm_data, layout):
    A, y = svm_data
    # pick a reachable gap threshold: the gap after a full H run
    opts0 = SolverOptions(method="sstep", s=S, max_iters=256, record=True)
    base = KernelSVM(C=1.0, kernel="rbf", options=opts0).fit(A, y)
    target = float(base.metric_history()[-1]) * 1.05
    opts = SolverOptions(method="sstep", s=S, layout=layout, tol=target,
                         check_every=2, max_iters=1024)
    res = KernelSVM(C=1.0, kernel="rbf", options=opts).fit(A, y)
    assert res.converged and res.iters_run < 1024
    assert res.metric == "duality_gap"
    assert res.metric_history()[-1] <= target


def test_record_without_tol_runs_full_budget(krr_data):
    A, y = krr_data
    opts = SolverOptions(method="sstep", s=S, b=B, tol=0.0, record=True,
                         check_every=2, max_iters=H)
    res = KernelRidge(lam=1.0, kernel="rbf", options=opts).fit(A, y)
    assert not res.converged
    assert res.iters_run == H
    n_rounds = -(-H // S)
    hist = res.metric_history()
    assert len(hist) == -(-n_rounds // 2)
    assert hist[-1] <= hist[0]


# ---------------------------------------------------------------------------
# eager SolverOptions validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(method="sgd"),
    dict(layout="3d"),
    dict(s=0),
    dict(s="16"),
    dict(b=0),
    dict(b=-4),
    dict(max_iters=0),
    dict(check_every=0),
    dict(tol=-1e-3),
    dict(tol=float("nan")),
    dict(layout="2d", slab_free=False),
], ids=lambda d: ",".join(f"{k}={v}" for k, v in d.items()))
def test_solver_options_validate_eagerly(bad):
    with pytest.raises(ValueError):
        SolverOptions(**bad)


def test_mesh_axis_names_validated(svm_data):
    A, y = svm_data
    mesh = jax.make_mesh((1,), ("rows",))
    opts = SolverOptions(layout="1d", mesh=mesh, max_iters=8)
    with pytest.raises(ValueError, match="mesh lacks axes"):
        KernelSVM(options=opts).fit(A, y)


# ---------------------------------------------------------------------------
# ragged tails: H % s != 0 no longer raises, parity holds
# ---------------------------------------------------------------------------

class TestRaggedTail:
    @pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
    @pytest.mark.parametrize("H_ragged", [5, 27, 50])
    def test_sstep_dcd_ragged_matches_dcd(self, svm_data, kernel,
                                          H_ragged):
        A, y = svm_data
        from repro.core import SVMConfig, coordinate_schedule
        cfg = SVMConfig(C=1.0, loss="l1", kernel=kernel)
        sched = coordinate_schedule(jax.random.key(1), H_ragged, M)
        a0 = jnp.zeros(M)
        assert H_ragged % 16 != 0
        ref, _ = dcd_ksvm(A, y, a0, sched, cfg)
        got, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
    @pytest.mark.parametrize("H_ragged", [3, 13, 27])
    def test_sstep_bdcd_ragged_matches_bdcd(self, krr_data, kernel,
                                            H_ragged):
        A, y = krr_data
        from repro.core import KRRConfig, block_schedule
        cfg = KRRConfig(lam=0.5, kernel=kernel)
        sched = block_schedule(jax.random.key(3), H_ragged, M, B)
        a0 = jnp.zeros(M)
        assert H_ragged % 8 != 0
        ref, _ = bdcd_krr(A, y, a0, sched, cfg)
        got, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_facade_ragged_every_layout(self, svm_data, layout):
        """H=27, s=8 -> 4 rounds, last one short — all layouts agree
        with classical DCD."""
        A, y = svm_data
        opts = SolverOptions(method="sstep", s=8, layout=layout,
                             max_iters=27)
        clf = KernelSVM(C=1.0, kernel="rbf", options=opts)
        res = clf.fit(A, y)
        ref, _ = dcd_ksvm(A, y, jnp.zeros(M), res.schedule, clf.cfg)
        np.testing.assert_allclose(np.asarray(res.alpha),
                                   np.asarray(ref), rtol=2e-4, atol=2e-5)
        assert res.rounds_run == 4 and res.iters_run == 27


# ---------------------------------------------------------------------------
# FitResult bookkeeping
# ---------------------------------------------------------------------------

def test_fit_result_comm_model_scales_with_s(krr_data):
    """The modeled comm cost must reflect the paper's claim: s-step
    sends ~the same words in 1/s as many messages."""
    A, y = krr_data
    fits = {}
    for method, s in (("classical", 1), ("sstep", 8)):
        opts = SolverOptions(method=method, s=s, b=B, max_iters=H)
        fits[method] = KernelRidge(kernel="rbf", options=opts).fit(A, y)
    assert isinstance(fits["sstep"], FitResult)
    assert fits["sstep"].comm["msgs"] < fits["classical"].comm["msgs"]
    assert fits["sstep"].wall_time_s > 0.0
    for fr in fits.values():
        assert {"flops", "words", "msgs", "time"} <= set(fr.comm)


# ---------------------------------------------------------------------------
# LoopResult accessor edge cases (DESIGN.md §8: host-sync accessors)
# ---------------------------------------------------------------------------

class TestLoopResultAccessors:
    """metric_history()/drift_history() edge cases: empty schedules,
    fleet shapes, and the unguarded/no-cadence distinction."""

    def _round_fn(self):
        return lambda a, x: 0.5 * a

    def test_check_cadence_beyond_budget_final_check_fires(self):
        """A check cadence beyond the round budget still runs exactly
        one check — the driver forces a final-round check so converged
        is never stale — and metric_history() is the (1,) slice of the
        recorded buffer."""
        from repro.core.loop import run_rounds
        res = run_rounds(self._round_fn(), jnp.ones(4),
                         jnp.zeros((8,), jnp.int32), tol=1e-6,
                         check_every=100,
                         metric_fn=lambda a: jnp.linalg.norm(a))
        assert int(res.checks_run) == 1
        hist = res.metric_history()
        assert hist is not None and hist.shape == (1,)
        assert np.isfinite(np.asarray(hist)).all()

    def test_scan_mode_history_is_none(self):
        from repro.core.loop import run_rounds
        res = run_rounds(self._round_fn(), jnp.ones(4),
                         jnp.zeros((8,), jnp.int32))
        assert res.metric_history() is None
        assert res.drift_history() is None

    def test_fleet_history_shape(self):
        """run_rounds_fleet records (n_checks, F); metric_history()
        slices the leading check axis and keeps F."""
        from repro.core.loop import NO_TOL, run_rounds_fleet
        F, m = 3, 4
        state0 = jnp.ones((F, m))
        res = run_rounds_fleet(
            lambda a, x: 0.5 * a, state0, jnp.zeros((8,), jnp.int32),
            tol=NO_TOL, check_every=2,
            metric_fn=lambda a: jnp.linalg.norm(a, axis=1))
        hist = res.metric_history()
        assert hist.shape == (int(res.checks_run), F)
        assert int(res.checks_run) == 4
        assert res.converged.shape == (F,)

    def test_drift_history_unguarded_is_none(self):
        from repro.core.loop import run_rounds
        res = run_rounds(self._round_fn(), jnp.ones(4),
                         jnp.zeros((8,), jnp.int32), tol=1e-30,
                         metric_fn=lambda a: jnp.linalg.norm(a))
        assert res.drift_history() is None

    def test_drift_history_no_cadence_is_none(self):
        """guard= with correct_every=0 records no drift buffer at all:
        drift_history() is None (distinct from an empty slice)."""
        from repro.core.loop import GuardSpec, run_rounds
        guard = GuardSpec(
            health_fn=lambda a: jnp.all(jnp.isfinite(a)))
        res = run_rounds(self._round_fn(), jnp.ones(4),
                         jnp.zeros((8,), jnp.int32), tol=1e-30,
                         metric_fn=lambda a: jnp.linalg.norm(a),
                         guard=guard)
        assert res.drift_history() is None
        assert int(res.diverged_round) == -1

    def test_drift_history_cadence_never_fired_is_empty(self):
        """A guarded run whose cadence exceeds the round budget returns
        the empty (0,) slice — the buffer exists, nothing was
        recorded."""
        from repro.core.loop import GuardSpec, run_rounds
        guard = GuardSpec(
            health_fn=lambda a: jnp.all(jnp.isfinite(a)),
            correct_fn=lambda a: (a, jnp.asarray(0.0)),
            correct_every=100)
        res = run_rounds(self._round_fn(), jnp.ones(4),
                         jnp.zeros((8,), jnp.int32), tol=1e-30,
                         metric_fn=lambda a: jnp.linalg.norm(a),
                         guard=guard)
        drift = res.drift_history()
        assert drift is not None and drift.shape == (0,)
