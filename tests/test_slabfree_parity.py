"""Slab-free (GramOperator) solvers vs materialized-slab iterates.

Acceptance contract: the slab-free path must reproduce the
materialized-slab iterates to <= 1e-5 (f32) across all kernel x loss
combinations — the two paths differ ONLY in reduction order (blocked
contraction vs one slab GEMM), never in math.

Covers all four solvers: classical DCD/BDCD and the s-step variants with
s in {1, 4, 16}, for the three paper kernels x {L1, L2} SVM x KRR, plus
an interpret-mode Pallas-KMV run per solver family.  The shard_map
(distributed 1D/2D) parity lives in tests/dist_worker.py, which runs both
``slab_free`` settings against the serial solvers under an 8-device mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelConfig, KRRConfig, SVMConfig, bdcd_krr,
                        block_schedule, coordinate_schedule, dcd_ksvm,
                        gram_slab, sstep_bdcd_krr, sstep_dcd_ksvm)
from repro.data.synthetic import classification_dataset, regression_dataset
from repro.kernels.ops import make_solver_op_factory

KERNELS = [
    KernelConfig("linear"),
    KernelConfig("polynomial", degree=3, coef0=1.0),
    KernelConfig("rbf", sigma=1.0),
]

TOL = dict(rtol=1e-5, atol=1e-5)        # acceptance bound (f32)


def _svm_problem(loss, kernel, m=96, n=24, H=16):
    A, y = classification_dataset(jax.random.key(0), m=m, n=n)
    cfg = SVMConfig(C=1.0, loss=loss, kernel=kernel)
    sched = coordinate_schedule(jax.random.key(1), H, m)
    return A, y, jnp.zeros(m), sched, cfg


def _krr_problem(kernel, m=80, n=12, H=16, b=4):
    A, y = regression_dataset(jax.random.key(2), m=m, n=n)
    cfg = KRRConfig(lam=0.5, kernel=kernel)
    sched = block_schedule(jax.random.key(3), H, m, b)
    return A, y, jnp.zeros(m), sched, cfg


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("loss", ["l1", "l2"])
def test_dcd_slabfree_matches_materialized(kernel, loss):
    A, y, a0, sched, cfg = _svm_problem(loss, kernel)
    ref, _ = dcd_ksvm(A, y, a0, sched, cfg, gram_fn=gram_slab)
    got, _ = dcd_ksvm(A, y, a0, sched, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("loss", ["l1", "l2"])
@pytest.mark.parametrize("s", [1, 4, 16])
def test_sstep_dcd_slabfree_matches_materialized(kernel, loss, s):
    A, y, a0, sched, cfg = _svm_problem(loss, kernel)
    ref, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=s, gram_fn=gram_slab)
    got, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
def test_bdcd_slabfree_matches_materialized(kernel):
    A, y, a0, sched, cfg = _krr_problem(kernel)
    ref, _ = bdcd_krr(A, y, a0, sched, cfg, gram_fn=gram_slab)
    got, _ = bdcd_krr(A, y, a0, sched, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("s", [1, 4, 16])
def test_sstep_bdcd_slabfree_matches_materialized(kernel, s):
    A, y, a0, sched, cfg = _krr_problem(kernel)
    ref, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=s, gram_fn=gram_slab)
    got, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
def test_sstep_dcd_pallas_kmv_backend(kernel):
    """Interpret-mode Pallas KMV behind the operator, vs materialized."""
    A, y, a0, sched, cfg = _svm_problem("l2", kernel, m=48, n=32, H=16)
    factory = make_solver_op_factory(use_pallas=True, interpret=True,
                                     bm=16, br=8, bk=128)
    ref, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=4, gram_fn=gram_slab)
    got, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=4, op_factory=factory)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


@pytest.mark.parametrize("kernel", KERNELS, ids=lambda k: k.name)
def test_sstep_bdcd_pallas_kmv_backend(kernel):
    A, y, a0, sched, cfg = _krr_problem(kernel, m=64, n=16, H=8, b=4)
    factory = make_solver_op_factory(use_pallas=True, interpret=True,
                                     bm=16, br=8, bk=128)
    ref, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=4, gram_fn=gram_slab)
    got, _ = sstep_bdcd_krr(A, y, a0, sched, cfg, s=4, op_factory=factory)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


def test_slabfree_still_matches_classical_equivalence():
    """End-to-end: slab-free s-step DCD still equals classical DCD (the
    paper's Section 3 claim must survive the operator rewiring)."""
    A, y, a0, sched, cfg = _svm_problem("l1", KernelConfig("rbf"), H=32)
    a_dcd, _ = dcd_ksvm(A, y, a0, sched, cfg)
    a_ss, _ = sstep_dcd_ksvm(A, y, a0, sched, cfg, s=8)
    np.testing.assert_allclose(np.asarray(a_ss), np.asarray(a_dcd),
                               rtol=2e-4, atol=2e-5)
